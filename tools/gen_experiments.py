"""Assemble EXPERIMENTS.md from results/ JSONs + the narrative sections.

Usage: PYTHONPATH=src python tools/gen_experiments.py
"""
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, ROOT)


def jload(p, default=None):
    try:
        with open(os.path.join(ROOT, p)) as f:
            return json.load(f)
    except Exception:
        return default


def roofline_md(dirname):
    from benchmarks.roofline_table import load_rows, markdown
    rows = load_rows(os.path.join(ROOT, dirname))
    return markdown(rows)


def table1_md():
    rows = jload("results/bench/table1.json", [])
    out = ["| #VF | Detach/Attach avg ms (σ) | Pause/Unpause avg ms (σ) | "
           "overhead % | ms/VF delta |", "|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['num_vf']} | {r['detach_attach_ms']:.1f} "
            f"({r['detach_attach_std']:.1f}) | {r['pause_unpause_ms']:.1f} "
            f"({r['pause_unpause_std']:.1f}) | {r['overhead_pct']:+.1f} "
            f"| {r['ms_per_vf_delta']:+.1f} |")
    return "\n".join(out)


def table2_md():
    rows = jload("results/bench/table2.json", [])
    steps = ["rescan", "remove_vf", "change_num_vf", "add_vf", "total"]
    hdr = "| step | " + " | ".join(
        f"{r['num_vf']}VF D/A | {r['num_vf']}VF P/U" for r in rows) + " |"
    sep = "|" + "---|" * (1 + 2 * len(rows))
    out = [hdr, sep]
    for s in steps:
        cells = []
        for r in rows:
            cells.append(f"{r[f'DA_{s}_ms']:.1f}")
            cells.append(f"{r[f'PU_{s}_ms']:.1f}")
        out.append(f"| {s} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def pause_path_md():
    rows = jload("results/bench/pause_path.json", [])
    out = ["| variant | save ms | bytes moved MB | max rel err | note |",
           "|---|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r['name']} | {r['save_ms']:.1f} "
                   f"| {r['bytes_moved']/1e6:.1f} | {r['max_rel_err']:.4f} "
                   f"| {r['note']} |")
    return "\n".join(out)


def throughput_md():
    r = jload("results/bench/throughput.json", {})
    if not r:
        return "(run benchmarks.run --only throughput)"
    return (f"- step time before pause: {r['step_ms_before_pause']:.1f} ms; "
            f"after unpause: {r['step_ms_after_unpause']:.1f} ms "
            f"({r['pause_cycle_overhead_pct']:+.1f}%)\n"
            f"- snapshot: plain {r['snapshot_none_bytes']/1e6:.1f} MB vs "
            f"int8 {r['snapshot_int8_bytes']/1e6:.1f} MB "
            f"(ratio {r['compression_ratio']:.2f}x)")


def main():
    narrative = open(os.path.join(ROOT, "tools",
                                  "experiments_narrative.md")).read()
    doc = narrative
    doc = doc.replace("<!--TABLE1-->", table1_md())
    doc = doc.replace("<!--TABLE2-->", table2_md())
    doc = doc.replace("<!--PAUSEPATH-->", pause_path_md())
    doc = doc.replace("<!--THROUGHPUT-->", throughput_md())
    doc = doc.replace("<!--ROOFLINE_BASELINE-->",
                      roofline_md("results/dryrun_baseline"))
    doc = doc.replace("<!--ROOFLINE_OPT-->", roofline_md("results/dryrun"))
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(doc)
    print("wrote EXPERIMENTS.md", len(doc), "bytes")


if __name__ == "__main__":
    main()
