"""DeepSeek-67B: llama-arch dense, 95 layers (deep) — the scan-over-layers
stress case. [arXiv:2401.02954; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b", family="dense",
        num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=22016, vocab_size=102400, rope_theta=1e4,
        source="arXiv:2401.02954; hf",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=512,
    )


register("deepseek-67b", full, smoke, optimizer="adamw")
