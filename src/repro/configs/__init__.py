from repro.configs.base import (ATTN, MAMBA, MLSTM, SLSTM, FrontendConfig,
                                MeshConfig, ModelConfig, MoEConfig,
                                MULTI_POD_MESH, OptimizerConfig,
                                PrecisionConfig, RunConfig, SHAPES,
                                ShapeConfig, ShardingConfig, SINGLE_POD_MESH,
                                SSMConfig, UNIT_MESH, XLSTMConfig,
                                arch_defaults, get_model_config, list_archs,
                                make_run_config, register, shape_applicable)

__all__ = [
    "ATTN", "MAMBA", "MLSTM", "SLSTM", "FrontendConfig", "MeshConfig",
    "ModelConfig", "MoEConfig", "MULTI_POD_MESH", "OptimizerConfig",
    "PrecisionConfig", "RunConfig", "SHAPES", "ShapeConfig", "ShardingConfig",
    "SINGLE_POD_MESH", "SSMConfig", "UNIT_MESH", "XLSTMConfig",
    "arch_defaults", "get_model_config", "list_archs", "make_run_config",
    "register", "shape_applicable",
]
