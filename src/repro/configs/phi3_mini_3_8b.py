"""Phi-3-mini-3.8B: dense, MHA (kv=32), RoPE + SwiGLU.
[arXiv:2404.14219; unverified]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32064, rope_theta=1e4,
        source="arXiv:2404.14219; unverified",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=160, vocab_size=512,
    )


register("phi3-mini-3.8b", full, smoke)
