"""Configuration system for the repro framework.

Everything is a frozen dataclass so configs hash/compare cleanly — the
executable cache ("bitstream cache" in SVFF terms) is keyed on them.

An *architecture* config (``ModelConfig``) describes the network. A *shape*
config (``ShapeConfig``) describes one input-shape cell from the assignment
(train_4k / prefill_32k / decode_32k / long_500k). A ``RunConfig`` glues one
of each to mesh/optimizer/precision choices and is what launchers consume.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

# ---------------------------------------------------------------------------
# Block kinds understood by the model builder. A layer stack is described by a
# repeating *pattern* of blocks (len(pattern) must divide num_layers), which
# lets heterogeneous stacks (jamba's 1:7 attn:mamba, xlstm's mLSTM/sLSTM mix)
# scan over pattern-periods instead of unrolling all layers.
# ---------------------------------------------------------------------------
ATTN = "attn"      # full transformer block: attention + FFN (dense or MoE)
MAMBA = "mamba"    # mamba(-2 style SSD) block
MLSTM = "mlstm"    # xLSTM matrix-memory block
SLSTM = "slstm"    # xLSTM scalar-memory block (sequential recurrence)

VALID_BLOCKS = (ATTN, MAMBA, MLSTM, SLSTM)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_token: int
    d_ff: int                      # per-expert hidden dim
    capacity_factor: float = 1.25
    # layers whose (global) index satisfies index % every == offset get MoE
    every: int = 1
    offset: int = 0
    # Arctic-style: dense FFN in parallel (residual) with the MoE FFN
    dense_residual: bool = False
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 style SSD parameters (see DESIGN.md §hardware-adaptation)."""
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64             # channels per decay-head
    conv_dim: int = 4
    chunk: int = 128               # chunkwise-parallel scan chunk length


@dataclass(frozen=True)
class XLSTMConfig:
    head_dim: int = 64             # mLSTM qkv head dim
    proj_factor: float = 2.0       # mLSTM up-projection factor
    slstm_proj_factor: float = 1.333
    chunk: int = 128


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs() provides precomputed embeddings.

    kind='audio'  -> encoder consumes (batch, frames, d_model) frames
    kind='vision' -> (batch, num_patches, d_model) patch embeddings prepended
                     to the text sequence
    """
    kind: str = "none"             # none | audio | vision
    num_patches: int = 0           # vision: patches prepended
    frame_ratio: int = 4           # audio: frames = seq_len // frame_ratio


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                      # dense FFN hidden (0 => no FFN in block)
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    block_pattern: tuple = (ATTN,) # repeats to cover num_layers
    moe: Optional[MoEConfig] = None
    ssm: SSMConfig = SSMConfig()
    xlstm: XLSTMConfig = XLSTMConfig()
    # encoder-decoder (audio family)
    num_encoder_layers: int = 0
    frontend: FrontendConfig = FrontendConfig()
    # source/verification tier from the assignment table
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: pattern len {len(self.block_pattern)} must divide "
            f"num_layers {self.num_layers}")
        for b in self.block_pattern:
            assert b in VALID_BLOCKS, b
        if self.num_heads and self.num_kv_heads:
            assert self.num_heads % self.num_kv_heads == 0

    # ---- derived ---------------------------------------------------------
    @property
    def is_encoder_decoder(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return ATTN not in self.block_pattern

    @property
    def sub_quadratic(self) -> bool:
        """True if the stack is O(S) per token in context length (SSM /
        hybrid-with-few-attn / linear-attn families) — gate for long_500k."""
        return self.family in ("ssm", "hybrid")

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def layer_has_moe(self, i: int) -> bool:
        if self.moe is None:
            return False
        return i % self.moe.every == self.moe.offset

    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.block_pattern)

    # ---- parameter counting (exact, mirrors init code) --------------------
    def param_count(self) -> int:
        from repro.models.params import count_params_config
        return count_params_config(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params_config
        return count_params_config(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                      # train | prefill | decode
    seq_len: int                   # context length (KV/state length for decode)
    global_batch: int

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode")


# The four assigned LM shape cells.
TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs, per the assignment rules.

    long_500k needs sub-quadratic attention -> only ssm/hybrid families.
    (No assigned arch is encoder-only, so decode shapes always apply.)
    """
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, ("long_500k skipped: pure full-attention arch "
                       "(see DESIGN.md §4)")
    return True, ""


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor | sgd
    lr: float = 3e-4
    warmup: int = 100
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # beyond-paper: quantize gradient all-reduce payloads (qdma_pack)
    grad_compression: str = "none" # none | int8


@dataclass(frozen=True)
class MeshConfig:
    shape: tuple = (1, 1)
    axes: tuple = ("data", "model")

    @property
    def num_devices(self) -> int:
        return math.prod(self.shape)

    @property
    def data_axes(self) -> tuple:
        """Axes the batch is sharded over (everything except 'model'/'pipe')."""
        return tuple(a for a in self.axes if a not in ("model", "pipe"))

    @property
    def model_size(self) -> int:
        if "model" not in self.axes:
            return 1
        return self.shape[self.axes.index("model")]


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))
UNIT_MESH = MeshConfig((1, 1), ("data", "model"))


@dataclass(frozen=True)
class ShardingConfig:
    fsdp: bool = True              # shard params/opt-state over data axes
    seq_shard_acts: bool = False   # sequence-shard long activations (SP)
    shard_kv_seq: bool = True      # decode KV cache sequence-sharded on model
    remat: str = "dots"            # none | dots | full
    scan_layers: bool = True
    # unroll the grad-accumulation scan (dry-run cost variants only: keeps
    # XLA's while-body-once cost_analysis honest for microbatch > 1)
    unroll_microbatch: bool = False
    # beyond-paper hillclimb knobs (see EXPERIMENTS.md §Perf)
    gather_dim: str = "auto"       # auto | fsdp-transpose


@dataclass(frozen=True)
class PrecisionConfig:
    params: str = "float32"        # float32 | bfloat16
    compute: str = "bfloat16"
    logits: str = "float32"


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = UNIT_MESH
    optimizer: OptimizerConfig = OptimizerConfig()
    sharding: ShardingConfig = ShardingConfig()
    precision: PrecisionConfig = PrecisionConfig()
    kernel_backend: str = "reference"   # reference | pallas | auto
    microbatch: int = 1                 # grad-accum microbatches
    seed: int = 0
    # VF placement policy the SVFFManager's scheduler uses for this tenant
    # (see core/scheduler.py): first_fit | best_fit | fair_share
    placement: str = "first_fit"

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Architecture registry.  configs/<arch>.py modules call register() at import.
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_DEFAULTS: dict[str, dict] = {}


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig], **defaults):
    """Register an architecture.

    ``full``  — the exact assigned config (dry-run only: never allocated).
    ``smoke`` — a reduced config of the same family for CPU tests.
    ``defaults`` — per-arch RunConfig field overrides (e.g. optimizer for
    the 400B-class archs that need Adafactor to fit v5e HBM).
    """
    _REGISTRY[name] = full
    _SMOKE_REGISTRY[name] = smoke
    _DEFAULTS[name] = defaults


def _ensure_imported():
    # One module per assigned arch, imported lazily to avoid import cycles.
    from repro.configs import (arctic_480b, olmoe_1b_7b, qwen3_0_6b,  # noqa
                               llama3_8b, deepseek_67b, phi3_mini_3_8b,
                               seamless_m4t_medium, xlstm_350m,
                               jamba_1_5_large_398b, internvl2_1b, paper)


def list_archs() -> list[str]:
    _ensure_imported()
    return sorted(_REGISTRY)


def get_model_config(name: str, smoke: bool = False) -> ModelConfig:
    _ensure_imported()
    reg = _SMOKE_REGISTRY if smoke else _REGISTRY
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; have {sorted(reg)}")
    return reg[name]()


def arch_defaults(name: str) -> dict:
    _ensure_imported()
    return dict(_DEFAULTS.get(name, {}))


def make_run_config(arch: str, shape: str, mesh: MeshConfig = UNIT_MESH,
                    smoke: bool = False, **overrides) -> RunConfig:
    model = get_model_config(arch, smoke=smoke)
    kw = arch_defaults(arch)
    kw.update(overrides)
    shape_cfg = SHAPES[shape] if isinstance(shape, str) else shape
    opt = kw.pop("optimizer", OptimizerConfig())
    if isinstance(opt, str):
        opt = OptimizerConfig(name=opt)
    prec = kw.pop("precision", None)
    if prec is None:
        # 100B+ archs store params in bf16 (see DESIGN.md memory budget)
        big = model.param_count() > 30_000_000_000
        prec = PrecisionConfig(params="bfloat16" if big else "float32")
    return RunConfig(model=model, shape=shape_cfg, mesh=mesh, optimizer=opt,
                     precision=prec, **kw)
