"""Jamba-1.5-Large: hybrid Mamba+attention 1:7 interleave (period of 8:
one attention layer per 7 mamba layers), MoE 16e top-2 on every other layer,
dense MLP on the rest. 398B total / ~94B active. Sub-quadratic (9 attn layers
only), so long_500k applies. [arXiv:2403.19887; hf]

Hardware adaptation: mamba layers use the Mamba-2 (SSD) scalar-per-head-decay
chunked formulation — MXU-friendly — rather than Mamba-1's per-(channel,state)
scan (see DESIGN.md §2).
"""
from repro.configs.base import (ATTN, MAMBA, ModelConfig, MoEConfig,
                                SSMConfig, register)


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=24576, vocab_size=65536, rope_theta=1e4,
        block_pattern=(ATTN,) + (MAMBA,) * 7,
        moe=MoEConfig(num_experts=16, num_experts_per_token=2, d_ff=24576,
                      every=2, offset=1),
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, conv_dim=4),
        source="arXiv:2403.19887; hf",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512,
        block_pattern=(ATTN, MAMBA, MAMBA, MAMBA),
        moe=MoEConfig(num_experts=4, num_experts_per_token=2, d_ff=128,
                      every=2, offset=1),
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, conv_dim=4, chunk=16),
    )


register("jamba-1.5-large-398b", full, smoke, optimizer="adafactor")
