"""SeamlessM4T-medium: encoder-decoder multimodal backbone; the audio
frontend is a STUB (input_specs provides precomputed frame embeddings at
seq_len // frame_ratio). [arXiv:2308.11596; hf]"""
from repro.configs.base import FrontendConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        num_layers=12, num_encoder_layers=12,
        d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=4096, vocab_size=256206, rope_theta=1e4,
        frontend=FrontendConfig(kind="audio", frame_ratio=4),
        source="arXiv:2308.11596; hf",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium-smoke", family="audio",
        num_layers=2, num_encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512,
        frontend=FrontendConfig(kind="audio", frame_ratio=4),
    )


register("seamless-m4t-medium", full, smoke)
