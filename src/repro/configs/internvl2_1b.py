"""InternVL2-1B: InternViT vision frontend (STUB — input_specs provides 256
precomputed patch embeddings) + Qwen2-0.5B-class LM backbone (GQA kv=2).
[arXiv:2404.16821; hf]"""
from repro.configs.base import FrontendConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        d_ff=4864, vocab_size=151655, rope_theta=1e6, tie_embeddings=True,
        frontend=FrontendConfig(kind="vision", num_patches=256),
        source="arXiv:2404.16821; hf",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, tie_embeddings=True,
        frontend=FrontendConfig(kind="vision", num_patches=8),
    )


register("internvl2-1b", full, smoke)
