"""xLSTM-350M-class: mLSTM + sLSTM blocks (3:1 mix), no FFN (d_ff=0 — the
recurrent blocks carry their own projections). State is O(1) in context
length, so long_500k applies. [arXiv:2405.04517; unverified]"""
from repro.configs.base import MLSTM, SLSTM, ModelConfig, XLSTMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        block_pattern=(MLSTM, MLSTM, MLSTM, SLSTM),
        # head_dim=512 => d_inner 2048 / 512 = 4 mLSTM heads (assignment: 4H)
        xlstm=XLSTMConfig(head_dim=512, proj_factor=2.0),
        source="arXiv:2405.04517; unverified",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-smoke", family="ssm",
        num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
        d_ff=0, vocab_size=512,
        block_pattern=(MLSTM, MLSTM, MLSTM, SLSTM),
        xlstm=XLSTMConfig(head_dim=32, proj_factor=2.0, chunk=16),
    )


register("xlstm-350m", full, smoke)
