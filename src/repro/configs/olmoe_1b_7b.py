"""OLMoE-1B-7B: 64-expert top-8 MoE, no dense path. [arXiv:2409.02060; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1024, vocab_size=50304, qk_norm=True, rope_theta=1e4,
        moe=MoEConfig(num_experts=64, num_experts_per_token=8, d_ff=1024),
        source="arXiv:2409.02060; hf",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=64, vocab_size=512, qk_norm=True,
        moe=MoEConfig(num_experts=8, num_experts_per_token=2, d_ff=64),
    )


register("olmoe-1b-7b", full, smoke)
