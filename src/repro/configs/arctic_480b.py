"""Snowflake Arctic (base): dense-MoE hybrid, 128 experts top-2 with a dense
FFN in residual parallel. [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=4864, vocab_size=32000, rope_theta=1e4,
        moe=MoEConfig(num_experts=128, num_experts_per_token=2, d_ff=4864,
                      dense_residual=True),
        source="hf:Snowflake/snowflake-arctic-base; hf",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=96, vocab_size=512,
        moe=MoEConfig(num_experts=4, num_experts_per_token=2, d_ff=96,
                      dense_residual=True),
    )


# 480B-class: bf16 params + Adafactor to fit v5e HBM (see DESIGN.md).
register("arctic-480b", full, smoke, optimizer="adafactor")
