"""The paper's own benchmark configuration (SVFF §V).

The paper's FPGA design exposes 1 PF (memory controller class) with up to
32 VFs; each VF surfaces a fast 512KB memory and a slow 32KB memory. The
TPU analogue used by benchmarks/table1.py is a pool partitioned into up to
32 VF slices, each running a small tenant workload ("svff-bench") whose
state plays the role of the VF's device memory. Reconfiguration cycles
(detach/attach vs pause/unpause) are measured end-to-end exactly as the
paper does (Table I: 1/4/10 VFs, avg of 100 runs).
"""
from repro.configs.base import ModelConfig, register

# SVFF paper constants (Section V-A)
PAPER_MAX_VFS = 32
PAPER_NUM_PFS = 1
PAPER_FAST_MEM_BYTES = 512 * 1024
PAPER_SLOW_MEM_BYTES = 32 * 1024
PAPER_VF_COUNTS = (1, 4, 10)     # Table I rows
PAPER_RUNS = 100                 # Table I: avg of 100 runs


def full() -> ModelConfig:
    # Tenant workload for reconfiguration benchmarks: a small dense LM whose
    # parameter state (~512KB at fp32) mirrors the paper's fast VF memory.
    return ModelConfig(
        name="svff-bench", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512,
        source="SVFF paper §V-A analogue",
    )


register("svff-bench", full, full)
