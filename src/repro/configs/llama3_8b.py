"""Llama-3-8B: dense GQA kv=8, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=128256, rope_theta=5e5,
        source="arXiv:2407.21783; unverified",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=160, vocab_size=512,
    )


register("llama3-8b", full, smoke)
