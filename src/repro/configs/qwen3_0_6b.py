"""Qwen3-0.6B: dense, GQA kv=8, qk_norm, head_dim=128 (decoupled from
d_model/H as in the Qwen3 family). [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
        d_ff=3072, vocab_size=151936, head_dim=128, qk_norm=True,
        rope_theta=1e6, tie_embeddings=True,
        source="hf:Qwen/Qwen3-8B; hf",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=32, qk_norm=True,
        tie_embeddings=True,
    )


register("qwen3-0.6b", full, smoke)
