"""MetricsBus — per-engine serve-plane telemetry for the autoscaler.

The hot path (``ServeFleet.submit`` / ``step``) only ever appends to
bounded deques and bumps counters; all aggregation (sorting for
percentiles) is deferred to ``snapshot``-time, which runs once per
autoscaler epoch, not once per token. Latencies are harvested from the
per-token wall timestamps the engine already records on each ``Request``
(``t_submit`` / ``t_tok``), so serving pays nothing extra for them.
"""
from __future__ import annotations

import collections
import math
from typing import Iterable


def percentile(xs, q: float) -> float:
    """Nearest-rank percentile over an unsorted iterable (0 when empty).

    Canonical nearest-rank: the smallest element with at least ``q`` of
    the sample at or below it — 1-based rank ``ceil(q * n)``. The old
    ``int(round(q * (n - 1)))`` compressed quantiles onto an (n-1) index
    range and broke .5 ties with Python's banker's rounding (toward
    EVEN), so the reported rank drifted off the definition by one
    position with direction depending on window parity — e.g. p50 of a
    4-sample window returned the 3rd smallest (rank 3, a ~62nd
    percentile), not rank ceil(2) = 2. Small telemetry windows (fresh
    engine, post-scale-out) are exactly where the autoscaler compares
    these numbers against fixed thresholds, so the rank must be the
    definitional one, not parity-dependent."""
    xs = sorted(xs)
    n = len(xs)
    if n == 0:
        return 0.0
    i = min(n - 1, max(0, math.ceil(q * n) - 1))
    return xs[i]


class MetricsBus:
    """Sliding-window fleet telemetry keyed by engine tid."""

    def __init__(self, window: int = 256):
        self.window = window
        self._ttft = collections.defaultdict(
            lambda: collections.deque(maxlen=window))
        self._itl = collections.defaultdict(
            lambda: collections.deque(maxlen=window))
        self._load = collections.defaultdict(
            lambda: collections.deque(maxlen=window))
        self.submitted = collections.Counter()
        self.completed = collections.Counter()
        self.rejected = collections.Counter()
        # cache-pressure counters (absolute cumulative values mirrored
        # from each engine's stats, not deltas — record overwrites)
        self.cache_exhausted = collections.Counter()
        self.defrag_events = collections.Counter()
        # request live-migration counters, attributed to the SOURCE
        # engine (it initiated the hand-off); stall ticks are mirrored
        # from the engine like the cache counters above
        self.migrations_attempted = collections.Counter()
        self.migrations_completed = collections.Counter()
        self.migrations_aborted = collections.Counter()
        self.migration_blocks = collections.Counter()
        self.migration_stall_ticks = collections.Counter()
        # pipeline-stage telemetry (mirror-overwrite like the cache
        # counters): per-stage busy shares of the measured makespan and
        # the measured GPipe bubble fraction, from the engine's schedule
        # walls — empty/0 for single-VF engines
        self.stage_loads: dict = {}
        self.stage_bubble: dict = {}
        self._rejected_since_snapshot = 0
        # requests already harvested, keyed (rid, t_submit); pruned when
        # the owner engine's finished list is drained
        self._seen: dict[str, set] = collections.defaultdict(set)

    # -- hot path (O(1) appends) -------------------------------------------
    def record_submit(self, tid: str) -> None:
        self.submitted[tid] += 1

    def record_reject(self, tid: str) -> None:
        self.rejected[tid] += 1
        self._rejected_since_snapshot += 1

    def record_load(self, tid: str, load: int, queued: int) -> None:
        self._load[tid].append((load, queued))

    def record_cache_pressure(self, tid: str, exhausted: int,
                              defrags: int) -> None:
        """Mirror an engine's cumulative exhaustion/defrag counters so the
        autoscaler sees CACHE pressure, not just queue depth: a fleet can
        have short queues yet be thrashing its paged pool."""
        self.cache_exhausted[tid] = exhausted
        self.defrag_events[tid] = defrags

    def record_migration(self, src: str, dst: str, *, completed: bool,
                         blocks: int = 0) -> None:
        """One request-migration attempt src -> dst. ``blocks`` is the
        number of KV pages actually shipped (0 on an aborted attempt)."""
        self.migrations_attempted[src] += 1
        if completed:
            self.migrations_completed[src] += 1
            self.migration_blocks[src] += blocks
        else:
            self.migrations_aborted[src] += 1

    def record_migration_stall(self, tid: str, ticks: int) -> None:
        """Mirror an engine's cumulative frozen-slot stall ticks (decode
        iterations a slot sat unservable mid-hand-off)."""
        self.migration_stall_ticks[tid] = ticks

    def record_stage_load(self, tid: str, loads, bubble: float) -> None:
        """Mirror a pipeline gang's per-stage busy shares and measured
        schedule bubble (vs the analytic ``bubble_fraction(M, S)``) so
        width actions are justified by evidence, not geometry."""
        self.stage_loads[tid] = tuple(float(x) for x in loads)
        self.stage_bubble[tid] = float(bubble)

    def harvest(self, tid: str, finished: Iterable) -> None:
        """Pull TTFT/ITL samples from finished requests' token walls.
        Idempotent per request, so it may be called every fleet step over
        the engine's not-yet-drained finished list."""
        seen = self._seen[tid]
        for req in finished:
            key = (req.rid, req.t_submit)
            if key in seen or not req.t_tok:
                continue
            seen.add(key)
            self.completed[tid] += 1
            self._ttft[tid].append(req.t_tok[0] - req.t_submit)
            self._itl[tid].extend(
                b - a for a, b in zip(req.t_tok, req.t_tok[1:]))

    def drained(self, tid: str) -> None:
        """The engine's finished list was emptied — its keys can't recur."""
        self._seen[tid].clear()

    # -- snapshot-time aggregation -----------------------------------------
    def ttft_ms(self, tid: str, q: float = 0.95) -> float:
        return percentile(self._ttft[tid], q) * 1e3

    def itl_ms(self, tid: str, q: float = 0.95) -> float:
        return percentile(self._itl[tid], q) * 1e3

    def take_rejected_recent(self) -> int:
        n, self._rejected_since_snapshot = self._rejected_since_snapshot, 0
        return n

    def load_p95(self, tid: str) -> float:
        return percentile([s[0] for s in self._load[tid]], 0.95)

    def replicate(self, now: float) -> dict:
        """Stamped copy of the aggregated view for cross-host replication:
        the federation coordinator keeps the newest replica it could pull
        per host, and judges freshness by ``stamp`` age on ITS clock (so
        host and coordinator clocks never need to agree). Everything in
        the replica is already aggregated — replication cost is O(engines),
        never O(requests)."""
        return {"stamp": float(now),
                "rejected_recent": self._rejected_since_snapshot,
                "engines": self.describe()}

    def describe(self) -> dict:
        return {tid: {"submitted": self.submitted[tid],
                      "completed": self.completed[tid],
                      "rejected": self.rejected[tid],
                      "cache_exhausted": self.cache_exhausted[tid],
                      "defrag_events": self.defrag_events[tid],
                      "migrations_attempted": self.migrations_attempted[tid],
                      "migrations_completed": self.migrations_completed[tid],
                      "migrations_aborted": self.migrations_aborted[tid],
                      "migration_blocks": self.migration_blocks[tid],
                      "migration_stall_ticks":
                          self.migration_stall_ticks[tid],
                      "stage_loads": list(self.stage_loads.get(tid, ())),
                      "bubble_frac": round(
                          self.stage_bubble.get(tid, 0.0), 4),
                      "load_p95": self.load_p95(tid),
                      "ttft_p95_ms": round(self.ttft_ms(tid), 3),
                      "itl_p95_ms": round(self.itl_ms(tid), 3)}
                for tid in sorted(set(self.submitted)
                                  | set(self.completed)
                                  | set(self.rejected)
                                  | set(self.cache_exhausted)
                                  | set(self.stage_bubble)
                                  | set(self.migrations_attempted))}
