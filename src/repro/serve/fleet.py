"""ServeFleet — N serving engines as tenants under the SVFF manager.

The paper's transparency claim only matters under load: a pause/migrate is
interesting when the paused guest is mid-decode with a full batch and
traffic keeps arriving. The fleet packages exactly that:

  EngineTenant   adapts a ``ServeEngine`` to the manager/pause duck-typed
                 tenant protocol (bind/suspend/resume/export_state/...), so
                 the real pool / scheduler / journal / staging / records
                 paths manage serving guests unchanged
  ServeFleet     owns a DevicePool + SVFFManager, places each engine tenant
                 through the configured placement policy
                 (``core.scheduler.make_scheduler``), spreads arriving
                 requests across engines with SLO-aware admission (bounded
                 per-engine load; overloads raise ``RequestRejected``
                 instead of building unbounded queues), and keeps serving
                 THROUGH ``pause_live``/``migrate`` — the pre-copy rounds
                 step the victim engine itself, so reconfiguration fires
                 mid-traffic, which is the whole point.
"""
from __future__ import annotations

import types
from typing import Optional

import jax
import numpy as np

from repro.core.manager import SVFFManager
from repro.core.pool import DevicePool
from repro.core.tenant import DevicePausedError
from repro.core.vf import VirtualFunction
from repro.serve.engine import Request, ServeEngine
from repro.serve.paged import RequestRejected


class EngineTenant:
    """Tenant-protocol adapter around a ServeEngine (the guest's 'VM')."""

    def __init__(self, tid: str, engine: ServeEngine, *,
                 placement: str = "first_fit"):
        self.tid = tid
        self.engine = engine
        self.status = "created"        # created|running|paused|detached
        self.vf_id: Optional[str] = None
        self.steps_done = 0
        self.workload = "serve"
        self._exec_cache: dict = {}
        self._template = None
        self.run = types.SimpleNamespace(
            model=types.SimpleNamespace(name=engine.run.model.name),
            placement=placement, seed=engine.run.seed)

    # -- lifecycle -----------------------------------------------------------
    def bind(self, vf: VirtualFunction, state=None, *,
             flash: bool = True) -> float:
        if state is not None:
            self.engine.import_state(state)
        key = (tuple(vf.mesh_shape), tuple(str(d) for d in vf.devices))
        self._exec_cache.setdefault(key, True)
        self.vf_id = vf.vf_id
        self.status = "running"
        self.engine.unpause()
        vf.emulated.update({"tenant": self.tid, "status": "running",
                            "steps_done": self.steps_done})
        return 0.0

    def run_steps(self, n: int = 1) -> dict:
        if self.status == "paused":
            raise DevicePausedError(
                f"{self.tid}: device {self.vf_id} is paused")
        if self.status != "running":
            raise RuntimeError(f"{self.tid}: no device attached")
        active = 0
        for _ in range(n):
            active = self.engine.step()
            self.steps_done += 1
        return {"active": active, "queued": len(self.engine.queue)}

    # -- pause protocol ------------------------------------------------------
    def export_state(self):
        st = self.engine.export_state()
        # cache the restore template only once the engine has a real
        # cache (a fresh engine exports cache=None, which would freeze a
        # template missing every cache leaf); shapes are stable after
        if self._template is None and st.get("cache") is not None:
            self._template = jax.tree.map(
                lambda x: np.zeros(getattr(x, "shape", ()),
                                   dtype=getattr(x, "dtype", np.float32)),
                st)
        return st

    def export_specs(self):
        return {}

    def shardings_for(self, vf: VirtualFunction):
        return None

    def state_template(self):
        if self._template is None:
            self.engine._ensure_cache()
            self.export_state()
        if self._template is None:
            raise RuntimeError(
                f"{self.tid}: no exported state to derive a restore "
                "template from")
        return self._template

    def dirty_keys(self):
        return self.engine.dirty_keys()

    def suspend(self):
        self.engine.pause()
        # in-flight chunked prefills re-queue (they have emitted nothing
        # and are deterministic), so the exported snapshot really is the
        # engine's complete device state
        self.engine.abort_prefill_jobs()
        self.engine._cache = None      # device refs dropped; snapshot holds
        self.status = "paused"

    def resume(self, state, vf: VirtualFunction):
        self.status = "running"
        self.bind(vf, state=state)

    def detach(self):
        self.engine.pause()
        self.engine.abort_prefill_jobs()
        self.engine._cache = None
        self.vf_id = None
        self.status = "detached"

    # -- introspection -------------------------------------------------------
    @property
    def load(self) -> int:
        """Requests this engine is responsible for right now."""
        eng = self.engine
        return (len(eng.queue) + len(eng._jobs)
                + sum(r is not None for r in eng.active))

    def query(self) -> dict:
        return {"tenant": self.tid, "status": self.status,
                "vf": self.vf_id, "steps_done": self.steps_done,
                "workload": self.workload, "load": self.load,
                "exec_keys": [list(map(str, k)) for k in self._exec_cache]}

    def inject_failure(self):
        pass


class ServeFleet:
    """Run ``num_engines`` ServeEngines as SVFF tenants over one pool."""

    def __init__(self, run, params, *, num_engines: int = 2,
                 num_devices: int = 8, policy: str = "first_fit",
                 slots: int = 4, max_len: int = 256, paged: bool = True,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: int = 0, slo_max_load: int = 64,
                 workdir: str = "/tmp/svff_fleet", devices=None):
        self.run = run
        self.slo_max_load = slo_max_load
        devices = (tuple(devices) if devices is not None else
                   tuple(f"fleetdev{i}" for i in range(num_devices)))
        self.pool = DevicePool(devices=devices, max_vfs=max(num_engines, 1))
        self.mgr = SVFFManager(self.pool, workdir=workdir, scheduler=policy)
        self.tenants: dict[str, EngineTenant] = {}
        # each tenant OWNS its device state: a pause deletes the exported
        # leaves after staging them, so engines must not alias one params
        # pytree (guest isolation, like VMs not sharing guest RAM)
        engines = [
            ServeEngine(run, jax.tree.map(jax.numpy.array, params),
                        slots=slots, max_len=max_len,
                        paged=paged, page_size=page_size,
                        num_pages=num_pages, prefill_chunk=prefill_chunk)
            for _ in range(num_engines)]
        tns = [EngineTenant(f"serve{i}", eng, placement=policy)
               for i, eng in enumerate(engines)]
        for tn in tns:
            self.tenants[tn.tid] = tn
        self.mgr.init(num_engines, tns)
        self._rejected: list[Request] = []

    # -- traffic --------------------------------------------------------------
    def submit(self, req: Request) -> str:
        """SLO-aware admission: the request goes to the least-loaded
        attached engine; if even that one is past ``slo_max_load``, the
        request is rejected NOW (typed) rather than queued into an SLO
        miss. Paused engines still accept traffic (their queue holds) but
        running ones are preferred."""
        cands = [tn for tn in self.tenants.values()
                 if tn.status in ("running", "paused")]
        if not cands:
            raise RequestRejected(f"request {req.rid}: no serving engines")
        running = [tn for tn in cands if tn.status == "running"]
        pick = min(running or cands, key=lambda tn: (tn.load, tn.tid))
        if pick.load >= self.slo_max_load:
            req.done = True
            req.error = (f"SLO admission: engine {pick.tid} at load "
                         f"{pick.load} >= {self.slo_max_load}")
            self._rejected.append(req)
            raise RequestRejected(req.error)
        pick.engine.submit(req)
        return pick.tid

    def step(self) -> int:
        """One fleet iteration: every RUNNING engine advances one step.
        Paused engines hold their queues (the guest keeps its device)."""
        active = 0
        for tn in self.tenants.values():
            if tn.status == "running":
                active += tn.run_steps(1)["active"]
        return active

    def drain(self, max_steps: int = 10_000) -> "DrainResult":
        """Serve until every RUNNING engine is idle; returns the finished
        (and SLO-rejected) requests. ``.drained`` is False when work is
        stranded — on a still-paused engine, or because max_steps ran
        out — mirroring ``ServeEngine.run_until_idle``."""
        from repro.serve.engine import DrainResult
        done: list[Request] = []
        for _ in range(max_steps):
            if self.step() == 0 and not any(
                    tn.engine.queue or tn.engine._jobs
                    for tn in self.tenants.values()
                    if tn.status == "running"):
                break
        pending = False
        for tn in self.tenants.values():
            res = tn.engine.run_until_idle(max_steps=0)
            done.extend(res)
            pending |= not res.drained
        done.extend(self._rejected)
        self._rejected = []
        return DrainResult(done, drained=not pending)

    # -- reconfiguration under traffic ----------------------------------------
    def pause_live(self, tid: str, *, rounds: int = 2):
        """Live pause of one engine while it KEEPS SERVING its batch: the
        pre-copy rounds step the victim engine (and the rest of the fleet
        rides along untouched)."""
        tn = self.tenants[tid]
        return self.mgr.pause_live(
            tn, rounds=rounds, step_fn=lambda: tn.run_steps(1))

    def unpause(self, tid: str):
        return self.mgr.unpause(self.tenants[tid])

    def migrate(self, tid: str):
        return self.mgr.migrate(self.tenants[tid])

    def query(self) -> dict:
        return {"manager": self.mgr.query(),
                "engines": {tid: tn.query()
                            for tid, tn in self.tenants.items()}}
