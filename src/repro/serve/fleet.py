"""ServeFleet — N serving engines as tenants under the SVFF manager.

The paper's transparency claim only matters under load: a pause/migrate is
interesting when the paused guest is mid-decode with a full batch and
traffic keeps arriving. The fleet packages exactly that:

  EngineTenant   adapts a ``ServeEngine`` to the manager/pause duck-typed
                 tenant protocol (bind/suspend/resume/export_state/...), so
                 the real pool / scheduler / journal / staging / records
                 paths manage serving guests unchanged
  ServeFleet     owns a DevicePool + SVFFManager, places each engine tenant
                 through the configured placement policy
                 (``core.scheduler.make_scheduler``), spreads arriving
                 requests across engines with SLO-aware admission (bounded
                 per-engine load; overloads raise ``RequestRejected``
                 instead of building unbounded queues), and keeps serving
                 THROUGH ``pause_live``/``migrate`` — the pre-copy rounds
                 step the victim engine itself, so reconfiguration fires
                 mid-traffic, which is the whole point.

On top of the on-request reconfiguration surface sits the elastic SLO
control plane: a ``MetricsBus`` (``serve/telemetry.py``) samples per-
engine load and latency windows on the hot path, and ``autoscale_step``
feeds one snapshot per epoch to the ``core.autoscaler`` policy loop,
executing its actions through the SAME journaled manager ops —

  scale_out   attach a parked/fresh ``EngineTenant`` to a free VF, or run
              the paper's full reconf cycle to carve one more VF
  scale_in    detach an idle engine (state parks on disk; its VF keeps
              its devices and becomes the next scale_out's cheap path)
  rebalance   move queued requests hot -> cold (they have emitted
              nothing, so moving them is token-identical) and migrate the
              hot victim onto fresh devices without dropping its batch

— so crash recovery (PR 3's journal + ``SVFFManager.recover``) covers
autoscaler-initiated reconfiguration for free.
"""
from __future__ import annotations

import collections
import types
from typing import Optional

import jax
import numpy as np

from repro.core.autoscaler import (Autoscaler, AutoscaleAction,
                                   AutoscaleConfig, EngineStats,
                                   TelemetrySnapshot)
from repro.core.manager import ManagerError, SVFFManager
from repro.core.pool import DevicePool
from repro.core.tenant import DevicePausedError
from repro.core.vf import VFState, VirtualFunction
from repro.serve.engine import Request, ServeEngine
from repro.serve.paged import CacheExhausted, RequestRejected
from repro.serve.telemetry import MetricsBus


class EngineTenant:
    """Tenant-protocol adapter around a ServeEngine (the guest's 'VM')."""

    def __init__(self, tid: str, engine: ServeEngine, *,
                 placement: str = "first_fit"):
        self.tid = tid
        self.engine = engine
        self.status = "created"        # created|running|paused|detached
        self.vf_id: Optional[str] = None
        self.steps_done = 0
        self.workload = "serve"
        self._exec_cache: dict = {}
        self._template = None
        # pipeline gang: a stage-spanning engine's K-1 shell members
        # (one VF each, stage 0 rides the lead's own VF). Empty for
        # single-VF engines — the manager dispatches on truthiness.
        self.gang_shells: tuple = ()
        self.run = types.SimpleNamespace(
            model=types.SimpleNamespace(name=engine.run.model.name),
            placement=placement, seed=engine.run.seed)

    # -- lifecycle -----------------------------------------------------------
    def bind(self, vf: VirtualFunction, state=None, *,
             flash: bool = True) -> float:
        if state is not None:
            self.engine.import_state(state)
        key = (tuple(vf.mesh_shape), tuple(str(d) for d in vf.devices))
        self._exec_cache.setdefault(key, True)
        self.vf_id = vf.vf_id
        self.status = "running"
        self.engine.unpause()
        vf.emulated.update({"tenant": self.tid, "status": "running",
                            "steps_done": self.steps_done})
        return 0.0

    def run_steps(self, n: int = 1) -> dict:
        if self.status == "paused":
            raise DevicePausedError(
                f"{self.tid}: device {self.vf_id} is paused")
        if self.status != "running":
            raise RuntimeError(f"{self.tid}: no device attached")
        active = 0
        for _ in range(n):
            active = self.engine.step()
            self.steps_done += 1
        return {"active": active, "queued": len(self.engine.queue)}

    # -- pause protocol ------------------------------------------------------
    def export_state(self):
        # a never-stepped engine must still export a structurally complete
        # state: the detach path round-trips it through CheckpointStore
        # against state_template(), which includes the cache leaves
        self.engine._ensure_cache()
        st = self.engine.export_state()
        if self._template is None and st.get("cache") is not None:
            self._template = jax.tree.map(
                lambda x: np.zeros(getattr(x, "shape", ()),
                                   dtype=getattr(x, "dtype", np.float32)),
                st)
        return st

    def export_specs(self):
        return {}

    def shardings_for(self, vf: VirtualFunction):
        return None

    def state_template(self):
        if self._template is None:
            self.export_state()
        if self._template is None:
            raise RuntimeError(
                f"{self.tid}: no exported state to derive a restore "
                "template from")
        return self._template

    def dirty_keys(self):
        return self.engine.dirty_keys()

    def suspend(self):
        self.engine.pause()
        # in-flight chunked prefills re-queue (they have emitted nothing
        # and are deterministic), so the exported snapshot really is the
        # engine's complete device state
        self.engine.abort_prefill_jobs()
        self.engine._cache = None      # device refs dropped; snapshot holds
        self.status = "paused"

    def resume(self, state, vf: VirtualFunction):
        self.status = "running"
        self.bind(vf, state=state)

    def detach(self):
        self.engine.pause()
        self.engine.abort_prefill_jobs()
        self.engine._cache = None
        self.vf_id = None
        self.status = "detached"

    # -- request live migration (delegated to the engine) --------------------
    # the manager's migrate_request op speaks this protocol on the
    # TENANT, so the adapter forwards it 1:1 — EngineTenant and
    # SimServeTenant stay interchangeable under SVFFManager
    def peek_migratable(self, rid: Optional[int] = None):
        return self.engine.peek_migratable(rid)

    def extract_request(self, rid: Optional[int] = None) -> dict:
        return self.engine.extract_request(rid)

    def admit_migrated(self, payload: dict, state) -> int:
        return self.engine.admit_migrated(payload, state)

    def release_request(self, rid: int) -> None:
        self.engine.release_request(rid)

    def abort_migration(self, rid: int) -> None:
        self.engine.abort_migration(rid)

    def abort_incoming(self, rid: int) -> None:
        self.engine.abort_incoming(rid)

    def owns_request(self, rid: int) -> bool:
        return self.engine.owns_request(rid)

    def reset_after_crash(self) -> None:
        self.engine.reset_after_crash()

    # -- pipeline gang protocol (manager gang ops + I14) ---------------------
    @property
    def stage_width(self) -> int:
        return getattr(self.engine, "stage_width", 1)

    @property
    def num_periods(self) -> int:
        return self.engine.num_periods

    def has_template(self, k: int) -> bool:
        return self.engine.has_template(k)

    def apply_reshape(self, k: int) -> None:
        self.engine.apply_reshape(k)

    def stage_bounds(self) -> tuple:
        return self.engine.stage_bounds()

    # -- introspection -------------------------------------------------------
    @property
    def load(self) -> int:
        """Requests this engine is responsible for right now."""
        eng = self.engine
        return (len(eng.queue) + len(eng._jobs)
                + sum(r is not None for r in eng.active))

    def query(self) -> dict:
        return {"tenant": self.tid, "status": self.status,
                "vf": self.vf_id, "steps_done": self.steps_done,
                "workload": self.workload, "load": self.load,
                "exec_keys": [list(map(str, k)) for k in self._exec_cache]}

    def inject_failure(self):
        pass


class StageShellTenant:
    """One pipeline stage's VF occupant. The LEAD's engine owns ALL
    compute and state (params, KV pages, requests) — the shell exists so
    invariant I1 (one tenant per attached VF) and every journaled manager
    op see the gang's K VFs as K first-class tenants: a shell attaches,
    detaches, pauses and recovers exactly like any tenant, it just has
    (almost) no state of its own."""

    def __init__(self, tid: str, lead: EngineTenant, stage_index: int, *,
                 placement: str = "first_fit"):
        self.tid = tid
        self.lead = lead
        self.stage_index = stage_index
        self.status = "created"        # created|running|paused|detached
        self.vf_id: Optional[str] = None
        self.steps_done = 0
        self.workload = "serve"
        self._exec_cache: dict = {}    # pause snapshots its keys
        self.run = types.SimpleNamespace(
            model=lead.run.model, placement=placement, seed=lead.run.seed)

    # -- lifecycle (the duck-typed tenant protocol, trivially) ---------------
    def bind(self, vf: VirtualFunction, state=None, *,
             flash: bool = True) -> float:
        self.vf_id = vf.vf_id
        self.status = "running"
        vf.emulated.update({"tenant": self.tid, "status": "running",
                            "steps_done": self.steps_done})
        return 0.0

    def export_state(self):
        return {"stage": np.asarray(self.stage_index, np.int32)}

    def state_template(self):
        return {"stage": np.zeros((), np.int32)}

    def export_specs(self):
        return {}

    def shardings_for(self, vf: VirtualFunction):
        return None

    def dirty_keys(self):
        return set()

    def suspend(self):
        self.status = "paused"

    def resume(self, state, vf: VirtualFunction):
        self.bind(vf, state=state)

    def detach(self):
        self.vf_id = None
        self.status = "detached"

    def query(self) -> dict:
        return {"tenant": self.tid, "status": self.status,
                "vf": self.vf_id, "lead": self.lead.tid,
                "stage_index": self.stage_index,
                "workload": self.workload}

    def inject_failure(self):
        pass


class ServeFleet:
    """Run ``num_engines`` ServeEngines as SVFF tenants over one pool."""

    def __init__(self, run, params, *, num_engines: int = 2,
                 num_devices: int = 8, policy: str = "first_fit",
                 slots: int = 4, max_len: int = 256, paged: bool = True,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: int = 0, share_prefix: bool = False,
                 kv_dtype: Optional[str] = None,
                 fused_sampling: bool = False,
                 slo_max_load: int = 64,
                 workdir: str = "/tmp/svff_fleet", devices=None,
                 autoscale: Optional[AutoscaleConfig] = None,
                 spare_engines: int = 0, num_vfs: Optional[int] = None,
                 stages: int = 1, max_stages: Optional[int] = None,
                 microbatches: int = 2, host_id: str = "host0"):
        self.run = run
        #: this fleet's identity when it is one member of a federation
        #: (``core.federation``); a standalone fleet keeps the default
        self.host_id = host_id
        self.slo_max_load = slo_max_load
        # stages > 1: every engine is a PipelineServeEngine spanning
        # ``stages`` VFs (a gang of 1 lead + stages-1 shell tenants);
        # ``max_stages`` bounds the reshape headroom (templates are
        # precomputed up to it at engine construction)
        self.stages = max(1, int(stages))
        self.max_stages = max_stages
        self.microbatches = microbatches
        devices = (tuple(devices) if devices is not None else
                   tuple(f"fleetdev{i}" for i in range(num_devices)))
        # the VF cap is the DEVICE budget (>= 1 device per VF), not the
        # initial engine count — capping at num_engines made every later
        # reconfiguration to more VFs silently impossible
        self.pool = DevicePool(devices=devices,
                               max_vfs=max(len(devices), 1))
        self.mgr = SVFFManager(self.pool, workdir=workdir, scheduler=policy)
        self.tenants: dict[str, EngineTenant] = {}
        self._order: dict[str, int] = {}        # tid -> creation index
        self._policy = policy
        self._params_src = params
        self._engine_kw = dict(slots=slots, max_len=max_len, paged=paged,
                               page_size=page_size, num_pages=num_pages,
                               prefill_chunk=prefill_chunk,
                               share_prefix=share_prefix,
                               kv_dtype=kv_dtype,
                               fused_sampling=fused_sampling)
        # pre-carving MORE VFs than engines (``num_vfs``) gives scale-out
        # a pause-free path: attaching to an existing detached VF never
        # interrupts the running engines, whereas growing the partition
        # runs the paper's full reconf cycle (brief pause of every
        # engine) — exactly the SR-IOV spare-VF provisioning pattern
        tns = [self._spawn_tenant() for _ in range(num_engines)]
        need = num_engines * self.stages      # every gang wants K VFs
        self.mgr.init(max(num_vfs or need, need), tns)
        # parked standbys: spawned (own params copy, own executables when
        # warmed) but not attached — the autoscaler's cheap scale-out pool
        for _ in range(spare_engines):
            self._spawn_tenant()
        self.telemetry = MetricsBus()
        self.autoscale_config = autoscale
        self.autoscaler = Autoscaler(autoscale) if autoscale else None
        self._epoch = 0
        self._harvested: dict[str, int] = {}   # tid -> _finished scanned
        #: fleet-side rejection ledger (the REQUEST is never mutated).
        #: One entry per rejected SUBMISSION — a caller retrying the same
        #: request K times logs K attempts — bounded so a long-lived
        #: fleet cannot leak; ``rejected_total`` is the running count
        self.rejections: collections.deque = collections.deque(maxlen=512)
        self.rejected_total = 0

    def _spawn_tenant(self) -> EngineTenant:
        """Create one engine tenant (own params copy: a pause deletes the
        exported leaves, so engines must not alias one pytree — guest
        isolation, like VMs not sharing guest RAM)."""
        i = len(self._order)
        params = jax.tree.map(jax.numpy.array, self._params_src)
        if self.stages > 1:
            from repro.serve.pipeline_engine import PipelineServeEngine
            eng = PipelineServeEngine(self.run, params,
                                      stages=self.stages,
                                      max_stages=self.max_stages,
                                      microbatches=self.microbatches,
                                      **self._engine_kw)
        else:
            eng = ServeEngine(self.run, params, **self._engine_kw)
        tn = EngineTenant(f"serve{i}", eng, placement=self._policy)
        if self.stages > 1:
            # shells up to the TEMPLATE ceiling, not the initial width:
            # a grow-reshape needs idle shells ready to attach
            # "." separator: tids become RecordStore file names, so no "/"
            tn.gang_shells = tuple(
                StageShellTenant(f"{tn.tid}.s{j}", tn, j,
                                 placement=self._policy)
                for j in range(1, eng.max_stage_width))
        self.tenants[tn.tid] = tn
        self._order[tn.tid] = i
        return tn

    # -- traffic --------------------------------------------------------------
    def submit(self, req: Request) -> str:
        """SLO-aware admission: the request goes to the least-loaded
        attached engine; if even that one is past ``slo_max_load``, the
        request is rejected NOW (typed) rather than queued into an SLO
        miss. Rejection is side-effect-free on the request — the caller
        may retry the SAME object after backoff — and is tracked fleet-
        side (``self.rejections`` + telemetry). Paused engines still
        accept traffic (their queue holds) but running ones are
        preferred. Load ties break on engine CREATION index, not tid
        string order, so a 12-engine fleet fills serve0..serve11 in
        order instead of serve0, serve1, serve10, serve11, serve2, ..."""
        cands = [tn for tn in self.tenants.values()
                 if tn.status in ("running", "paused")]
        if not cands:
            self.rejected_total += 1
            self.rejections.append({"rid": req.rid, "engine": None,
                                    "reason": "no serving engines"})
            raise RequestRejected(f"request {req.rid}: no serving engines")
        running = [tn for tn in cands if tn.status == "running"]
        pick = min(running or cands,
                   key=lambda tn: (tn.load, self._order[tn.tid]))
        if pick.load >= self.slo_max_load:
            self.telemetry.record_reject(pick.tid)
            self.rejected_total += 1
            self.rejections.append({"rid": req.rid, "engine": pick.tid,
                                    "load": pick.load,
                                    "reason": "slo_max_load"})
            raise RequestRejected(
                f"SLO admission: engine {pick.tid} at load {pick.load} "
                f">= {self.slo_max_load} (request {req.rid})")
        pick.engine.submit(req)
        self.telemetry.record_submit(pick.tid)
        return pick.tid

    def step(self) -> int:
        """One fleet iteration: every RUNNING engine advances one step.
        Paused engines hold their queues (the guest keeps its device)."""
        active = 0
        for tn in self.tenants.values():
            if tn.status == "running":
                active += tn.run_steps(1)["active"]
                self.telemetry.record_load(tn.tid, tn.load,
                                           len(tn.engine.queue))
                self.telemetry.record_cache_pressure(
                    tn.tid, tn.engine.stats["cache_exhausted"],
                    tn.engine.stats["defrag_events"])
                self.telemetry.record_migration_stall(
                    tn.tid, tn.engine.stats["migration_stall_ticks"])
                if getattr(tn.engine, "stage_width", 1) > 1:
                    self.telemetry.record_stage_load(
                        tn.tid, tn.engine.stage_loads(),
                        tn.engine.measured_bubble)
                # harvest only the suffix of _finished not yet scanned —
                # the list is cleared by drain, and rescanning it whole
                # would make the hot path O(completed history)
                done = len(tn.engine._finished)
                seen = self._harvested.get(tn.tid, 0)
                if done < seen:
                    # someone drained the engine directly: rescan from
                    # the start (MetricsBus.harvest dedups by request)
                    seen = 0
                if done > seen:
                    self.telemetry.harvest(tn.tid,
                                           tn.engine._finished[seen:])
                self._harvested[tn.tid] = done
        return active

    def drain(self, max_steps: int = 10_000) -> "DrainResult":
        """Serve until every RUNNING engine is idle; returns the finished
        requests. ``.drained`` is False when work is stranded — on a
        still-paused engine, or because max_steps ran out — mirroring
        ``ServeEngine.run_until_idle``."""
        from repro.serve.engine import DrainResult
        done: list[Request] = []
        for _ in range(max_steps):
            if self.step() == 0 and not any(
                    tn.engine.queue or tn.engine._jobs
                    for tn in self.tenants.values()
                    if tn.status == "running"):
                break
        pending = False
        for tn in self.tenants.values():
            res = tn.engine.run_until_idle(max_steps=0)
            self.telemetry.harvest(tn.tid, res)
            self.telemetry.drained(tn.tid)
            self._harvested[tn.tid] = 0        # _finished was emptied
            done.extend(res)
            pending |= not res.drained
        return DrainResult(done, drained=not pending)

    # -- reconfiguration under traffic ----------------------------------------
    def pause_live(self, tid: str, *, rounds: int = 2):
        """Live pause of one engine while it KEEPS SERVING its batch: the
        pre-copy rounds step the victim engine (and the rest of the fleet
        rides along untouched)."""
        tn = self.tenants[tid]
        return self.mgr.pause_live(
            tn, rounds=rounds, step_fn=lambda: tn.run_steps(1))

    def unpause(self, tid: str):
        return self.mgr.unpause(self.tenants[tid])

    def migrate(self, tid: str):
        return self.mgr.migrate(self.tenants[tid])

    def migrate_request(self, src: str, dst: str,
                        rid: Optional[int] = None, *,
                        retries: int = 2) -> Optional[dict]:
        """Live-migrate one in-flight request ``src -> dst`` through the
        journaled manager op. A target-side ``CacheExhausted`` aborts the
        attempt CLEANLY — journal rolled back, the request untouched and
        still decoding on the source — and the target defragments before
        the bounded retry. Returns the manager's result dict (rid /
        blocks shipped / timing), or None when every attempt aborted."""
        s, d = self.tenants[src], self.tenants[dst]
        for attempt in range(1 + retries):
            try:
                res = self.mgr.migrate_request(s, d, rid)
            except CacheExhausted:
                self.telemetry.record_migration(src, dst, completed=False)
                if attempt < retries:
                    d.engine.defragment()     # compact, then retry
                continue
            self.telemetry.record_migration(src, dst, completed=True,
                                            blocks=res["blocks"])
            return res
        return None

    # -- the elastic control plane --------------------------------------------
    def _free_vfs(self) -> list:
        """Attachable VFs: detached, unowned, still holding devices. One
        predicate for BOTH the snapshot the planner reads and the VF
        scale_out picks, so plan and execution criteria cannot drift."""
        return [vf for vf in self.pool.vfs.values()
                if vf.state == VFState.DETACHED and vf.owner is None
                and vf.devices]

    def telemetry_snapshot(self) -> TelemetrySnapshot:
        """One observation epoch: per-engine stats + the capacity facts
        that gate scale-out. Cheap (counters + window percentiles)."""
        self._epoch += 1
        stats = []
        for tid, tn in self.tenants.items():
            eng = tn.engine
            paged = getattr(eng, "paged", False)
            stats.append(EngineStats(
                tid=tid, index=self._order[tid], status=tn.status,
                load=tn.load, queue_depth=len(eng.queue),
                inflight=sum(r is not None for r in eng.active),
                prefill_jobs=len(eng._jobs),
                ttft_p95_ms=self.telemetry.ttft_ms(tid),
                itl_p95_ms=self.telemetry.itl_ms(tid),
                rejected=self.telemetry.rejected[tid],
                cache_exhausted=eng.stats["cache_exhausted"],
                defrag_events=eng.stats["defrag_events"],
                pages_in_use=eng.alloc.pages_in_use if paged else 0,
                pages_free=eng.alloc.num_free if paged else 0,
                migrations_attempted=(
                    self.telemetry.migrations_attempted[tid]),
                migrations_completed=(
                    self.telemetry.migrations_completed[tid]),
                migrations_aborted=self.telemetry.migrations_aborted[tid],
                migration_blocks_shipped=self.telemetry.migration_blocks[tid],
                migration_stall_ticks=(
                    eng.stats["migration_stall_ticks"]),
                stage_width=getattr(eng, "stage_width", 1),
                stage_width_max=getattr(eng, "max_stage_width", 1),
                stage_loads=(tuple(eng.stage_loads())
                             if hasattr(eng, "stage_loads") else ()),
                bubble_frac=getattr(eng, "measured_bubble", 0.0)))
        return TelemetrySnapshot(
            epoch=self._epoch, slo_max_load=self.slo_max_load,
            engines=tuple(stats), free_vfs=len(self._free_vfs()),
            grow_budget=max(0, self.pool.num_devices - len(self.pool.vfs)),
            rejected_recent=self.telemetry.take_rejected_recent())

    def federation_snapshot(self, now: float = 0.0) -> dict:
        """This fleet as ONE host of a federation: the stamped replicated-
        telemetry payload ``core.federation.FederationCoordinator`` keeps
        per host (same shape as ``core.host.Host.snapshot``), built from
        the serve-plane ``MetricsBus`` replica. ``now`` is the caller-
        injected clock reading — wall time never leaks in."""
        engines = {tid: {"load": tn.load,
                         "slots": len(tn.engine.active)}
                   for tid, tn in sorted(self.tenants.items())
                   if tn.status == "running"}
        return {"host_id": self.host_id, "stamp": float(now),
                "load": sum(e["load"] for e in engines.values()),
                "capacity": self.slo_max_load * len(engines),
                "max_load": self.slo_max_load,
                "free_vfs": len(self._free_vfs()),
                "engines": engines,
                "telemetry": self.telemetry.replicate(now)}

    def autoscale_step(self) -> Optional[AutoscaleAction]:
        """One policy-loop epoch: snapshot -> plan -> execute. Returns the
        executed action (None on a quiet/cooldown epoch). Every executed
        action flows through journaled manager ops, so a crash mid-action
        recovers exactly like a crash mid-reconf (I8/I9)."""
        if self.autoscaler is None:
            raise ValueError(
                "fleet built without autoscale=AutoscaleConfig(...)")
        action = self.autoscaler.observe(self.telemetry_snapshot())
        if action is None:
            return None
        if action.kind == "scale_out":
            self.scale_out()
        elif action.kind == "scale_in":
            self.scale_in(action.victim)
        elif action.kind == "reshape":
            self.reshape_engine(action.victim, action.width)
        else:
            self.rebalance(action.victim, action.target)
        return action

    def scale_out(self) -> str:
        """Bring one more engine into service: re-attach the oldest parked
        tenant (or spawn a fresh one) onto a free VF; when no detached VF
        exists, run the paper's full reconf cycle to carve one more
        (running engines pause briefly — their queues hold — and resume
        on the new partition)."""
        free = self._free_vfs()
        # gang-aware device budget: a K-stage engine consumes K VFs, so
        # "is there room" must count the VFs a whole gang needs, not 1 —
        # the old `len(vfs) + 1` let a K>1 scale-out past the clamp and
        # fail halfway through carving
        need = self.stages
        missing = max(0, need - len(free))
        n = len(self.pool.vfs) + missing
        if missing and n > self.pool.num_devices:
            # validate BEFORE spawning: a fresh tenant registered here
            # would leak (params copy + a never-attachable fleet entry)
            raise ManagerError(
                f"scale_out: {n} VFs exceed the device budget "
                f"({self.pool.num_devices})")
        parked = sorted((tn for tn in self.tenants.values()
                         if tn.status in ("created", "detached")),
                        key=lambda tn: self._order[tn.tid])
        tn = parked[0] if parked else self._spawn_tenant()
        if not missing:
            if tn.gang_shells:
                self.mgr.attach_group(tn)
            else:
                self.mgr.attach(tn)
        else:
            self.mgr.reconf(n, new_tenants=[tn],
                            devices_per_vf=max(
                                1, self.pool.num_devices // n))
        # the new engine takes queued (not-yet-admitted) work off the
        # hottest engine immediately — otherwise it idles until the next
        # rebalance epoch while the hot queue keeps missing SLO
        hot = max((t for t in self.tenants.values()
                   if t.status == "running" and t.tid != tn.tid),
                  key=lambda t: (t.load, -self._order[t.tid]),
                  default=None)
        if hot is not None and hot.engine.queue:
            self.rebalance(hot.tid, tn.tid, migrate=False)
        return tn.tid

    def scale_in(self, tid: str) -> str:
        """Park an engine: journaled detach (state snapshots to disk,
        the VF keeps its devices and becomes attachable). A BUSY engine
        drains first — in-flight chunked prefills abort back to its
        queue (they have emitted nothing), queued requests resubmit to
        running siblings under the SLO admission bound, and active
        decode slots LIVE-MIGRATE (journaled KV hand-off, token streams
        unchanged). Typed refusal when no sibling has the capacity —
        every request the drain already moved stays live on its new
        engine, nothing strands."""
        tn = self.tenants[tid]
        if tn.status != "running":
            raise ManagerError(f"scale_in: {tid} is {tn.status}")
        if tn.load:      # load = queued + in-flight prefill + active slots
            self._drain_for_scale_in(tn)
        self.mgr.detach(tn)
        return tid

    def _drain_for_scale_in(self, tn: EngineTenant) -> None:
        sibs = [t for t in self.tenants.values()
                if t.status == "running" and t.tid != tn.tid]
        if not sibs:
            raise ManagerError(
                f"scale_in: {tn.tid} is busy (load {tn.load}) and has "
                "no running sibling to drain to")

        def best():
            return min(sibs, key=lambda t: (t.load, self._order[t.tid]))
        # chunked prefills re-queue deterministically (nothing emitted)
        tn.engine.abort_prefill_jobs()
        while tn.engine.queue:
            pick = best()
            if pick.load >= self.slo_max_load:
                raise ManagerError(
                    f"scale_in: no sibling admission capacity for "
                    f"{tn.tid}'s queued requests (best {pick.tid}@"
                    f"{pick.load} >= {self.slo_max_load})")
            pick.engine.submit(tn.engine.queue.pop())
            self.telemetry.record_submit(pick.tid)
        # active decode slots: journaled live migration with bounded
        # per-sibling retries (migrate_request defragments in between)
        while (rid := tn.peek_migratable()) is not None:
            for t in sorted(sibs,
                            key=lambda t: (t.load, self._order[t.tid])):
                if (t.load < self.slo_max_load and
                        self.migrate_request(tn.tid, t.tid,
                                             rid) is not None):
                    break
            else:
                raise ManagerError(
                    f"scale_in: no sibling has KV capacity for in-"
                    f"flight request {rid} on {tn.tid}")
        if tn.load:
            # dense engines (no paged KV) can't ship active slots
            raise ManagerError(
                f"scale_in: {tn.tid} still busy after drain "
                f"(load {tn.load}) — active work is not migratable")

    def rebalance(self, src: str, dst: str,
                  migrate: Optional[bool] = None) -> int:
        """Move queued (not-yet-admitted) requests from the hot engine to
        the cold one — they have emitted nothing, so replacement is
        token-identical — then migrate the hot victim onto fresh devices
        (pause -> reallocate -> unpause keeps its in-flight batch).
        Returns the number of requests moved."""
        s, d = self.tenants[src], self.tenants[dst]
        moved = 0
        while s.engine.queue and s.load - d.load > 1:
            # steal from the BACK: the oldest requests keep their engine
            d.engine.submit(s.engine.queue.pop())
            moved += 1
        # queue-stealing can't close the gap when the hot engine's load
        # is IN-FLIGHT: live-migrate idle decode slots hot -> cold
        # through the journaled op. An abort (target KV full even after
        # its defrag retries) ends the steal — the request stays live
        # and decoding on the source.
        while (s.status == "running" and d.status == "running"
               and s.load - d.load > 1
               and s.peek_migratable() is not None):
            if self.migrate_request(src, dst) is None:
                break
            moved += 1
        if migrate is None:
            migrate = (self.autoscale_config.rebalance_migrate
                       if self.autoscale_config else True)
        if migrate and s.status == "running":
            self.mgr.migrate(s)
        return moved

    def reshape_engine(self, tid: str, width: int) -> dict:
        """Re-instantiate a gang engine at ``width`` stages via the
        journaled manager reshape — in-flight token streams unchanged
        (I10), the gang matching exactly one registered template before
        and after (I14)."""
        tn = self.tenants[tid]
        if not tn.gang_shells:
            raise ManagerError(
                f"reshape_engine: {tid} is not a pipeline gang")
        return self.mgr.reshape(tn, width)

    def handle_vf_loss(self, tid: str, vf_id: str) -> dict:
        """A gang member's VF died (device failure): shed exactly that
        stage and re-instantiate the engine at K-1 through the same
        journaled reshape, so the fallback is crash-covered and the
        surviving K-1 stages keep every request byte. The lead's own VF
        dying is a full engine crash — that path is ``recover_engine``."""
        tn = self.tenants[tid]
        shell = next((s for s in tn.gang_shells if s.vf_id == vf_id), None)
        if shell is None:
            raise ManagerError(
                f"handle_vf_loss: {vf_id} backs no active stage of {tid}")
        return self.mgr.reshape(tn, tn.stage_width - 1, drop=shell.tid)

    def recover_engine(self, tid: str) -> dict:
        """An engine CRASHED mid-serving (its device state is gone):
        re-home every live request onto running siblings by
        deterministic recompute — emitted tokens are cleared and
        regenerate bit-identically from the prompt (the counter-seeded
        sampler keys on (seed, rid, position), not on engine identity)
        — then reset the victim to a clean, re-servable state. Typed
        refusal BEFORE any mutation when the siblings lack admission
        capacity, so the caller can scale out first and retry."""
        tn = self.tenants[tid]
        eng = tn.engine
        live = [r for r in ([j.req for j in eng._jobs.values()]
                            + list(eng.queue)
                            + [r for r in eng.active if r is not None])
                if not r.done]
        sibs = [t for t in self.tenants.values()
                if t.status == "running" and t.tid != tid]
        if live and not sibs:
            raise ManagerError(
                f"recover_engine: {tid} holds {len(live)} live requests "
                "and no sibling is running")
        headroom = sum(max(0, self.slo_max_load - t.load) for t in sibs)
        if len(live) > headroom:
            raise ManagerError(
                f"recover_engine: siblings have admission headroom for "
                f"{headroom} requests, {tid} holds {len(live)}")
        eng.reset_after_crash()
        self._harvested[tid] = 0
        rehomed = []
        for req in live:
            req.out.clear()
            req.t_tok.clear()
            pick = min(sibs, key=lambda t: (t.load, self._order[t.tid]))
            pick.engine.submit(req)
            self.telemetry.record_submit(pick.tid)
            rehomed.append((req.rid, pick.tid))
        return {"tid": tid, "rehomed": rehomed}

    def query(self) -> dict:
        return {"manager": self.mgr.query(),
                "engines": {tid: tn.query()
                            for tid, tn in self.tenants.items()},
                "telemetry": self.telemetry.describe(),
                "rejections": self.rejected_total,
                "autoscale_actions": (len(self.autoscaler.history)
                                      if self.autoscaler else 0)}
