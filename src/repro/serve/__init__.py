"""Serving substrate: continuous-batching engine."""
