"""Serving substrate: continuous-batching engine (dense or paged KV),
block allocator, the multi-tenant fleet under the SVFF manager, and the
telemetry bus feeding the elastic autoscaler (``core.autoscaler``)."""
from repro.serve.engine import DrainResult, Request, ServeEngine
from repro.serve.fleet import EngineTenant, ServeFleet
from repro.serve.paged import (BlockAllocator, CacheExhausted,
                               DoubleFreeError, RequestRejected,
                               UnknownRequestError)
from repro.serve.pipeline_engine import PipelineServeEngine
from repro.serve.stages import StageTemplate, build_templates
from repro.serve.telemetry import MetricsBus, percentile

__all__ = ["BlockAllocator", "CacheExhausted", "DoubleFreeError",
           "DrainResult", "EngineTenant", "MetricsBus",
           "PipelineServeEngine", "Request", "RequestRejected",
           "ServeEngine", "ServeFleet", "StageTemplate",
           "UnknownRequestError", "build_templates", "percentile"]
