"""Serving substrate: continuous-batching engine (dense or paged KV),
block allocator, and the multi-tenant fleet under the SVFF manager."""
from repro.serve.engine import DrainResult, Request, ServeEngine
from repro.serve.fleet import EngineTenant, ServeFleet
from repro.serve.paged import (BlockAllocator, CacheExhausted,
                               RequestRejected)

__all__ = ["BlockAllocator", "CacheExhausted", "DrainResult",
           "EngineTenant", "Request", "RequestRejected", "ServeEngine",
           "ServeFleet"]
