"""Paged KV cache: block-granular allocation for the serve plane.

The dense per-slot ring allocates ``slots x max_len`` KV rows up front and
decodes against the whole allocation; the paged cache carves the same
physical storage into fixed-size *pages* handed out on demand — the exact
shape of the ``DevicePool`` one layer down (a pool of indivisible resource
units, an owner table, allocate/free/defragment), applied to KV rows
instead of accelerator devices:

  BlockAllocator     the PF analogue: owns the page pool, tracks per-request
                     ownership and per-page refcounts, compacts on
                     ``defragment``
  page 0             reserved garbage page — never allocated; inactive batch
                     slots' masked writes are redirected there, which is how
                     an idle slot's pages stay bit-untouched
  copy-on-admit      a request is prefilled into a private dense staging
                     cache (B=1) and its KV is *copied* into its allocated
                     pages on admission (``admit_kv``), so admission never
                     aliases the running batch's storage
  prefix sharing     requests whose token prefixes match map their block
                     tables onto the SAME physical pages (a prefix trie
                     keyed by token-prefix chains; see below), multiplying
                     effective pool capacity for shared system prompts
  copy-on-write      a decode write landing in a page with refcount > 1
                     splits exactly that page (``cow`` + ``copy_page``):
                     the writer gets a private copy and repoints only its
                     own table row; every other sharer is untouched

Sharing is read-free because ``kernels/paged_decode`` masks reads with
``kpos <= pos``: rows a sharer has not logically reached (another
request's longer prompt tail, or its decode tokens parked in a shared
partial page) are never read, so a page may be shared as long as the rows
BELOW each sharer's position are bit-identical — which the token-prefix
keys guarantee.

The attention-side consumer is ``kernels/paged_decode`` (block-table
indirection, cost proportional to pages actually written).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


class RequestRejected(RuntimeError):
    """Typed admission rejection: the request can NEVER be served by this
    engine (over-long prompt, more pages than the pool holds). The engine
    marks the request done-with-error and keeps serving the batch — one
    bad request must not kill the engine (this replaces a bare ``assert``
    that vanished under ``python -O``)."""


class CacheExhausted(RuntimeError):
    """Transient allocation failure: not enough free pages *right now*.
    Admission backs off (the request stays queued) rather than failing."""


# DoubleFreeError / UnknownRequestError now live in the canonical typed
# error hierarchy (repro.core.errors) so callers can catch them via
# ``from repro.core import ...``; re-exported here because this was their
# historic home (PRs 4-8 call sites / docs name repro.serve.paged).
from repro.core.errors import DoubleFreeError, UnknownRequestError  # noqa: E402,F401


def _is_kv(path) -> bool:
    """Attention-cache leaves that need no slot reset (self-attn KV is
    masked by pos; cross xk/xv only ever appear in DENSE caches — the
    paged layout gates out encoder-decoder stacks entirely)."""
    name = path[-1].key if hasattr(path[-1], "key") else ""
    return name in ("k", "v", "xk", "xv")


def _is_kv_scale(path) -> bool:
    """Per-page quantization-scale siblings of int8 KV pools
    ((nper, P, page, K) fp32 next to (nper, P, page, K, hd) int8) — they
    move with their pages (CoW copies, defragment gathers) but are
    neither scattered from the fp request cache nor slot-reset."""
    name = path[-1].key if hasattr(path[-1], "key") else ""
    return name in ("k_scale", "v_scale", "xk_scale", "xv_scale")


class BlockAllocator:
    """Fixed-size page pool with per-request ownership, per-page
    refcounts, and a prefix trie for copy-on-write page sharing.

    Page ids run [0, num_pages); page 0 is reserved (garbage page), so the
    allocatable capacity is ``num_pages - 1``. Free pages are handed out
    lowest-id first, which keeps block tables deterministic (the serving
    analogue of the scheduler's 'ties break in PF table order').

    Sharing model. A page's KV rows are a function of the ENTIRE token
    prefix up to and including the page's own tokens, so the trie keys
    are token-prefix tuples, not per-page token windows:

      full pages     ``tokens[: page_size * (i+1)]`` -> page of chain
                     index i (registered once, first placement wins)
      partial page   a prompt's last, partly-filled page, keyed under its
                     full-page prefix by the leftover token tuple. A later
                     request may share it only when its own leftover
                     tokens are an exact PREFIX of the registered entry's
                     — its rows are then already present at the right
                     offsets, and any longer registered tail (or the
                     registrant's decode rows parked above it) sits past
                     the sharer's position, masked by the decode kernel

    Registration happens at PLACE time (``register_prefix``), after the
    page bytes are actually written — pages reserved by an in-flight
    chunked prefill are never offered for sharing. Trie entries live
    exactly as long as the page has owners: the last ``free`` decref
    unregisters. Every owner of a page counts one refcount; a decode
    write into a page with refcount > 1 must go through ``cow`` first."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._free = list(range(1, num_pages))     # ascending
        self._owned: dict[int, list[int]] = {}     # rid -> page chain
        self._ref: dict[int, int] = {}             # page -> owner count
        self._shared: dict[int, int] = {}          # rid -> shared chain head
        self._tokens: dict[int, tuple] = {}        # rid -> prompt tokens
        # the trie: full-prefix keys -> page; partial entries grouped
        # under their full-page prefix; _site is the reverse map (one
        # registration per page) used by unregistration and defragment
        self._full: dict[tuple, int] = {}
        self._partial: dict[tuple, list] = {}      # key -> [(rest, page)]
        self._site: dict[int, tuple] = {}

    # -- capacity ------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        return self.num_pages - 1

    @property
    def pages_in_use(self) -> int:
        """Unique physical pages currently owned (the sharing win shows
        up here: N requests on one system prompt count its pages once)."""
        return self.capacity - len(self._free)

    def pages_needed(self, tokens: int) -> int:
        return max(1, math.ceil(tokens / self.page_size))

    # -- the prefix trie -----------------------------------------------------
    def _lookup(self, tokens: tuple, n: int) -> list[int]:
        """Longest registered chain prefix for ``tokens``, at most ``n``
        pages: consecutive full-page hits from the root, then (only when
        every full page hit) at most one partial-page hit."""
        P = self.page_size
        shared: list[int] = []
        nfull = len(tokens) // P
        for i in range(min(nfull, n)):
            page = self._full.get(tokens[:P * (i + 1)])
            if page is None:
                return shared
            shared.append(page)
        rest = tokens[P * nfull:]
        if rest and len(shared) == nfull < n:
            for reg_rest, page in self._partial.get(tokens[:P * nfull], ()):
                if rest == reg_rest[:len(rest)]:
                    shared.append(page)
                    break
        return shared

    def register_prefix(self, rid: int) -> int:
        """Offer rid's PROMPT pages (the tokens recorded at allocate) for
        sharing. Idempotent and conflict-safe: a page registers at most
        once, a key keeps its first page. Returns entries added."""
        tokens = self._tokens.get(rid)
        chain = self._owned.get(rid)
        if not tokens or not chain:
            return 0
        P = self.page_size
        added = 0
        nfull = len(tokens) // P
        for i in range(min(nfull, len(chain))):
            key = tokens[:P * (i + 1)]
            page = chain[i]
            if key not in self._full and page not in self._site:
                self._full[key] = page
                self._site[page] = ("full", key)
                added += 1
        rest = tokens[P * nfull:]
        if rest and nfull < len(chain):
            page = chain[nfull]
            key = tokens[:P * nfull]
            node = self._partial.setdefault(key, [])
            if page not in self._site and rest not in [r for r, _ in node]:
                node.append((rest, page))
                self._site[page] = ("partial", key, rest)
                added += 1
        return added

    def _unregister(self, page: int):
        site = self._site.pop(page, None)
        if site is None:
            return
        if site[0] == "full":
            del self._full[site[1]]
        else:
            node = self._partial[site[1]]
            node.remove((site[2], page))
            if not node:
                del self._partial[site[1]]

    # -- allocate / free -----------------------------------------------------
    def allocate(self, rid: int, n: int,
                 tokens: Optional[tuple] = None) -> list[int]:
        """Hand ``rid`` a chain of ``n`` pages. With ``tokens`` (the
        prompt the pages will hold), the chain head reuses registered
        shared pages — only the remainder consumes free pages. The
        exhaustion check runs BEFORE any refcount moves, so a failed
        allocation is side-effect-free."""
        if rid in self._owned:
            raise ValueError(f"request {rid} already holds pages")
        if n > self.capacity:
            raise RequestRejected(
                f"request {rid} needs {n} pages; pool capacity is "
                f"{self.capacity} (page_size={self.page_size})")
        shared = self._lookup(tuple(tokens), n) if tokens else []
        fresh = n - len(shared)
        if fresh > len(self._free):
            raise CacheExhausted(
                f"request {rid} needs {fresh} fresh pages "
                f"({len(shared)} shared), only {len(self._free)} free")
        got, self._free = self._free[:fresh], self._free[fresh:]
        for p in shared:
            self._ref[p] += 1
        for p in got:
            self._ref[p] = 1
        self._owned[rid] = shared + got
        self._shared[rid] = len(shared)
        if tokens is not None:
            self._tokens[rid] = tuple(tokens)
        return list(self._owned[rid])

    def extend(self, rid: int, n: int = 1) -> list[int]:
        """Lazy decode growth: append ``n`` fresh (private) pages to
        rid's chain. Decode-grown pages are never offered for sharing.
        Unknown rid is an ``UnknownRequestError`` — see the class."""
        if rid not in self._owned:
            raise UnknownRequestError(
                f"extend of request {rid}, which holds no pages")
        if n > len(self._free):
            raise CacheExhausted(
                f"request {rid} needs {n} more pages, only "
                f"{len(self._free)} free")
        got, self._free = self._free[:n], self._free[n:]
        for p in got:
            self._ref[p] = 1
        self._owned[rid].extend(got)
        return list(got)

    def shared_count(self, rid: int) -> int:
        """Pages at the head of rid's chain that came from the trie at
        allocate time (the copy-on-admit scatter skips exactly these)."""
        return self._shared.get(rid, 0)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def cow(self, rid: int, idx: int) -> tuple[int, int]:
        """Copy-on-write split: replace the shared page at chain index
        ``idx`` with a fresh private one (caller device-copies the bytes
        via ``copy_page`` and repoints its own table row). Returns
        ``(old_page, new_page)``."""
        if rid not in self._owned:
            raise UnknownRequestError(
                f"cow of request {rid}, which holds no pages")
        chain = self._owned[rid]
        old = chain[idx]
        if self._ref[old] <= 1:
            raise ValueError(
                f"cow of unshared page {old} (rid {rid}, idx {idx})")
        if not self._free:
            raise CacheExhausted(
                f"request {rid} needs 1 page for a CoW split, none free")
        new = self._free.pop(0)
        self._ref[new] = 1
        chain[idx] = new
        self._ref[old] -= 1           # > 0 by the guard above
        return old, new

    def _decref(self, page: int):
        self._ref[page] -= 1
        if self._ref[page] == 0:
            del self._ref[page]
            self._unregister(page)
            self._free.append(page)
            self._free.sort()

    def free(self, rid: int) -> list[int]:
        """Release rid's references. Pages drop to the free list only
        when their LAST owner lets go (a finished request's shared
        system-prompt pages stay live for its siblings). Unknown rid is a
        ``DoubleFreeError`` — see the class docstring."""
        pages = self._owned.pop(rid, None)
        if pages is None:
            raise DoubleFreeError(
                f"free of request {rid}, which holds no pages "
                "(double free, or never allocated)")
        self._shared.pop(rid, None)
        self._tokens.pop(rid, None)
        for p in pages:
            self._decref(p)
        return pages

    def pages_of(self, rid: int) -> list[int]:
        return list(self._owned.get(rid, []))

    def tokens_of(self, rid: int) -> Optional[tuple]:
        """The prompt tokens recorded at allocate time (what migration
        ships so the target allocator can re-share trie pages)."""
        return self._tokens.get(rid)

    def owners(self) -> dict[int, list[int]]:
        return {rid: list(p) for rid, p in self._owned.items()}

    def check_invariants(self):
        """Mirror of DevicePool._check_invariants, refcount-aware: every
        page's refcount equals its live chain references, free+owned is
        an exact partition, and the trie/site maps agree and only name
        live pages."""
        refs: dict[int, int] = {}
        for rid, pages in self._owned.items():
            seen = set()
            for p in pages:
                assert 1 <= p < self.num_pages, (rid, p)
                assert p not in seen, (
                    f"page {p} twice in request {rid}'s chain")
                seen.add(p)
                refs[p] = refs.get(p, 0) + 1
        assert set(refs) == set(self._ref), (
            f"refcount key drift: owned {sorted(refs)} != "
            f"counted {sorted(self._ref)}")
        for p, want in refs.items():
            assert self._ref[p] == want, (
                f"refcount drift: page {p} counted {self._ref[p]}, "
                f"{want} live chain references")
        assert not (set(self._free) & set(refs))
        assert len(self._free) + len(refs) == self.capacity
        for rid, nsh in self._shared.items():
            assert 0 <= nsh <= len(self._owned.get(rid, ())), (rid, nsh)
        for page, site in self._site.items():
            assert page in refs, f"trie entry for freed page {page}"
            if site[0] == "full":
                assert self._full.get(site[1]) == page, site
            else:
                assert (site[2], page) in self._partial.get(site[1], ()), \
                    site
        for key, page in self._full.items():
            assert self._site.get(page) == ("full", key)
        for key, node in self._partial.items():
            for rest, page in node:
                assert self._site.get(page) == ("partial", key, rest)

    # -- defragment ----------------------------------------------------------
    def defragment(self) -> dict[int, int]:
        """Compact owned pages to the lowest ids (request order, then
        chain order, each UNIQUE page re-id'd once — a shared page moves
        once and every sharer's chain follows). Returns the {old: new}
        moves; the caller must apply the same mapping to the physical
        page arrays and any block tables (``apply_page_moves``)."""
        newid: dict[int, int] = {}
        nxt = 1
        for rid in sorted(self._owned):
            for p in self._owned[rid]:
                if p not in newid:
                    newid[p] = nxt
                    nxt += 1
        moves = {old: new for old, new in newid.items() if old != new}
        self._owned = {rid: [newid[p] for p in pages]
                       for rid, pages in self._owned.items()}
        self._ref = {newid[p]: c for p, c in self._ref.items()}
        self._full = {k: newid[p] for k, p in self._full.items()}
        self._partial = {k: [(r, newid[p]) for r, p in node]
                         for k, node in self._partial.items()}
        self._site = {newid[p]: s for p, s in self._site.items()}
        self._free = list(range(nxt, self.num_pages))
        self.check_invariants()
        return moves


def permutation_of(moves: dict[int, int], num_pages: int) -> np.ndarray:
    """(num_pages,) gather indices g with new_pages = pages[g]. Moves from
    ``defragment`` never swap into a still-live source (targets are always
    compacted below their sources), so a single gather applies them all."""
    g = np.arange(num_pages)
    for old, new in moves.items():
        g[new] = old
    return g


# ---------------------------------------------------------------------------
# the paged cache tree
# ---------------------------------------------------------------------------
def paged_cache_supported(cfg) -> tuple[bool, str]:
    if cfg.is_encoder_decoder:
        return False, "encoder-decoder cross-KV is not paged"
    if "attn" not in cfg.block_pattern:
        return False, "attention-free stack has no KV to page"
    return True, ""


def kv_quantize(x):
    """Symmetric int8 quantization of KV rows over the trailing hd axis:
    scale = max|x| / 127 per (..., head) row, q = round(x / scale). The
    max always lands on q = +-127, so dequant -> requant round-trips
    bit-exactly — migration may ship dequantized fp rows and the target
    re-admits them to the identical int8 bytes (I13 stays exact)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def kv_dequantize(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_paged_cache(model, shape, num_pages: int, page_size: int,
                     kv_dtype: Optional[str] = None) -> dict:
    """Build the serve cache tree: attention k/v leaves become shared page
    pools (nper, P, page, K, hd); every other leaf (recurrent state) stays
    dense per-slot (B, ...) exactly as ``init_cache`` makes it.

    ``kv_dtype='int8'`` stores the pools quantized (per-(row,head)
    symmetric scales in fp32 ``k_scale``/``v_scale`` siblings, shape
    (nper, P, page, K)) — page bytes drop ~2x (int8 payload + one fp32
    scale per hd-row vs fp32 payload), so resident requests per pool
    roughly double on top of the prefix-sharing multiplier."""
    ok, why = paged_cache_supported(model.cfg)
    if not ok:
        raise ValueError(f"paged KV unsupported for {model.cfg.name}: {why}")
    if kv_dtype not in (None, "int8"):
        raise ValueError(f"unsupported kv_dtype {kv_dtype!r} "
                         "(None or 'int8')")
    # the dense template only sizes non-KV leaves, so keep its seq dim tiny
    base = model.init_cache(dataclasses.replace(shape, seq_len=1))

    def one(path, leaf):
        if _is_kv(path):
            nper, _, _, K, hd = leaf.shape
            return jnp.zeros((nper, num_pages, page_size, K, hd),
                             leaf.dtype)
        return leaf
    tree = jax.tree_util.tree_map_with_path(one, base)
    if kv_dtype == "int8":
        tree = _quantize_tree(tree)
    return tree


def _quantize_tree(node):
    """Recursively convert fp KV page pools to int8 + scale siblings.
    The cache tree is plain nested dicts (see Model.cache_specs)."""
    if not isinstance(node, dict):
        return node
    out = {}
    for name, child in node.items():
        if isinstance(child, dict):
            out[name] = _quantize_tree(child)
        elif name in ("k", "v", "xk", "xv") and child.ndim == 5:
            nper, P, page, K, hd = child.shape
            out[name] = jnp.zeros((nper, P, page, K, hd), jnp.int8)
            out[name + "_scale"] = jnp.zeros((nper, P, page, K),
                                             jnp.float32)
        else:
            out[name] = child
    return out


def _path_key(path) -> tuple:
    return tuple(getattr(p, "key", getattr(p, "idx", None)) for p in path)


def admit_kv(cache: dict, req_cache: dict, page_ids, page_size: int,
             slot: int, skip_pages: int = 0) -> dict:
    """Copy-on-admit: scatter a prefilled request's (nper, 1, L, K, hd)
    KV into its allocated pages; non-KV leaves (recurrent state) are
    written into batch ``slot`` densely. ``skip_pages`` leading pages of
    the chain are trie-shared and already hold the right rows — writing
    them here would zero-pad over a sibling's live rows, so they are
    excluded from the scatter.

    The pool may be int8 (``kv_dtype='int8'``) while the request cache is
    always the fp dense staging layout — quantization happens here, and
    the scale siblings are filled from the same rows. The two trees then
    have different structures, so this walks the pool's flattened paths
    and looks the fp sources up by path.

    The whole scatter is jit-compiled, keyed by (staging length, page
    count, skip) — the same shape family the prefill executables already
    warm — so an int8 admit costs one fused kernel, not an eager
    quantize-dispatch per cache leaf. ``slot`` rides in as a traced
    scalar: slot churn never retraces."""
    skip = int(skip_pages)
    ids = jnp.asarray(page_ids, jnp.int32)[skip:]
    return _admit_kv_jit(cache, req_cache, ids, jnp.int32(slot),
                         page_size=int(page_size), skip=skip)


@functools.partial(jax.jit, static_argnames=("page_size", "skip"))
def _admit_kv_jit(cache: dict, req_cache: dict, ids, slot, *,
                  page_size: int, skip: int) -> dict:
    n = int(ids.shape[0])
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    req = {_path_key(p): leaf
           for p, leaf in jax.tree_util.tree_flatten_with_path(req_cache)[0]}

    def page_rows(req_leaf):
        nper, _, L, K, hd = req_leaf.shape
        r = req_leaf[:, 0, skip * page_size:]
        pad = n * page_size - (L - skip * page_size)
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return r.reshape(nper, n, page_size, K, hd)

    out = []
    for path, pooled in flat:
        key = _path_key(path)
        if _is_kv(path):
            if n == 0:                 # whole prompt shared: nothing to copy
                out.append(pooled)
                continue
            r = page_rows(req[key])
            if pooled.dtype == jnp.int8:
                r, _ = kv_quantize(r)
            out.append(pooled.at[:, ids].set(r.astype(pooled.dtype)))
        elif _is_kv_scale(path):
            if n == 0:
                out.append(pooled)
                continue
            r = page_rows(req[key[:-1] + (key[-1][:-len("_scale")],)])
            _, scale = kv_quantize(r)
            out.append(pooled.at[:, ids].set(scale))
        else:
            out.append(jax.lax.dynamic_update_slice(
                pooled, req[key].astype(pooled.dtype),
                (0, slot) + (0,) * (pooled.ndim - 2)))
    return jax.tree_util.tree_unflatten(treedef, out)


def extract_kv(cache: dict, page_ids, page_size: int, slot: int) -> dict:
    """Gather dual of ``admit_kv``, for request migration: pull a
    request's KV block chain out of the page pools into a dense
    (nper, 1, n*page_size, K, hd) request tree, and slice its batch slot
    out of every dense (recurrent-state) leaf, keeping the slot axis.
    The result has exactly the shape ``admit_kv`` scatters, so target-
    side admission IS ``admit_kv(..., skip_pages=n_reshared)``.

    int8 pools are DEQUANTIZED here and scale leaves dropped: a migration
    payload is always the fp dense layout, so source and target engines
    may run different ``kv_dtype`` settings — and since the quantizer's
    row max lands exactly on +-127, a target re-admitting into int8
    reproduces the source's bytes bit-for-bit."""
    ids = jnp.asarray(page_ids, jnp.int32)
    n = int(ids.shape[0])

    def walk(node):
        out = {}
        for name, child in node.items():
            if isinstance(child, dict):
                out[name] = walk(child)
                continue
            if name in ("k_scale", "v_scale", "xk_scale", "xv_scale"):
                continue
            if name in ("k", "v", "xk", "xv") and child.ndim == 5:
                nper, _, P, K, hd = child.shape
                rows = child[:, ids]
                if child.dtype == jnp.int8:
                    rows = kv_dequantize(rows, node[name + "_scale"][:, ids])
                out[name] = rows.reshape(nper, 1, n * P, K, hd)
            else:
                out[name] = jax.lax.dynamic_slice(
                    child, (0, slot) + (0,) * (child.ndim - 2),
                    (child.shape[0], 1) + child.shape[2:])
        return out
    return walk(cache)


def copy_page(cache: dict, src: int, dst: int) -> dict:
    """CoW page split, device side: duplicate one physical page across
    every KV pool (and its quantization scales) so the writer's fresh
    private page starts bit-identical to the shared one it is leaving."""
    def one(path, leaf):
        if _is_kv(path) or _is_kv_scale(path):
            return leaf.at[:, dst].set(leaf[:, src])
        return leaf
    return jax.tree_util.tree_map_with_path(one, cache)


def apply_page_moves(cache: dict, moves: dict[int, int]) -> dict:
    """Apply a ``defragment`` move map to the physical page pools
    (quantization scales ride along — their axis 1 is the same page id)."""
    if not moves:
        return cache

    def one(path, leaf):
        if _is_kv(path) or _is_kv_scale(path):
            g = permutation_of(moves, leaf.shape[1])
            return leaf[:, jnp.asarray(g)]
        return leaf
    return jax.tree_util.tree_map_with_path(one, cache)


def reset_slot_state(cache: dict, slot: int) -> dict:
    """Zero a finished slot's dense (non-KV) recurrent state; paged KV
    (and its scales) needs no reset — its pages are simply returned to
    the allocator."""
    def one(path, leaf):
        if _is_kv(path) or _is_kv_scale(path):
            return leaf
        name = path[-1].key if hasattr(path[-1], "key") else ""
        fill = -1e30 if name == "m" else 0.0
        return leaf.at[:, slot].set(fill)
    return jax.tree_util.tree_map_with_path(one, cache)
