"""Paged KV cache: block-granular allocation for the serve plane.

The dense per-slot ring allocates ``slots x max_len`` KV rows up front and
decodes against the whole allocation; the paged cache carves the same
physical storage into fixed-size *pages* handed out on demand — the exact
shape of the ``DevicePool`` one layer down (a pool of indivisible resource
units, an owner table, allocate/free/defragment), applied to KV rows
instead of accelerator devices:

  BlockAllocator     the PF analogue: owns the page pool, tracks per-request
                     ownership, enforces isolation (a page has at most one
                     owner), compacts on ``defragment``
  page 0             reserved garbage page — never allocated; inactive batch
                     slots' masked writes are redirected there, which is how
                     an idle slot's pages stay bit-untouched
  copy-on-admit      a request is prefilled into a private dense staging
                     cache (B=1) and its KV is *copied* into its allocated
                     pages on admission (``admit_kv``), so admission never
                     aliases the running batch's storage

The attention-side consumer is ``kernels/paged_decode`` (block-table
indirection, cost proportional to pages actually written).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


class RequestRejected(RuntimeError):
    """Typed admission rejection: the request can NEVER be served by this
    engine (over-long prompt, more pages than the pool holds). The engine
    marks the request done-with-error and keeps serving the batch — one
    bad request must not kill the engine (this replaces a bare ``assert``
    that vanished under ``python -O``)."""


class CacheExhausted(RuntimeError):
    """Transient allocation failure: not enough free pages *right now*.
    Admission backs off (the request stays queued) rather than failing."""


def _is_kv(path) -> bool:
    """Attention-cache leaves that need no slot reset (self-attn KV is
    masked by pos; cross xk/xv only ever appear in DENSE caches — the
    paged layout gates out encoder-decoder stacks entirely)."""
    name = path[-1].key if hasattr(path[-1], "key") else ""
    return name in ("k", "v", "xk", "xv")


class BlockAllocator:
    """Fixed-size page pool with per-request ownership.

    Page ids run [0, num_pages); page 0 is reserved (garbage page), so the
    allocatable capacity is ``num_pages - 1``. Free pages are handed out
    lowest-id first, which keeps block tables deterministic (the serving
    analogue of the scheduler's 'ties break in PF table order')."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._free = list(range(1, num_pages))     # ascending
        self._owned: dict[int, list[int]] = {}     # rid -> page ids

    # -- capacity ------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def capacity(self) -> int:
        return self.num_pages - 1

    def pages_needed(self, tokens: int) -> int:
        return max(1, math.ceil(tokens / self.page_size))

    # -- allocate / free -----------------------------------------------------
    def allocate(self, rid: int, n: int) -> list[int]:
        if rid in self._owned:
            raise ValueError(f"request {rid} already holds pages")
        if n > self.capacity:
            raise RequestRejected(
                f"request {rid} needs {n} pages; pool capacity is "
                f"{self.capacity} (page_size={self.page_size})")
        if n > len(self._free):
            raise CacheExhausted(
                f"request {rid} needs {n} pages, only {len(self._free)} "
                "free")
        got, self._free = self._free[:n], self._free[n:]
        self._owned[rid] = got
        return list(got)

    def extend(self, rid: int, n: int = 1) -> list[int]:
        if rid not in self._owned:
            raise ValueError(f"request {rid} holds no pages")
        if n > len(self._free):
            raise CacheExhausted(
                f"request {rid} needs {n} more pages, only "
                f"{len(self._free)} free")
        got, self._free = self._free[:n], self._free[n:]
        self._owned[rid].extend(got)
        return list(got)

    def free(self, rid: int) -> list[int]:
        pages = self._owned.pop(rid, [])
        self._free.extend(pages)
        self._free.sort()
        return pages

    def pages_of(self, rid: int) -> list[int]:
        return list(self._owned.get(rid, []))

    def owners(self) -> dict[int, list[int]]:
        return {rid: list(p) for rid, p in self._owned.items()}

    def check_invariants(self):
        """Mirror of DevicePool._check_invariants: disjoint ownership,
        everything in-pool, free+owned is an exact partition."""
        seen: dict[int, int] = {}
        for rid, pages in self._owned.items():
            for p in pages:
                assert 1 <= p < self.num_pages, (rid, p)
                assert p not in seen, (
                    f"page {p} owned by both {seen[p]} and {rid}")
                seen[p] = rid
        assert not (set(self._free) & set(seen))
        assert len(self._free) + len(seen) == self.capacity

    # -- defragment ----------------------------------------------------------
    def defragment(self) -> dict[int, int]:
        """Compact owned pages to the lowest ids (request order, then page
        order — deterministic). Returns the {old_id: new_id} moves; the
        caller must apply the same mapping to the physical page arrays and
        any block tables (``apply_page_moves``)."""
        moves: dict[int, int] = {}
        nxt = 1
        for rid in sorted(self._owned):
            pages = self._owned[rid]
            for i, p in enumerate(pages):
                if p != nxt:
                    moves[p] = nxt
                pages[i] = nxt
                nxt += 1
        self._free = list(range(nxt, self.num_pages))
        self.check_invariants()
        return moves


def permutation_of(moves: dict[int, int], num_pages: int) -> np.ndarray:
    """(num_pages,) gather indices g with new_pages = pages[g]. Moves from
    ``defragment`` never swap into a still-live source (targets are always
    compacted below their sources), so a single gather applies them all."""
    g = np.arange(num_pages)
    for old, new in moves.items():
        g[new] = old
    return g


# ---------------------------------------------------------------------------
# the paged cache tree
# ---------------------------------------------------------------------------
def paged_cache_supported(cfg) -> tuple[bool, str]:
    if cfg.is_encoder_decoder:
        return False, "encoder-decoder cross-KV is not paged"
    if "attn" not in cfg.block_pattern:
        return False, "attention-free stack has no KV to page"
    return True, ""


def init_paged_cache(model, shape, num_pages: int, page_size: int) -> dict:
    """Build the serve cache tree: attention k/v leaves become shared page
    pools (nper, P, page, K, hd); every other leaf (recurrent state) stays
    dense per-slot (B, ...) exactly as ``init_cache`` makes it."""
    ok, why = paged_cache_supported(model.cfg)
    if not ok:
        raise ValueError(f"paged KV unsupported for {model.cfg.name}: {why}")
    # the dense template only sizes non-KV leaves, so keep its seq dim tiny
    base = model.init_cache(dataclasses.replace(shape, seq_len=1))

    def one(path, leaf):
        if _is_kv(path):
            nper, _, _, K, hd = leaf.shape
            return jnp.zeros((nper, num_pages, page_size, K, hd),
                             leaf.dtype)
        return leaf
    return jax.tree_util.tree_map_with_path(one, base)


def admit_kv(cache: dict, req_cache: dict, page_ids, page_size: int,
             slot: int) -> dict:
    """Copy-on-admit: scatter a prefilled request's (nper, 1, L, K, hd)
    KV into its allocated pages; non-KV leaves (recurrent state) are
    written into batch ``slot`` densely."""
    ids = jnp.asarray(page_ids, jnp.int32)
    n = int(ids.shape[0])

    def one(path, pooled, req_leaf):
        if _is_kv(path):
            nper, _, L, K, hd = req_leaf.shape
            pad = n * page_size - L
            r = jnp.pad(req_leaf[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
            r = r.reshape(nper, n, page_size, K, hd)
            return pooled.at[:, ids].set(r.astype(pooled.dtype))
        return jax.lax.dynamic_update_slice(
            pooled, req_leaf.astype(pooled.dtype),
            (0, slot) + (0,) * (pooled.ndim - 2))
    return jax.tree_util.tree_map_with_path(one, cache, req_cache)


def apply_page_moves(cache: dict, moves: dict[int, int]) -> dict:
    """Apply a ``defragment`` move map to the physical page pools."""
    if not moves:
        return cache

    def one(path, leaf):
        if _is_kv(path):
            g = permutation_of(moves, leaf.shape[1])
            return leaf[:, jnp.asarray(g)]
        return leaf
    return jax.tree_util.tree_map_with_path(one, cache)


def reset_slot_state(cache: dict, slot: int) -> dict:
    """Zero a finished slot's dense (non-KV) recurrent state; paged KV
    needs no reset — its pages are simply returned to the allocator."""
    def one(path, leaf):
        if _is_kv(path):
            return leaf
        name = path[-1].key if hasattr(path[-1], "key") else ""
        fill = -1e30 if name == "m" else 0.0
        return leaf.at[:, slot].set(fill)
    return jax.tree_util.tree_map_with_path(one, cache)
