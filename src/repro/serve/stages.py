"""Stage templates — the Oobleck-style precomputed pipeline partitions
that let one serving engine span K VFs.

A ``StageTemplate`` is a balanced contiguous partition of the model's
``num_periods`` layer periods into K stages (stage i owns periods
``[bounds[i], bounds[i+1])``). Templates are precomputed for every K up
to the engine's maximum width at construction time, so a VF loss or a
scale-pressure decision re-instantiates the engine at K±1 by *selecting*
an existing template — a pure re-layout of the SAME params and KV pages,
never a recompute — which is why a reshape is bit-identical on every
token stream (invariant I10) and why invariant I14 can demand that every
live engine's stage set matches exactly one registered template.

The per-stage step functions are built from the same primitives as the
monolithic model path (``models.model.run_stack`` over a period-sliced
config, ``Model._embed`` / ``Model._logits`` verbatim on the boundary
stages), so stage i's computation IS the monolithic computation over its
period range: the inter-stage boundary tensor is the exact ``x`` the
monolithic stack would hold between those periods, carried in the
compute dtype with no extra cast.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig, RunConfig
from repro.models.layers import rms_norm
from repro.models.model import _dt, build_model, run_stack
from repro.runtime.partitioning import constrain, sharding_scope

import jax


@dataclasses.dataclass(frozen=True)
class StageTemplate:
    """One registered pipeline partition: K stages over ``num_periods``
    layer periods. ``bounds`` has K+1 entries, strictly increasing from 0
    to ``num_periods``."""
    k: int
    num_periods: int
    bounds: tuple

    def __post_init__(self):
        check_partition(self.bounds, self.num_periods)
        if len(self.bounds) != self.k + 1:
            raise ValueError(
                f"template k={self.k}: bounds {self.bounds} has "
                f"{len(self.bounds) - 1} stages")

    def stage_range(self, i: int) -> tuple:
        return (self.bounds[i], self.bounds[i + 1])


def check_partition(bounds, num_periods: int) -> None:
    """I14's partition predicate: ``bounds`` must tile [0, num_periods)
    cleanly — strictly increasing, starting at 0, ending at the period
    count — so stage-resident params/KV neither overlap nor leave gaps."""
    b = tuple(int(x) for x in bounds)
    if len(b) < 2 or b[0] != 0 or b[-1] != num_periods:
        raise ValueError(
            f"stage bounds {b} do not span [0, {num_periods}]")
    for lo, hi in zip(b, b[1:]):
        if hi <= lo:
            raise ValueError(f"stage bounds {b} not strictly increasing")


def build_templates(num_periods: int, max_k: int) -> dict:
    """Balanced contiguous partitions for every width 1..min(max_k, P).
    Stage i of width k owns ceil/floor(P/k) periods (the first P%k stages
    take the extra one), so the widest stage never exceeds the narrowest
    by more than one period."""
    if num_periods < 1:
        raise ValueError(f"num_periods must be >= 1, got {num_periods}")
    if max_k < 1:
        raise ValueError(f"max_k must be >= 1, got {max_k}")
    out = {}
    for k in range(1, min(max_k, num_periods) + 1):
        base, extra = divmod(num_periods, k)
        bounds = [0]
        for i in range(k):
            bounds.append(bounds[-1] + base + (1 if i < extra else 0))
        out[k] = StageTemplate(k=k, num_periods=num_periods,
                               bounds=tuple(bounds))
    return out


def pipeline_supported(cfg: ModelConfig) -> tuple:
    """(ok, why): which model stacks the serve pipeline can span. Gated
    to homogeneous attention decoders — recurrent blocks would need their
    inter-stage state threaded through the host boundary, and frontends
    (vision patches / audio frames) belong to stage 0 only, which the
    balanced templates do not model yet."""
    if any(b != ATTN for b in cfg.block_pattern):
        return False, f"block pattern {cfg.block_pattern} is not all-attn"
    if cfg.is_encoder_decoder:
        return False, "encoder-decoder stacks are not stage-splittable"
    if cfg.frontend.kind != "none":
        return False, f"frontend {cfg.frontend.kind!r} not supported"
    return True, ""


def split_stage_params(params: dict, cfg: ModelConfig,
                       template: StageTemplate) -> list:
    """Slice the full param tree into per-stage trees that mirror the
    full structure, so ``Model._embed`` / ``Model._logits`` / ``run_stack``
    consume them verbatim:

      every stage   {"decoder": {"layers": block leaves sliced [lo:hi]}}
      stage 0       + "embed" (the token table feeds ``_embed``)
      last stage    + "decoder.final_norm", and "lm_head" or "embed"
                    (tied) for ``_logits``

    Slices are jnp views/copies of the SAME param values — a reshape
    re-slices, it never re-initializes."""
    out = []
    layers = params["decoder"]["layers"]
    last = template.k - 1
    for i in range(template.k):
        lo, hi = template.stage_range(i)
        sp = {"decoder": {"layers": jax.tree.map(lambda l: l[lo:hi],
                                                 layers)}}
        if i == 0:
            sp["embed"] = params["embed"]
        if i == last:
            sp["decoder"]["final_norm"] = params["decoder"]["final_norm"]
            if cfg.tie_embeddings:
                sp["embed"] = params["embed"]
            elif "lm_head" in params:
                sp["lm_head"] = params["lm_head"]
        out.append(sp)
    return out


def _stage_cfg(cfg: ModelConfig, lo: int, hi: int) -> ModelConfig:
    """A config whose layer stack is exactly this stage's period range —
    ``run_stack`` reads ``num_layers // len(block_pattern)`` periods."""
    return dataclasses.replace(
        cfg, num_layers=(hi - lo) * len(cfg.block_pattern))


def make_stage_decode(run: RunConfig, rules, lo: int, hi: int, *,
                      first: bool, last: bool):
    """One pipeline stage of the paged continuous-batching decode step.

    first stage:  (params, cache, tokens (B,1) i32, pos, tables, active)
    middle:       (params, cache, x (B,1,D) cdt, pos, tables, active)
    last stage additionally returns (logits (B,V), cache) instead of
    (x, cache) — matching ``Model.decode_step``'s tail exactly.
    """
    cfg = run.model
    scfg = _stage_cfg(cfg, lo, hi)
    model = build_model(run)          # _embed/_logits (stack-size agnostic)

    def step(params, cache, xin, pos, tables, active):
        with sharding_scope(rules):
            cdt = _dt(run.precision.compute)
            if first:
                x = model._embed(params, xin, cdt)
                x = constrain(x, "hidden")
            else:
                x = xin
            posa = jnp.asarray(pos)
            if posa.ndim == 0:
                positions = jnp.reshape(pos, (1,))
            else:
                positions = jnp.maximum(posa, 0)[:, None]
            x, _, ncache = run_stack(
                scfg, run, params["decoder"]["layers"], x, "decode",
                cache=cache, positions=positions, pos=pos, tables=tables,
                active=active)
            if not last:
                return x, ncache
            x = rms_norm(x, params["decoder"]["final_norm"], cfg.norm_eps)
            logits = model._logits(params, x)
            return logits[:, 0], ncache

    return step


def make_stage_prefill(run: RunConfig, rules, lo: int, hi: int, *,
                       first: bool, last: bool):
    """One pipeline stage of the B=1 whole-prompt prefill. Every stage
    returns (y, stage_cache) where ``y`` is the boundary activation —
    except the last stage, whose ``y`` is the last-position logits row
    (matching ``Model.prefill``'s return contract)."""
    cfg = run.model
    scfg = _stage_cfg(cfg, lo, hi)
    model = build_model(run)

    def step(params, xin):
        with sharding_scope(rules):
            cdt = _dt(run.precision.compute)
            if first:
                x = model._embed(params, xin, cdt)
                x = constrain(x, "hidden")
            else:
                x = xin
            positions = jnp.arange(x.shape[1])
            x, _, cache = run_stack(
                scfg, run, params["decoder"]["layers"], x, "prefill",
                positions=positions)
            if not last:
                return x, cache
            xo = rms_norm(x, params["decoder"]["final_norm"], cfg.norm_eps)
            logits = model._logits(params, xo)
            return logits[:, -1], cache

    return step
