"""Serving engine: continuous batching over decode_step, dense or paged KV.

Requests are admitted into slots of a batched decode state and decoded
together; finished slots are recycled without stopping the batch. Two
cache layouts:

  dense (default)   per-slot ring of ``max_len`` KV rows — simple, but
                    every slot pays for its worst case and decode walks the
                    whole allocation
  paged             block-granular paged KV (``repro.serve.paged``): slots
                    borrow fixed-size pages from a shared pool via a
                    ``BlockAllocator``, decode is block-table-indirected
                    (``kernels/paged_decode``) and costs only the pages a
                    request has actually written — the vLLM-shaped layout
                    that lets 16+ concurrent requests share the storage a
                    dense ring would burn on 4

Prefill is chunked when ``prefill_chunk > 0`` (attention-pattern stacks):
one prompt chunk is processed per engine step, interleaved with the
running batch's decode, so admitting a long prompt no longer stalls
in-flight requests. Sampling is per-request temperature / top-k with a
counter-seeded RNG — a request's tokens are a pure function of
(request, logits), so a pause/migrate mid-request cannot change its
output (invariant I10).

The engine runs as a Tenant workload under the SVFF manager (see
``repro.serve.fleet``), so it can be paused/unpaused mid-serving —
requests queue while paused; the guest keeps its 'device'.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.models.model import Model, build_model
from repro.serve.paged import (BlockAllocator, CacheExhausted,
                               RequestRejected, admit_kv, apply_page_moves,
                               copy_page, extract_kv, init_paged_cache,
                               paged_cache_supported, reset_slot_state)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stops early
    temperature: float = 0.0           # 0: greedy argmax (<= 0 likewise)
    top_k: int = 0                     # 0: no top-k filter
    seed: int = 0                      # sampling stream (with rid)
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None        # set when admission rejected it
    t_submit: float = 0.0              # set by ServeEngine.submit
    t_tok: list = dataclasses.field(default_factory=list)  # per-token wall

    def __post_init__(self):
        # a positive-but-denormal temperature is always a caller bug: it
        # asks for near-greedy noise but 1/T overflows the f32 logits to
        # inf. The old sampler hid this with a silent max(T, 1e-6) clamp
        # that changed the requested distribution — reject it loudly at
        # construction instead (temperature <= 0 stays the greedy switch)
        if 0 < self.temperature < 1e-6:
            raise ValueError(
                f"request {self.rid}: temperature {self.temperature} is "
                "positive but below 1e-6; use 0 for greedy or a "
                "temperature >= 1e-6")


class DrainResult(list):
    """``run_until_idle``'s return value: the finished requests, plus
    ``drained`` — False when the engine stopped with work still pending
    (paused with a non-empty queue / live slots, or max_steps ran out)."""

    def __init__(self, items=(), drained: bool = True):
        super().__init__(items)
        self.drained = drained


@dataclasses.dataclass
class _PrefillJob:
    """An in-progress chunked prefill occupying a slot (not yet decoding)."""
    req: Request
    slot: int
    cache: dict                        # dense (B=1) staging cache
    plen: int
    offset: int = 0
    pages: Optional[list] = None       # paged: pages reserved at admission


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class ServeEngine:
    def __init__(self, run: RunConfig, params, *, slots: int = 4,
                 max_len: int = 256, rules=None, paged: bool = False,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: int = 0, share_prefix: bool = False,
                 kv_dtype: Optional[str] = None,
                 fused_sampling: bool = False):
        self.run = run
        self.model = build_model(run)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.queue: collections.deque[Request] = collections.deque()
        self.active: list[Optional[Request]] = [None] * slots
        self.pos = np.full((slots,), -1, np.int64)      # last written index
        self.last_token = np.zeros((slots,), np.int32)
        self.paused = False
        self._finished: list[Request] = []              # completed requests
        self._jobs: dict[int, _PrefillJob] = {}         # slot -> prefill job
        #: rid -> slot frozen by an in-flight outbound migration. A frozen
        #: slot keeps its Request/pages/KV (extraction copies, never
        #: moves), is skipped by decode, and thaws on release (commit) or
        #: abort — which is why an aborted migration is side-effect-free.
        self._migrating: dict[int, int] = {}
        #: cache-pressure / sharing counters, pumped into the MetricsBus
        #: by ServeFleet so the autoscaler sees cache pressure, not just
        #: queue depth. Cumulative over the engine's lifetime.
        self.stats = collections.Counter()
        # per-step dirty set: which export_state keys changed since the
        # last export. Informational for drivers (and asserted in tests);
        # the byte-level skipping itself happens in StagingEngine's
        # identity/digest memo — params stay the same jax objects across
        # exports, so a live pause's stop-and-copy moves them 0 times.
        self._dirty = {"params", "cache", "pos", "last_token"}

        cfg = run.model
        self.paged = paged
        if kv_dtype is not None and not paged:
            raise ValueError("kv_dtype requires the paged cache layout")
        self.kv_dtype = kv_dtype
        #: fused sampling: temperature/top-k Gumbel sampling runs inside
        #: the jitted decode step (kernels/sampling) and only token ids
        #: come back to the host — bit-identical to the host ``_sample``
        #: path (both draw the same portable counter-hash noise), so I10
        #: holds across the knob
        self.fused_sampling = bool(fused_sampling)
        if paged:
            ok, why = paged_cache_supported(cfg)
            if not ok:
                raise ValueError(f"paged KV for {cfg.name}: {why}")
            self.page_size = page_size
            maxp = math.ceil(max_len / page_size)
            self.num_pages = (num_pages if num_pages is not None
                              else 1 + slots * maxp)
            self.alloc = BlockAllocator(self.num_pages, page_size)
            self.tables = np.zeros((slots, maxp), np.int32)
            self._dirty.add("tables")
        # prefix sharing keys on prompt tokens alone, so it is gated to
        # token-only frontends (vision patch rows precede the token rows
        # and differ per request)
        self.share_prefix = (paged and share_prefix
                             and cfg.frontend.kind == "none")
        # chunked prefill needs per-chunk attention continuation, which only
        # the attention-pattern stacks support (recurrent blocks would need
        # their chunk-boundary state threaded through)
        chunkable = (all(b == "attn" for b in cfg.block_pattern)
                     and not cfg.is_encoder_decoder
                     and cfg.frontend.kind == "none")
        self.prefill_chunk = prefill_chunk if chunkable else 0

        from repro.train.step import (make_decode_step, make_prefill_chunk,
                                      make_serve_steps)
        prefill, _ = make_serve_steps(run, rules)
        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(make_decode_step(
            run, rules, paged=paged, fused=self.fused_sampling))
        self._chunk = jax.jit(make_prefill_chunk(run, rules))
        self._cache = None                              # lazy batched cache

    # -- cache plumbing -------------------------------------------------------
    def _ensure_cache(self):
        if self._cache is None:
            shape = dataclasses.replace(self.run.shape, seq_len=self.max_len,
                                        global_batch=self.slots)
            if self.paged:
                self._cache = init_paged_cache(self.model, shape,
                                               self.num_pages,
                                               self.page_size,
                                               kv_dtype=self.kv_dtype)
            else:
                self._cache = self.model.init_cache(shape)

    def _insert(self, slot: int, req_cache):
        """Write a (1, prefill_len, ...) request cache into batch slot."""
        def one(path, batch_leaf, req_leaf):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name in ("k", "v", "xk", "xv"):
                return jax.lax.dynamic_update_slice(
                    batch_leaf, req_leaf.astype(batch_leaf.dtype),
                    (0, slot, 0, 0, 0))
            return jax.lax.dynamic_update_slice(
                batch_leaf, req_leaf.astype(batch_leaf.dtype),
                (0, slot) + (0,) * (batch_leaf.ndim - 2))
        self._cache = jax.tree_util.tree_map_with_path(
            one, self._cache, req_cache)

    # -- public API -----------------------------------------------------------
    def submit(self, req: Request):
        if not req.t_submit:
            req.t_submit = time.perf_counter()
        self.queue.append(req)

    def pause(self):
        self.paused = True

    def unpause(self):
        self.paused = False

    # -- admission ------------------------------------------------------------
    def _validate(self, req: Request):
        cfg = self.run.model
        npatch = (cfg.frontend.num_patches
                  if cfg.frontend.kind == "vision" else 0)
        need = npatch + len(req.prompt) + req.max_new_tokens
        if len(req.prompt) < 1:
            raise RequestRejected(f"request {req.rid}: empty prompt")
        if need > self.max_len:
            raise RequestRejected(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"max_new {req.max_new_tokens} exceeds max_len "
                f"{self.max_len}")
        return npatch, need

    def _reject(self, req: Request, err: Exception):
        req.done = True
        req.error = str(err)
        self._finished.append(req)

    def _paged_admit(self, req: Request, npatch: int, need: int) -> list:
        """Reserve pages for admission: only the PROMPT rows up front —
        decode pages grow lazily (``extend`` in ``_ensure_writable``), so
        reserved-but-never-written pages stop inflating pool pressure.
        The full need is still validated against pool capacity here: a
        request that could never complete must be rejected at admission,
        not discovered mid-decode as an endless preempt/replay cycle."""
        if self.alloc.pages_needed(need) > self.alloc.capacity:
            raise RequestRejected(
                f"request {req.rid} needs {self.alloc.pages_needed(need)} "
                f"pages; pool capacity is {self.alloc.capacity} "
                f"(page_size={self.page_size})")
        tokens = None
        if self.share_prefix and npatch == 0:
            tokens = tuple(int(t) for t in req.prompt)
        return self.alloc.allocate(
            req.rid, self.alloc.pages_needed(npatch + len(req.prompt)),
            tokens=tokens)

    def _admit(self):
        """Fill free slots from the queue. A request that is rejected or
        finishes at prefill does NOT consume the slot — it is re-offered
        to the next queued request in the same pass."""
        for s in range(self.slots):
            if self.active[s] is not None or s in self._jobs:
                continue
            while self.queue:
                req = self.queue.popleft()
                try:
                    npatch, need = self._validate(req)
                except RequestRejected as e:
                    self._reject(req, e)
                    continue                      # slot still free
                pages = None
                if self.paged:
                    try:
                        pages = self._paged_admit(req, npatch, need)
                    except RequestRejected as e:
                        self._reject(req, e)
                        continue
                    except CacheExhausted:
                        # transient. One defragment pass before backing
                        # off: compaction keeps block tables dense and
                        # the counters give the autoscaler a cache-
                        # pressure signal distinct from queue depth
                        self.stats["cache_exhausted"] += 1
                        self.defragment()
                        self.stats["defrag_events"] += 1
                        try:
                            pages = self._paged_admit(req, npatch, need)
                        except CacheExhausted:
                            # back off, keep arrival order
                            self.queue.appendleft(req)
                            return
                self._ensure_cache()
                if self.prefill_chunk and len(req.prompt) > \
                        self.prefill_chunk:
                    self._start_job(s, req, pages)
                    break                         # slot taken by the job
                if self._prefill_full(s, req, npatch, pages):
                    break                         # slot now decoding
                # finished at prefill: slot re-offered to the next request

    def _prefill_full(self, slot: int, req: Request, npatch: int,
                      pages) -> bool:
        """B=1 whole-prompt prefill. Returns True if the slot is occupied
        (request entered the decode batch), False if it finished at
        prefill (slot stays free — nothing was written into it)."""
        plen = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        cfg = self.run.model
        if cfg.frontend.kind == "vision":
            batch["patches"] = jnp.zeros(
                (1, cfg.frontend.num_patches, cfg.d_model), jnp.bfloat16)
        if cfg.is_encoder_decoder:
            Te = max(1, plen // cfg.frontend.frame_ratio)
            batch["frames"] = jnp.zeros((1, Te, cfg.d_model), jnp.bfloat16)
        req_cache, last_logits = self._prefill(self.params, batch)
        tok = self._emit(req, np.asarray(last_logits[0]))
        if req.done:
            if pages is not None:
                self.alloc.free(req.rid)
            return False
        self._place(slot, req, req_cache, npatch + plen, pages)
        self.last_token[slot] = tok
        return True

    # -- chunked prefill ------------------------------------------------------
    def _start_job(self, slot: int, req: Request, pages):
        C = self.prefill_chunk
        plen = len(req.prompt)
        cap = C * _next_pow2(math.ceil(plen / C))   # bucketed staging len
        shape = dataclasses.replace(self.run.shape, seq_len=cap,
                                    global_batch=1)
        self._jobs[slot] = _PrefillJob(
            req=req, slot=slot, cache=self.model.init_cache(shape),
            plen=plen, pages=pages)

    def _advance_prefill(self):
        """Process ONE chunk of the oldest pending prefill job — prefill
        work is batched into the decode schedule instead of stalling it."""
        if not self._jobs:
            return
        slot, job = next(iter(self._jobs.items()))
        C = self.prefill_chunk
        req = job.req
        real = min(C, job.plen - job.offset)
        chunk = np.zeros((C,), np.int32)
        chunk[:real] = np.asarray(req.prompt[job.offset:job.offset + real],
                                  np.int32)
        job.cache, logits = self._chunk(self.params, job.cache,
                                        jnp.asarray(chunk)[None],
                                        jnp.int32(job.offset))
        job.offset += real
        if job.offset < job.plen:
            return
        del self._jobs[slot]
        tok = self._emit(req, np.asarray(logits[0, real - 1]))
        if req.done:                         # finished at prefill
            if job.pages is not None:
                self.alloc.free(req.rid)
            return
        req_cache = self._slice_kv(job.cache, job.plen)
        self._place(slot, req, req_cache, job.plen, job.pages)
        self.last_token[slot] = tok

    @staticmethod
    def _slice_kv(cache: dict, L: int) -> dict:
        def one(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            return leaf[:, :, :L] if name in ("k", "v") else leaf
        return jax.tree_util.tree_map_with_path(one, cache)

    def _place(self, slot: int, req: Request, req_cache, logical_len: int,
               pages):
        """Copy-on-admit: move a prefilled request's cache into the batch
        (paged: into its allocated pages, skipping the trie-shared chain
        head; dense: into its slot ring)."""
        if self.paged:
            shared = self.alloc.shared_count(req.rid)
            self.stats["shared_page_hits"] += shared
            self._cache = admit_kv(self._cache, req_cache, pages,
                                   self.page_size, slot,
                                   skip_pages=shared)
            row = self.tables[slot]
            row[:] = 0
            row[:len(pages)] = pages
            self._dirty.add("tables")
            # offer this prompt's pages for sharing only now that their
            # bytes are written (registration at allocate time would let
            # a sibling map onto a still-unwritten chunked prefill)
            if self.share_prefix:
                self.alloc.register_prefix(req.rid)
        else:
            self._insert(slot, req_cache)
        self.active[slot] = req
        self.pos[slot] = logical_len - 1
        self._dirty |= {"cache", "pos", "last_token"}

    # -- sampling -------------------------------------------------------------
    def _sample(self, req: Request, logits_row: np.ndarray) -> int:
        """THE sampling oracle (invariant I10): every other path —
        pause/migrate replay, preemption-by-recompute, and the fused
        in-kernel sampler (``kernels/sampling``) — must reproduce this
        bit-for-bit. All arithmetic is float32 with portably-exact ops:
        cast, divide, selection (partition), and the shared counter-hash
        Gumbel noise, so host numpy and the device kernel agree on every
        bit. Counter-seeded: token t of request (seed, rid) always draws
        the same noise, so sampling is a pure function of the request."""
        lg = np.asarray(logits_row, np.float32)
        V = self.run.model.vocab_size
        if lg.size > V:
            lg = lg.copy()
            lg[V:] = -np.inf                 # padded vocab tail
        if req.temperature <= 0:
            return int(np.argmax(lg))
        z = lg / np.float32(req.temperature)
        if 0 < req.top_k < V:
            kth = np.partition(z, -req.top_k)[-req.top_k]
            z = np.where(z >= kth, z, -np.inf)
        from repro.kernels.sampling import host_gumbel
        return int(np.argmax(z + host_gumbel(req.seed, req.rid,
                                             len(req.out), z.shape[0])))

    def _emit(self, req: Request, logits_row: np.ndarray) -> int:
        return self._finish_token(req, self._sample(req, logits_row))

    def _finish_token(self, req: Request, tok: int) -> int:
        """Record one sampled token (host- or kernel-sampled) and retire
        the request on EOS / token budget."""
        req.out.append(tok)
        req.t_tok.append(time.perf_counter())
        if tok == req.eos_id or len(req.out) >= req.max_new_tokens:
            req.done = True
            self._finished.append(req)
        return tok

    # -- the decode loop ------------------------------------------------------
    def _table_width(self, pos_new: np.ndarray) -> int:
        """Narrowest pow2 block-table width covering every active slot —
        decode cost follows the tokens actually written, and the pow2
        bucketing keeps the number of compiled variants logarithmic."""
        need = int(np.max(pos_new, initial=-1)) // self.page_size + 1
        return min(_next_pow2(max(need, 1)), self.tables.shape[1])

    def step(self) -> int:
        """One engine iteration: admit + one prefill chunk + one batched
        decode over the ACTIVE slots (inactive slots are masked out: their
        cache bytes stay untouched and they add no attention work).
        Returns number of active slots (0 = idle). No-op while paused."""
        if self.paused:
            return 0
        self._admit()
        self._advance_prefill()
        frozen = set(self._migrating.values())
        if frozen:
            # a synchronous migration freezes+thaws within one manager op,
            # so this only ticks when a caller holds the freeze across
            # steps (or a crash did) — the benchmarked migration stall
            self.stats["migration_stall_ticks"] += sum(
                1 for s in frozen if self.active[s] is not None)
        act = [s for s in range(self.slots)
               if self.active[s] is not None and s not in frozen]
        if not act:
            return 0
        self._ensure_cache()
        if self.paged:
            # the decode kernel writes each slot's new KV row through its
            # block table, so every write target must be private and
            # allocated BEFORE the batched call: lazily grow the chain
            # (prompt pages were all admission reserved) and CoW-split
            # shared pages; a slot the pool cannot serve is preempted
            act = [s for s in act if self._ensure_writable(s)]
            if not act:
                return 0
        act_mask = np.zeros((self.slots,), bool)
        act_mask[act] = True
        pos_new = np.where(act_mask, self.pos + 1, -1).astype(np.int32)
        tokens = jnp.asarray(np.where(act_mask, self.last_token, 0),
                             jnp.int32)[:, None]
        args = [self.params, self._cache, tokens, jnp.asarray(pos_new)]
        if self.paged:
            W = self._table_width(pos_new)
            args.append(jnp.asarray(self.tables[:, :W]))
        args.append(jnp.asarray(act_mask))
        if self.fused_sampling:
            # per-slot sampling params ride into the jitted step; only
            # (slots,) int32 token ids come back — the (B, V) logits
            # never leave the device
            temp = np.zeros((self.slots,), np.float32)
            topk = np.zeros((self.slots,), np.int32)
            keys = np.zeros((self.slots, 3), np.int32)
            for s in act:
                req = self.active[s]
                temp[s] = np.float32(req.temperature)
                topk[s] = req.top_k
                keys[s] = (req.seed, req.rid, len(req.out))
            toks, self._cache = self._decode(
                *args, jnp.asarray(temp), jnp.asarray(topk),
                jnp.asarray(keys))
            sampled = np.asarray(toks)
        else:
            logits, self._cache = self._decode(*args)
            lg = np.asarray(logits)
        self._dirty |= {"cache", "pos", "last_token"}
        for s in act:
            req = self.active[s]
            self.pos[s] += 1
            if self.fused_sampling:
                tok = self._finish_token(req, int(sampled[s]))
            else:
                tok = self._emit(req, lg[s])
            self.last_token[s] = tok
            if not req.done and self.pos[s] + 1 >= self.max_len:
                req.done = True
                self._finished.append(req)
            if req.done:
                self.active[s] = None
                self._reset_slot(s, rid=req.rid)
        return len(act)

    def _ensure_writable(self, slot: int) -> bool:
        """Make this step's KV write target (position ``pos+1``) safe for
        the decoding slot: extend the chain when the write crosses into
        an unallocated page (lazy growth), CoW-split when it lands in a
        page with refcount > 1. Exhaustion preempts the slot (False)."""
        req = self.active[slot]
        pi = (int(self.pos[slot]) + 1) // self.page_size
        chain = self.alloc.pages_of(req.rid)
        try:
            if pi >= len(chain):
                (new,) = self.alloc.extend(req.rid, 1)
                self.tables[slot, pi] = new
                self.stats["lazy_extends"] += 1
                self._dirty.add("tables")
            elif self.alloc.refcount(chain[pi]) > 1:
                old, new = self.alloc.cow(req.rid, pi)
                self._cache = copy_page(self._cache, old, new)
                self.tables[slot, pi] = new
                self.stats["cow_splits"] += 1
                self._dirty |= {"cache", "tables"}
        except CacheExhausted:
            self.stats["cache_exhausted"] += 1
            self._preempt(slot)
            return False
        return True

    def _preempt(self, slot: int):
        """Preemption-by-recompute, the exhaustion safety valve: drop the
        slot's work, release its pages (guaranteeing pool progress for
        the surviving slots), and requeue the request from scratch at the
        FRONT of the queue. Prefill and sampling are deterministic pure
        functions of the request (counter-seeded RNG — I10), so the
        replay emits exactly the tokens the preempted attempt did."""
        req = self.active[slot]
        self.alloc.free(req.rid)
        req.out.clear()
        req.t_tok.clear()
        self.active[slot] = None
        self.tables[slot, :] = 0
        self.pos[slot] = -1
        self._cache = reset_slot_state(self._cache, slot)
        self.queue.appendleft(req)
        self.stats["preemptions"] += 1
        self._dirty |= {"cache", "pos", "tables"}

    def _reset_slot(self, slot: int, rid: Optional[int] = None):
        """Recycle a finished slot: paged KV pages go back to the
        allocator; dense attn KV is masked by pos so it needs no reset;
        recurrent per-slot state is zeroed either way."""
        if self.paged:
            if rid is not None:
                self.alloc.free(rid)
            self.tables[slot, :] = 0
            self._dirty.add("tables")
        # dense attn KV is masked by pos (paged pages return to the
        # allocator), so only the recurrent per-slot state needs zeroing
        # — one fill-rule implementation for both layouts
        self._cache = reset_slot_state(self._cache, slot)
        self.pos[slot] = -1

    def defragment(self) -> dict:
        """Compact the page pool (allocator + physical pages + tables);
        returns the {old: new} page moves. No-op for dense engines."""
        if not self.paged:
            return {}
        moves = self.alloc.defragment()
        if moves and self._cache is not None:
            self._cache = apply_page_moves(self._cache, moves)
            self._dirty |= {"cache", "tables"}
        for s, req in enumerate(self.active):
            if req is not None:
                pages = self.alloc.pages_of(req.rid)
                self.tables[s, :] = 0
                self.tables[s, :len(pages)] = pages
        for job in self._jobs.values():
            if job.pages is not None:
                job.pages = self.alloc.pages_of(job.req.rid)
        return moves

    def abort_prefill_jobs(self):
        """Push every in-flight chunked-prefill job back onto the queue
        (front, original arrival order) and release its pages. A job has
        emitted NO tokens yet (the first token is sampled at completion)
        and prefill is deterministic, so restarting it after a pause is
        token-identical — this is how a suspend keeps export_state a
        COMPLETE device-state snapshot without staging half-built
        staging caches."""
        for slot, job in reversed(list(self._jobs.items())):
            if job.pages is not None:
                self.alloc.free(job.req.rid)
            self.queue.appendleft(job.req)    # dict is admission-ordered
        self._jobs.clear()

    # -- request migration (KV block shipping) --------------------------------
    # Protocol driven by SVFFManager.migrate_request: peek -> journal ->
    # extract (freeze, copy) -> ship -> admit on target -> release here.
    # Everything before release is non-destructive, so any abort (target
    # CacheExhausted, crash rollback) just thaws the frozen slot and the
    # source keeps serving the request.
    def peek_migratable(self, rid: Optional[int] = None) -> Optional[int]:
        """Pure query: the rid ``extract_request`` would pick — first
        active decoding slot in slot order (or ``rid`` if it is one).
        None when nothing is migratable (dense engine, idle, or already
        mid-migration)."""
        if not self.paged:
            return None
        frozen = set(self._migrating.values())
        for s in range(self.slots):
            req = self.active[s]
            if req is None or s in frozen:
                continue
            if rid is None or req.rid == rid:
                return req.rid
        return None

    def extract_request(self, rid: Optional[int] = None) -> Optional[dict]:
        """Freeze one in-flight request and gather everything the target
        needs to resume it: the Request object, its KV block chain as a
        dense strip (``extract_kv``), its slot's recurrent state, decode
        position and last sampled token, and the prompt tokens recorded
        by the allocator (so the target can re-share trie pages). The
        source keeps its pages — nothing destructive happens here."""
        rid = self.peek_migratable(rid)
        if rid is None:
            return None
        slot = next(s for s in range(self.slots)
                    if self.active[s] is not None
                    and self.active[s].rid == rid)
        self._ensure_cache()
        chain = self.alloc.pages_of(rid)
        state = extract_kv(self._cache, chain, self.page_size, slot)
        self._migrating[rid] = slot
        return {"rid": rid, "req": self.active[slot], "slot": slot,
                "chain_len": len(chain), "page_size": self.page_size,
                "tokens": self.alloc.tokens_of(rid),
                "pos": int(self.pos[slot]),
                "last": int(self.last_token[slot]),
                "state": state}

    def admit_migrated(self, payload: dict, state) -> int:
        """Admit a migrated request into a free slot: allocate a same-
        length chain (re-sharing trie pages for FULL prompt pages only —
        the partly-filled last prompt page may already hold this
        request's decode rows, which a sibling's registered page does
        not), scatter the shipped strip via ``admit_kv`` skipping the
        re-shared head, and resume at the shipped pos/last_token. Raises
        ``CacheExhausted`` (clean, side-effect-free) when no slot or not
        enough pages. Idempotent: re-admitting an owned rid is a no-op
        (recovery roll-forward replays)."""
        rid = payload["rid"]
        if not self.paged:
            raise RequestRejected(
                f"request {rid}: migration target is not a paged engine")
        if self.owns_request(rid):
            return next(s for s, r in enumerate(self.active)
                        if r is not None and r.rid == rid)
        slot = next((s for s in range(self.slots)
                     if self.active[s] is None and s not in self._jobs),
                    None)
        if slot is None:
            raise CacheExhausted(
                f"request {rid}: no free slot on migration target")
        n = payload["chain_len"]
        if n > self.tables.shape[1]:
            raise RequestRejected(
                f"request {rid}: chain of {n} pages exceeds target table "
                f"width {self.tables.shape[1]}")
        if payload["page_size"] != self.page_size:
            raise RequestRejected(
                f"request {rid}: page_size {payload['page_size']} != "
                f"target {self.page_size}")
        tokens = payload.get("tokens")
        share = None
        if self.share_prefix and tokens:
            share = tokens[:self.page_size * (len(tokens)
                                              // self.page_size)] or None
        try:
            pages = self.alloc.allocate(rid, n, tokens=share)
        except CacheExhausted:
            self.stats["cache_exhausted"] += 1
            self.defragment()
            self.stats["defrag_events"] += 1
            pages = self.alloc.allocate(rid, n, tokens=share)
        shared = self.alloc.shared_count(rid)
        self.stats["shared_page_hits"] += shared
        self._ensure_cache()
        self._cache = admit_kv(self._cache,
                               jax.tree.map(jnp.asarray, state), pages,
                               self.page_size, slot, skip_pages=shared)
        row = self.tables[slot]
        row[:] = 0
        row[:len(pages)] = pages
        self.active[slot] = payload["req"]
        self.pos[slot] = payload["pos"]
        self.last_token[slot] = payload["last"]
        if self.share_prefix and share:
            self.alloc.register_prefix(rid)
        self.stats["migrations_in"] += 1
        self.stats["migration_blocks_shipped"] += n - shared
        self._dirty |= {"cache", "pos", "last_token", "tables"}
        return slot

    def release_request(self, rid: int) -> bool:
        """Commit side of an outbound migration: the target owns the
        request now, so free our pages and recycle the frozen slot.
        Idempotent (False when rid is not frozen here) — recovery may
        roll the same release forward twice."""
        slot = self._migrating.pop(rid, None)
        if slot is None:
            return False
        self.active[slot] = None
        self._reset_slot(slot, rid=rid)
        self.stats["migrations_out"] += 1
        self._dirty |= {"cache", "pos", "tables"}
        return True

    def abort_migration(self, rid: int) -> bool:
        """Abort side: thaw the frozen slot. The request never stopped
        being ours (pages, KV, Request object all untouched), so decode
        resumes next step exactly where it froze."""
        return self._migrating.pop(rid, None) is not None

    def abort_incoming(self, rid: int):
        """Target-side rollback: drop any (possibly partial) admission of
        ``rid``. Idempotent no-op when we never admitted it."""
        if not self.paged or rid not in self.alloc.owners():
            return
        for s, req in enumerate(self.active):
            if req is not None and req.rid == rid:
                self.active[s] = None
                self._reset_slot(s, rid=rid)
                return
        self.alloc.free(rid)

    def owns_request(self, rid: int) -> bool:
        """Commit predicate for migration recovery: does this engine hold
        ``rid`` live (an active slot, a prefill job, the queue, or pages
        in its allocator)?"""
        if any(r is not None and r.rid == rid for r in self.active):
            return True
        if any(j.req.rid == rid for j in self._jobs.values()):
            return True
        if any(r.rid == rid for r in self.queue):
            return True
        return self.paged and rid in self.alloc.owners()

    def reset_after_crash(self):
        """Model an engine-process crash: device state (cache, page pool,
        block tables) is lost, every queued/active request is gone. The
        fleet re-homes the victim's requests onto siblings BEFORE calling
        this (``ServeFleet.recover_engine``); afterwards the engine is
        empty but servable again."""
        self.queue.clear()
        self._jobs.clear()
        self._finished.clear()
        self._migrating.clear()
        self.active = [None] * self.slots
        self.pos = np.full((self.slots,), -1, np.int64)
        self.last_token = np.zeros((self.slots,), np.int32)
        self._cache = None
        if self.paged:
            self.alloc = BlockAllocator(self.num_pages, self.page_size)
            self.tables = np.zeros_like(self.tables)
            self._dirty.add("tables")
        self._dirty |= {"params", "cache", "pos", "last_token"}

    def run_until_idle(self, max_steps: int = 10_000) -> DrainResult:
        """Drive the engine until queue and slots drain; returns every
        request completed during the run (prefill-finished ones included),
        in completion order. On a PAUSED engine this returns immediately —
        a paused engine makes no progress, so spinning would only lie
        about the drain; check ``.drained`` to see whether work remains."""
        for _ in range(max_steps):
            if self.paused:
                break
            if self.step() == 0 and not self.queue and not self._jobs:
                break
        pending = (bool(self.queue) or bool(self._jobs)
                   or any(r is not None for r in self.active))
        done, self._finished = self._finished, []
        return DrainResult(done, drained=not pending)

    # -- state for SVFF pause (config-space save) ------------------------------
    def dirty_keys(self) -> set:
        """Top-level export_state keys mutated since the last export —
        a pre-copy pause can skip the clean ones (params, in steady
        state) in its stop-and-copy."""
        return set(self._dirty)

    def export_state(self) -> dict:
        st = {"params": self.params, "cache": self._cache,
              "pos": self.pos.copy(), "last_token": self.last_token.copy()}
        if self.paged:
            st["tables"] = self.tables.copy()
        self._dirty = set()
        return st

    def import_state(self, st: dict):
        if "params" in st:
            self.params = st["params"]
        # restored cache leaves may be host numpy (zero-copy staging
        # transport); admit_kv/reset_slot_state index with .at[], so
        # re-materialize as jax arrays here rather than crashing on the
        # first admission after an unpause
        self._cache = (None if st["cache"] is None else
                       jax.tree.map(jnp.asarray, st["cache"]))
        # restored host arrays may be read-only views (zero-copy staging
        # transport); the engine mutates these in place, so copy
        self.pos = np.array(st["pos"], np.int64)
        self.last_token = np.array(st["last_token"], np.int32)
        if self.paged and "tables" in st:
            self.tables = np.array(st["tables"], np.int32)
        self._dirty = set(st)
