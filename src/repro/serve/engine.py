"""Serving engine: slot-based continuous batching over decode_step.

Requests are prefillled individually (B=1), inserted into a free slot of the
batched decode state, and decoded together; finished slots are recycled
without stopping the batch (vLLM-style, minus paged KV — the cache is a
dense per-slot ring). The engine runs as a Tenant workload under the SVFF
manager, so it can be paused/unpaused mid-serving (requests queue while
paused — the guest keeps its 'device').
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.models.model import Model, build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stops early
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, run: RunConfig, params, *, slots: int = 4,
                 max_len: int = 256, rules=None):
        self.run = run
        self.model = build_model(run)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.queue: collections.deque[Request] = collections.deque()
        self.active: list[Optional[Request]] = [None] * slots
        self.pos = np.full((slots,), -1, np.int64)      # last written index
        self.last_token = np.zeros((slots,), np.int32)
        self.paused = False
        self._finished: list[Request] = []              # completed requests
        # per-step dirty set: which export_state keys changed since the
        # last export. Informational for drivers (and asserted in tests);
        # the byte-level skipping itself happens in StagingEngine's
        # identity/digest memo — params stay the same jax objects across
        # exports, so a live pause's stop-and-copy moves them 0 times.
        self._dirty = {"params", "cache", "pos", "last_token"}
        from repro.train.step import make_serve_steps
        prefill, decode = make_serve_steps(run, rules)
        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)
        self._cache = None                              # lazy batched cache

    # -- cache plumbing -------------------------------------------------------
    def _ensure_cache(self):
        if self._cache is None:
            shape = dataclasses.replace(self.run.shape, seq_len=self.max_len,
                                        global_batch=self.slots)
            self._cache = self.model.init_cache(shape)

    def _insert(self, slot: int, req_cache, prompt_len: int):
        """Write a (1, prefill_len, ...) request cache into batch slot."""
        def one(path, batch_leaf, req_leaf):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name in ("k", "v", "xk", "xv"):
                L = req_leaf.shape[2]
                return jax.lax.dynamic_update_slice(
                    batch_leaf, req_leaf.astype(batch_leaf.dtype),
                    (0, slot, 0, 0, 0))
            return jax.lax.dynamic_update_slice(
                batch_leaf, req_leaf.astype(batch_leaf.dtype),
                (0, slot) + (0,) * (batch_leaf.ndim - 2))
        self._cache = jax.tree_util.tree_map_with_path(
            one, self._cache, req_cache)

    # -- public API -----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def pause(self):
        self.paused = True

    def unpause(self):
        self.paused = False

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                plen = len(req.prompt)
                assert plen + req.max_new_tokens <= self.max_len
                self._ensure_cache()
                batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
                cfg = self.run.model
                if cfg.frontend.kind == "vision":
                    batch["patches"] = jnp.zeros(
                        (1, cfg.frontend.num_patches, cfg.d_model),
                        jnp.bfloat16)
                if cfg.is_encoder_decoder:
                    Te = max(1, plen // cfg.frontend.frame_ratio)
                    batch["frames"] = jnp.zeros((1, Te, cfg.d_model),
                                                jnp.bfloat16)
                req_cache, last_logits = self._prefill(self.params, batch)
                self._insert(s, req_cache, plen)
                self._dirty |= {"cache", "pos", "last_token"}
                tok = int(jnp.argmax(last_logits[0]))
                req.out.append(tok)
                npatch = (cfg.frontend.num_patches
                          if cfg.frontend.kind == "vision" else 0)
                if tok == req.eos_id or req.max_new_tokens <= 1:
                    req.done = True        # finished at prefill
                    self._finished.append(req)
                    continue
                self.active[s] = req
                self.pos[s] = npatch + plen - 1
                self.last_token[s] = tok

    def step(self) -> int:
        """One engine iteration: admit + one batched decode. Returns number
        of active slots (0 = idle). No-op while paused."""
        if self.paused:
            return 0
        self._admit()
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return 0
        self._ensure_cache()
        tokens = jnp.asarray(self.last_token, jnp.int32)[:, None]
        pos = jnp.asarray(np.maximum(self.pos + 1, 0), jnp.int32)
        logits, self._cache = self._decode(self.params, self._cache,
                                           tokens, pos)
        self._dirty |= {"cache", "pos", "last_token"}
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in act:
            req = self.active[s]
            self.pos[s] += 1
            tok = int(nxt[s])
            req.out.append(tok)
            self.last_token[s] = tok
            if (len(req.out) >= req.max_new_tokens or tok == req.eos_id
                    or self.pos[s] + 1 >= self.max_len):
                req.done = True
                self._finished.append(req)
                self.active[s] = None
                self._reset_slot(s)
        return len(act)

    def _reset_slot(self, slot: int):
        """Zero a finished slot's recurrent state (attn KV is masked by pos
        so it needs no reset)."""
        def one(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name in ("k", "v", "xk", "xv"):
                return leaf
            fill = -1e30 if name == "m" else 0.0
            return leaf.at[:, slot].set(fill)
        self._cache = jax.tree_util.tree_map_with_path(one, self._cache)
        self.pos[slot] = -1

    def run_until_idle(self, max_steps: int = 10_000) -> list[Request]:
        """Drive the engine until queue and slots drain; returns every
        request completed during the run (prefill-finished ones included),
        in completion order."""
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        done, self._finished = self._finished, []
        return done

    # -- state for SVFF pause (config-space save) ------------------------------
    def dirty_keys(self) -> set:
        """Top-level export_state keys mutated since the last export —
        a pre-copy pause can skip the clean ones (params, in steady
        state) in its stop-and-copy."""
        return set(self._dirty)

    def export_state(self) -> dict:
        st = {"params": self.params, "cache": self._cache,
              "pos": self.pos.copy(), "last_token": self.last_token.copy()}
        self._dirty = set()
        return st

    def import_state(self, st: dict):
        if "params" in st:
            self.params = st["params"]
        self._cache = st["cache"]
        self.pos = st["pos"]
        self.last_token = st["last_token"]
        self._dirty = set(st)
