"""PipelineServeEngine — one serving engine spanning K VFs as pipeline
stages.

The engine IS a ``ServeEngine``: admission, sampling (the I10 oracle),
paged-KV bookkeeping, migration, pause/export — all inherited unchanged.
What changes is the two jitted entry points:

  ``_prefill``   runs the B=1 prompt through the K per-stage prefill
                 functions sequentially and reassembles the full-layout
                 request cache (period axis concatenation), so the base
                 class's copy-on-admit path is byte-identical
  ``_decode``    a HOST-side GPipe schedule: the active slots split into
                 M round-robin microbatch groups, and work item
                 (stage s, group m) runs at tick s+m
                 (``runtime.pipeline.serve_schedule``), each stage
                 threading its own KV slice through its groups

The batched cache keeps the FULL layout (every leaf leads with the
period axis), exactly as in the single-VF engine — stages only ever see
``leaf[lo:hi]`` slices at call time and the updated slices concatenate
back. That single decision is what makes width elastic: a reshape K→K'
is a pure re-layout (new template bounds, re-sliced params, different
jitted stage functions over the SAME bytes), so every in-flight request
decodes bit-identically across it (I10), and the base class's
export/import/migration plumbing — which only indexes the leading axis
by page or period id — needs no pipeline awareness at all.

Masked per-group stage calls are bit-identical to one full-batch call
because decode rows are independent (each slot attends only through its
own block table) and inactive rows are masked to the reserved garbage
page; the schedule changes WHEN a slot's row is computed, never what it
reads.

Per-item wall times feed ``runtime.pipeline.schedule_stats``: the
measured bubble fraction (vs the analytic ``bubble_fraction(M, S)``)
and per-stage busy seconds surface through ``EngineStats`` so the
autoscaler can justify width actions with evidence, not geometry.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.pipeline import schedule_stats, serve_schedule
from repro.serve.engine import ServeEngine
from repro.serve.stages import (StageTemplate, build_templates,
                                make_stage_decode, make_stage_prefill,
                                pipeline_supported, split_stage_params)


class PipelineServeEngine(ServeEngine):
    def __init__(self, run, params, *, stages: int = 2,
                 max_stages: Optional[int] = None, microbatches: int = 2,
                 rules=None, **kw):
        ok, why = pipeline_supported(run.model)
        if not ok:
            raise ValueError(f"pipeline serving for {run.model.name}: "
                             f"{why}")
        if kw.pop("fused_sampling", False):
            raise ValueError("pipeline serving samples on the host "
                             "(the I10 oracle); fused_sampling=False")
        if kw.pop("prefill_chunk", 0):
            raise ValueError("pipeline serving prefills whole prompts; "
                             "prefill_chunk=0")
        kw["paged"] = True
        # force the unrolled layer path: stage period counts differ per
        # template, and scan-vs-unroll is a different XLA program — the
        # unrolled path everywhere is what makes token streams
        # bit-identical across EVERY registered K
        run = dataclasses.replace(
            run, sharding=dataclasses.replace(run.sharding,
                                              scan_layers=False))
        super().__init__(run, params, rules=rules, **kw)
        cfg = run.model
        plen = len(cfg.block_pattern)
        self.num_periods = cfg.num_layers // plen
        want_max = max(stages, max_stages or stages)
        self.templates: dict = build_templates(self.num_periods, want_max)
        if stages not in self.templates:
            raise ValueError(
                f"no stage template for K={stages} "
                f"(registered: {sorted(self.templates)})")
        self.max_stage_width = max(self.templates)
        self.microbatches = max(1, int(microbatches))
        self._k = stages
        self._rules = rules
        # precompute the per-stage jitted step functions for EVERY
        # registered template at init — a reshape (VF loss, scale
        # pressure) selects an existing entry instead of building one
        self._stage_decode: dict = {}
        self._stage_prefill: dict = {}
        for k, tpl in self.templates.items():
            dfs, pfs = [], []
            for i in range(k):
                lo, hi = tpl.stage_range(i)
                first, last = i == 0, i == k - 1
                dfs.append(jax.jit(make_stage_decode(
                    run, rules, lo, hi, first=first, last=last)))
                pfs.append(jax.jit(make_stage_prefill(
                    run, rules, lo, hi, first=first, last=last)))
            self._stage_decode[k] = dfs
            self._stage_prefill[k] = pfs
        # param slices are cached per (params object, k) and rebuilt when
        # either changes (import_state swaps params; reshape swaps k)
        self._sparams_src = None
        self._sparams_k = 0
        self._sparams: list = []
        # measured schedule telemetry (cumulative since last reshape)
        self.stage_busy_s: list = [0.0] * stages
        self._cum_busy = 0.0
        self._cum_makespan = 0.0
        self.measured_bubble = 0.0
        self.sched_ticks = 0
        self.reshape_count = 0
        # signature-compatible overrides: the base class's step() /
        # _prefill_full() drive these exactly like the jitted originals
        self._prefill = self._pipeline_prefill
        self._decode = self._pipeline_decode

    # -- template / width protocol (manager gang ops + I14) ------------------
    @property
    def stage_width(self) -> int:
        return self._k

    def has_template(self, k: int) -> bool:
        return k in self.templates

    def stage_bounds(self) -> tuple:
        return self.templates[self._k].bounds

    def template(self) -> StageTemplate:
        return self.templates[self._k]

    def apply_reshape(self, k: int) -> None:
        """Re-instantiate at width ``k``: select the registered template,
        drop the stage-param slice cache, reset the per-stage telemetry
        window. The batched KV cache and every request byte are
        untouched — a reshape changes the program layout, not the state
        — which is the whole bit-identity argument. Idempotent at the
        current width."""
        if k == self._k:
            return
        if k not in self.templates:
            raise ValueError(f"no stage template for K={k} "
                             f"(registered: {sorted(self.templates)})")
        self._k = k
        self._sparams_src = None
        self.stage_busy_s = [0.0] * k
        self._cum_busy = 0.0
        self._cum_makespan = 0.0
        self.measured_bubble = 0.0
        self.reshape_count += 1

    def stage_loads(self) -> tuple:
        """Per-stage busy share of the measured makespan (0..1 each)."""
        if self._cum_makespan <= 0.0:
            return tuple(0.0 for _ in range(self._k))
        return tuple(b / self._cum_makespan for b in self.stage_busy_s)

    def _stage_param_slices(self) -> list:
        if self._sparams_src is not self.params or self._sparams_k != self._k:
            self._sparams = split_stage_params(
                self.params, self.run.model, self.templates[self._k])
            self._sparams_src = self.params
            self._sparams_k = self._k
        return self._sparams

    # -- the two overridden entry points --------------------------------------
    def _pipeline_prefill(self, params, batch):
        """(params, batch) -> (full-layout request cache, last logits) —
        the contract ``_prefill_full`` expects. ``params`` is ignored in
        favour of the stage slices (same values, sliced)."""
        sp = self._stage_param_slices()
        fns = self._stage_prefill[self._k]
        y = batch["tokens"]
        caches = []
        for i, fn in enumerate(fns):
            y, c = fn(sp[i], y)
            caches.append(c)
        full = jax.tree.map(lambda *ls: jnp.concatenate(ls, axis=0),
                            *caches)
        return full, y

    def _pipeline_decode(self, params, cache, tokens, pos, tables,
                         active):
        """(params, cache, tokens, pos, tables, active) ->
        (logits (B,V), cache) — the paged non-fused decode contract, run
        as a host-side GPipe schedule over K stage slices and M
        round-robin microbatch groups."""
        sp = self._stage_param_slices()
        fns = self._stage_decode[self._k]
        K = self._k
        tpl = self.templates[K]
        act = np.asarray(active)
        idx = np.flatnonzero(act)
        M = max(1, min(len(idx), self.microbatches))
        groups = [idx[m::M] for m in range(M)]
        pos_np = np.asarray(pos)
        gmasks, gposs = [], []
        for m in range(M):
            gm = np.zeros(act.shape, bool)
            gm[groups[m]] = True
            gmasks.append(jnp.asarray(gm))
            # the decode contract: pos < 0 marks a masked row
            gposs.append(jnp.asarray(
                np.where(gm, pos_np, -1).astype(pos_np.dtype)))
        slices = []
        for i in range(K):
            lo, hi = tpl.stage_range(i)
            slices.append(jax.tree.map(
                lambda l, lo=lo, hi=hi: l[lo:hi], cache))
        xs: list = [tokens] * M          # stage-0 input is the token ids
        last_rows: list = [None] * M
        walls = [[0.0] * M for _ in range(K)]
        for s, m in serve_schedule(M, K):
            t0 = time.perf_counter()
            y, ns = fns[s](sp[s], slices[s], xs[m], gposs[m], tables,
                           gmasks[m])
            jax.block_until_ready(y)
            walls[s][m] = time.perf_counter() - t0
            slices[s] = ns
            if s == K - 1:
                last_rows[m] = np.asarray(y)
            else:
                xs[m] = y
        new_cache = jax.tree.map(
            lambda *parts: jnp.concatenate(parts, axis=0), *slices)
        out = np.zeros_like(last_rows[0])
        for m in range(M):
            out[groups[m]] = last_rows[m][groups[m]]
        st = schedule_stats(walls)
        for i in range(K):
            self.stage_busy_s[i] += st.stage_busy[i]
        self._cum_busy += st.busy
        self._cum_makespan += st.makespan
        if self._cum_makespan > 0.0:
            self.measured_bubble = max(
                0.0, 1.0 - self._cum_busy / (K * self._cum_makespan))
        self.sched_ticks += M + K - 1
        return out, new_cache
