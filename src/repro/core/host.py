"""Host — one failure domain of the federated fleet.

The single-host stack (PRs 1-9) already *is* a host: one ``SVFFManager``
over one ``DevicePool`` with one ``OpJournal`` and a telemetry surface.
This module names that unit so the federation layer
(``core.federation``) can hold many of them, and adds the two things a
multi-host control plane needs from each member:

  * a **lease heartbeat** on an injected clock — the host periodically
    produces a stamped liveness+load payload; the coordinator grants a
    TTL lease against its OWN clock, so a partitioned host simply stops
    renewing and falls out of the routing set (OpenStack Neutron's
    SR-IOV agent ``report_interval``/``agent_down_time`` model);
  * an **epoch fence** — every coordinator op carries its lease epoch
    and the host rejects epochs older than the highest it has accepted
    (``SplitBrainError``), so a stale coordinator that lost a handoff
    can never drive this host again (invariant I15's fencing half).

The serve plane is duck-typed exactly like the manager's tenant
protocol: any occupant exposing ``submit_request``/``SLOTS``/``queue``/
``active`` (``SimServeTenant``, the bench's lite engines) is a routable
engine, whether it is a journaled manager tenant or a registered
lightweight one.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.core.errors import SplitBrainError
from repro.core.journal import OpJournal
from repro.core.manager import SVFFManager
from repro.core.pool import DevicePool
from repro.core.scheduler import AdmissionError
from repro.core.staging import StagingEngine
from repro.core.vf import VFState


class HostTelemetry:
    """Host-local counters the coordinator replicates (a miniature
    ``MetricsBus``: the serve-plane bus stays in ``repro.serve`` — core
    must not import it — but the federation snapshot shape is shared)."""

    def __init__(self):
        self.submitted = 0
        self.completed = 0
        self.rejected = 0
        self.heartbeats = 0
        self.fenced = 0            # ops rejected by the epoch fence

    def describe(self) -> dict:
        return {"submitted": self.submitted, "completed": self.completed,
                "rejected": self.rejected, "heartbeats": self.heartbeats,
                "fenced": self.fenced}


class Host:
    """One ``SVFFManager`` + ``OpJournal`` + ``DevicePool`` + telemetry,
    with a lease heartbeat and an epoch fence. ``clock`` is injected
    (``repro.sim.clock.VirtualClock`` in every test/bench), so lease
    arithmetic is deterministic and wall-clock never leaks into the sim.
    """

    def __init__(self, host_id: str, *,
                 workdir: str,
                 clock,
                 num_devices: int = 8,
                 max_vfs: int = 4,
                 policy: str = "first_fit",
                 lease_ttl: float = 3.0,
                 compact_every: Optional[int] = 256,
                 staging_queues: int = 2,
                 max_load_per_engine: int = 6):
        self.host_id = host_id
        self.clock = clock
        self.policy = policy
        self.lease_ttl = lease_ttl
        self.max_load_per_engine = max_load_per_engine
        self.workdir = workdir
        self.pool = DevicePool(
            devices=tuple(f"{host_id}.d{i}" for i in range(num_devices)),
            max_vfs=max_vfs)
        journal = OpJournal(os.path.join(workdir, "journal"),
                            compact_every=compact_every)
        self.mgr = SVFFManager(
            self.pool, staging=StagingEngine(num_queues=staging_queues),
            workdir=workdir, scheduler=policy, journal=journal)
        #: guest registry — survives a manager crash (the guests live in
        #: their VMs, not the management process); ``recover`` hands this
        #: to ``SVFFManager.recover`` exactly like the chaos harness does
        self.tenants: dict[str, object] = {}
        #: lightweight (non-journaled) engines the scale bench registers;
        #: routable exactly like managed serve tenants
        self.engines: dict[str, object] = {}
        self.telemetry = HostTelemetry()
        self.fence_epoch = 0
        self.last_beat: float = clock.now()

    # ------------------------------------------------------------- liveness
    def heartbeat(self) -> dict:
        """One lease-renewal payload, stamped with the HOST's clock. The
        coordinator turns it into a lease against its own clock — clocks
        never need to agree, only to advance."""
        self.last_beat = self.clock.now()
        self.telemetry.heartbeats += 1
        return {"host_id": self.host_id, "t": self.last_beat,
                "load": self.load(), "capacity": self.capacity()}

    def check_epoch(self, epoch: int) -> None:
        """Fence: reject ops from coordinators older than any this host
        has obeyed; adopt newer epochs (monotone, so I15's fencing check
        is a simple <= over the fleet)."""
        if epoch < self.fence_epoch:
            self.telemetry.fenced += 1
            raise SplitBrainError(
                f"{self.host_id}: op carries epoch {epoch} < fence "
                f"{self.fence_epoch} — stale coordinator rejected")
        self.fence_epoch = epoch

    # ---------------------------------------------------------- serve plane
    def serve_targets(self) -> list:
        """Routable engines, deterministic order: running managed serve
        tenants first (tid order), then registered lite engines."""
        managed = [tn for tid, tn in sorted(self.mgr.tenants.items())
                   if getattr(tn, "status", None) == "running"
                   and hasattr(tn, "submit_request")]
        lite = [e for _, e in sorted(self.engines.items())]
        return managed + lite

    @staticmethod
    def _engine_load(tn) -> int:
        return (len(getattr(tn, "queue", ()))
                + sum(1 for r in getattr(tn, "active", ())
                      if r is not None))

    def load(self) -> int:
        return sum(self._engine_load(tn) for tn in self.serve_targets())

    def capacity(self) -> int:
        return sum(self.max_load_per_engine for _ in self.serve_targets())

    def submit(self, rid: int, *, epoch: int, seed: Optional[int] = None):
        """Admit one federation-routed request onto the least-loaded
        local engine (creation order breaks ties, mirroring
        ``ServeFleet.submit``). Raises ``SplitBrainError`` for a stale
        epoch BEFORE any admission, ``AdmissionError`` when every engine
        is at its load cap."""
        self.check_epoch(epoch)
        targets = self.serve_targets()
        if not targets:
            self.telemetry.rejected += 1
            raise AdmissionError(f"{self.host_id}: no serving engine")
        best, best_load = None, None
        for tn in targets:
            ld = self._engine_load(tn)
            if ld >= self.max_load_per_engine:
                continue
            if best is None or ld < best_load:
                best, best_load = tn, ld
        if best is None:
            self.telemetry.rejected += 1
            raise AdmissionError(
                f"{self.host_id}: every engine at load cap "
                f"{self.max_load_per_engine}")
        req = best.submit_request(rid, seed=seed)
        self.telemetry.submitted += 1
        return best, req

    def owner_engine(self, rid: int):
        """The engine serving ``rid`` here, or None — the coordinator's
        post-heal reconciliation query for in-doubt admissions."""
        for tn in self.serve_targets():
            if getattr(tn, "owns_request", None) and tn.owns_request(rid):
                return tn
        return None

    # ------------------------------------------------------------ telemetry
    def snapshot(self) -> dict:
        """Stamped telemetry snapshot for replication: the coordinator
        keeps the newest it could PULL, and the stamp's age (by the
        coordinator's clock) is what the staleness bound tests."""
        engines = {}
        for tn in self.serve_targets():
            engines[getattr(tn, "tid", repr(tn))] = {
                "load": self._engine_load(tn),
                "slots": int(getattr(tn, "SLOTS", 0)),
            }
        free_vfs = sum(1 for vf in self.pool.vfs.values()
                       if vf.state == VFState.DETACHED
                       and vf.owner is None and vf.devices)
        return {"host_id": self.host_id, "stamp": self.clock.now(),
                "fence_epoch": self.fence_epoch,
                "load": self.load(), "capacity": self.capacity(),
                "max_load": self.max_load_per_engine,
                "free_vfs": free_vfs,
                "engines": engines, "counters": self.telemetry.describe()}

    # ------------------------------------------------------------- recovery
    def recover(self, peer_lookup=None) -> "SVFFManager":
        """Rebuild this host's manager after a crash, from what survives
        the management process: journal + records on disk, the pool, the
        guest registry, the RAM snapshot table. ``peer_lookup`` (wired by
        the federation) lets recovery resolve cross-host migrate entries;
        without it — or with the peer unreachable — those entries defer
        rather than guess (I15/I16)."""
        old = self.mgr
        lookup = peer_lookup if peer_lookup is not None else old.peer_lookup
        self.mgr = SVFFManager.recover(
            old.journal, old.pool, old.records,
            StagingEngine(num_queues=2),
            tenants=dict(self.tenants) or dict(old.tenants),
            snapshots=old.snapshots, workdir=self.workdir,
            pause_enabled=old.pause_enabled, scheduler=self.policy,
            peer_lookup=lookup)
        return self.mgr

    def adopt(self, tenants: dict) -> None:
        """Record the guest registry (objects that survive manager death)."""
        self.tenants.update(tenants)

    def describe(self) -> dict:
        return {"host_id": self.host_id, "policy": self.policy,
                "fence_epoch": self.fence_epoch,
                "lease_ttl": self.lease_ttl,
                "engines": len(self.serve_targets()),
                "load": self.load(), "capacity": self.capacity()}


__all__ = ["Host", "HostTelemetry"]
