"""FederationCoordinator — lease-based cross-host control plane.

One ``SVFFManager`` is one failure domain; this module federates many
``Host``s (``core.host``) behind a coordinator that:

  * tracks **host liveness with TTL leases** on an injected clock — a
    host that stops heartbeating (crashed or partitioned) falls out of
    the routing set when its lease lapses, exactly like an OpenStack
    Neutron agent going stale past ``agent_down_time``;
  * **routes admissions across hosts** through the same three scheduler
    policy names the VF placement layer uses
    (``core.scheduler.choose_host``), over **replicated telemetry
    snapshots with staleness bounds** — a snapshot older than
    ``max_staleness`` disqualifies its host from routing, and an
    autoscale plan built from stale evidence is suppressed (the
    ``TelemetrySnapshot.age_s`` / ``AutoscaleConfig.max_staleness_s``
    lift of invariant I11);
  * runs **journaled cross-host request migration** on the PR-7
    extract/ship/admit path: the SOURCE host's manager journals the
    intent (``dst_host`` detail), the destination tenant is driven
    through a fabric-checked ``RemoteTenant`` proxy, and a partition
    mid-migration leaves a DEFERRED pending entry (frozen source slot,
    nothing served twice) that the first post-heal ``recover`` resolves
    exactly once — invariants I15/I16;
  * fences **stale coordinators with lease epochs**: every op carries
    the coordinator's epoch, hosts reject older epochs
    (``SplitBrainError``), and ``handoff`` mints epoch+1 so at most one
    coordinator can drive any host after a takeover.

All networking is modelled by ``Fabric`` — an in-process reachability
relation with armable one-shot fault windows, the network analogue of
``core.fault.crash_plane`` (the sim's network-fault catalogue lives in
``repro.sim.federation.NETWORK_FAULTS``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Optional, Sequence

from repro.core.autoscaler import (Autoscaler, AutoscaleAction,
                                   EngineStats, TelemetrySnapshot)
from repro.core.errors import (FederationError, HostUnreachableError,
                               LeaseExpiredError, SplitBrainError)
from repro.core.host import Host
from repro.core.scheduler import (AdmissionError, HostCandidate,
                                  choose_host)

#: rid-space stride between coordinator epochs: two coordinators that
#: both survive a handoff window can never mint the same request id
RID_STRIDE = 1_000_000_000


# ---------------------------------------------------------------------------
# network model
# ---------------------------------------------------------------------------
class Fabric:
    """In-process network: nodes (host ids + coordinator ids) are mutually
    reachable unless a partition splits them into groups. ``arm`` primes a
    one-shot fault window (named points inside coordinator paths); when
    the window executes, the armed partition strikes *at that instant* —
    the network analogue of ``crash_plane.arm``/``crashpoint``."""

    def __init__(self):
        self._groups: Optional[tuple] = None
        self._armed: Optional[tuple] = None     # (window, groups)
        self.fired: list[str] = []              # windows that struck
        self.partitions = 0

    # -- partitions ---------------------------------------------------------
    def partition(self, *groups: Iterable[str]) -> None:
        """Split the fabric: nodes within one group stay mutually
        reachable; nodes in different groups (or unlisted — they form one
        implicit residual group) cannot reach each other."""
        self._groups = tuple(frozenset(g) for g in groups)
        self.partitions += 1

    def heal(self) -> None:
        self._groups = None

    @property
    def partitioned(self) -> bool:
        return self._groups is not None

    def _group_of(self, node: str) -> int:
        for i, g in enumerate(self._groups):
            if node in g:
                return i
        return -1                               # implicit residual group

    def reachable(self, a: str, b: str) -> bool:
        if a == b or self._groups is None:
            return True
        return self._group_of(a) == self._group_of(b)

    def require(self, a: str, b: str) -> None:
        if not self.reachable(a, b):
            raise HostUnreachableError(
                f"{a} cannot reach {b} (fabric partitioned)")

    # -- fault windows ------------------------------------------------------
    def arm(self, window: str, *groups: Iterable[str]) -> None:
        """One-shot: when ``window`` next executes, install
        ``partition(*groups)`` at exactly that instant."""
        self._armed = (window, tuple(tuple(g) for g in groups))

    def disarm(self) -> None:
        self._armed = None

    def window(self, name: str) -> None:
        if self._armed is not None and self._armed[0] == name:
            _, groups = self._armed
            self._armed = None
            self.fired.append(name)
            self.partition(*groups)


# ---------------------------------------------------------------------------
# leases
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Lease:
    """One host's liveness grant: valid until ``expires_at`` on the
    COORDINATOR's clock, stamped with the granting epoch."""
    host_id: str
    epoch: int
    granted_at: float
    expires_at: float

    def valid(self, now: float) -> bool:
        return now < self.expires_at


# ---------------------------------------------------------------------------
# cross-host tenant proxy
# ---------------------------------------------------------------------------
class RemoteTenant:
    """Coordinator-side proxy making a tenant on ANOTHER host usable as
    the ``dst`` of ``SVFFManager.migrate_request``: every protocol call
    traverses the fabric (raising ``HostUnreachableError`` on a
    partition), and the two migration fault windows live here —
    ``fed_migrate_mid_ship`` strikes before the remote admit (rollback-
    shaped), ``fed_migrate_after_admit`` after it (roll-forward-shaped,
    the classic in-doubt distributed commit)."""

    def __init__(self, fabric: Fabric, src_host: str, dst_host: str,
                 tenant):
        self._fabric = fabric
        self._src = src_host
        self._dst = dst_host
        self._t = tenant

    def _require(self) -> None:
        self._fabric.require(self._src, self._dst)

    # identity/validation surface the manager reads
    @property
    def tid(self):
        return self._t.tid

    @property
    def status(self):
        return getattr(self._t, "status", None)

    @property
    def vf_id(self):
        return getattr(self._t, "vf_id", None)

    # migration protocol, fabric-checked
    def admit_migrated(self, payload, state):
        self._fabric.window("fed_migrate_mid_ship")
        self._require()
        out = self._t.admit_migrated(payload, state)
        self._fabric.window("fed_migrate_after_admit")
        self._require()                 # ack loss after the remote admit
        return out

    def owns_request(self, rid) -> bool:
        self._require()
        return self._t.owns_request(rid)

    def abort_incoming(self, rid):
        self._require()
        return self._t.abort_incoming(rid)


# ---------------------------------------------------------------------------
# the coordinator
# ---------------------------------------------------------------------------
class FederationCoordinator:
    """Lease-based fleet-of-fleets control plane over ``Host``s. All time
    comes from the injected ``clock``; all networking goes through the
    shared ``Fabric``; every host-facing op carries ``self.epoch`` so a
    superseded coordinator is fenced, not trusted."""

    def __init__(self, hosts: Sequence[Host], *, clock,
                 fabric: Optional[Fabric] = None,
                 policy: str = "first_fit",
                 lease_ttl: float = 3.0,
                 max_staleness: float = 2.0,
                 epoch: int = 1,
                 node_id: str = "fed0"):
        self.hosts: dict[str, Host] = {h.host_id: h for h in hosts}
        if len(self.hosts) != len(hosts):
            raise FederationError("duplicate host_id in federation")
        self.clock = clock
        self.fabric = fabric or Fabric()
        self.policy = policy
        self.lease_ttl = lease_ttl
        self.max_staleness = max_staleness
        self.epoch = epoch
        self.node_id = node_id
        self.leases: dict[str, Lease] = {}
        #: replicated, stamped telemetry (newest snapshot PULLED per host)
        self.snapshots: dict[str, dict] = {}
        #: routing ledger: rid -> host_id it was admitted to
        self.residency: dict[int, str] = {}
        #: admissions whose ack was lost to a partition: never re-routed
        #: until ``reconcile`` confirms them against the owner (I15)
        self.in_doubt: set[int] = set()
        #: optimistic per-host load routed since the last fresh snapshot
        self._routed: dict[str, int] = {}
        self._next_rid = 0
        self._obs_epoch = 0
        self.rejections = 0
        self.deferred_migrations = 0
        for h in hosts:
            self._wire(h)

    # ------------------------------------------------------------- plumbing
    def _wire(self, host: Host) -> None:
        host.mgr.peer_lookup = functools.partial(self._peer_tenant,
                                                 host.host_id)

    def _peer_tenant(self, from_host: str, to_host: str, tid: str):
        """Host-to-host tenant resolution for cross-host recovery — goes
        through the fabric (host A recovering a migrate toward host B
        needs A-B reachability, not coordinator involvement)."""
        self.fabric.require(from_host, to_host)
        peer = self.hosts.get(to_host)
        if peer is None:
            return None
        return (peer.mgr.tenants.get(tid)
                or peer.tenants.get(tid))

    def now(self) -> float:
        return self.clock.now()

    def mint_rid(self) -> int:
        """Epoch-salted request ids: coordinators that coexist across a
        handoff window can never mint the same rid."""
        rid = self.epoch * RID_STRIDE + self._next_rid
        self._next_rid += 1
        return rid

    # ------------------------------------------------------------- liveness
    def heartbeat_all(self) -> dict:
        """Renew every reachable host's lease and pull its telemetry
        snapshot; unreachable hosts keep their (aging) lease and stale
        snapshot — lapsing is what takes them out of routing. A host that
        fences this coordinator's epoch (post-handoff) loses its lease
        here instead of renewing it."""
        now = self.now()
        renewed, lost = [], []
        for hid in sorted(self.hosts):
            if not self.fabric.reachable(self.node_id, hid):
                continue
            host = self.hosts[hid]
            try:
                host.check_epoch(self.epoch)
            except SplitBrainError:
                self.leases.pop(hid, None)
                lost.append(hid)
                continue
            host.heartbeat()
            self.leases[hid] = Lease(hid, self.epoch, now,
                                     now + self.lease_ttl)
            self.snapshots[hid] = host.snapshot()
            self.snapshots[hid]["pulled_at"] = now
            self._routed[hid] = 0
            renewed.append(hid)
        return {"renewed": renewed, "fenced": lost, "t": now}

    def live_hosts(self) -> list[str]:
        now = self.now()
        return [hid for hid in sorted(self.hosts)
                if (lease := self.leases.get(hid)) is not None
                and lease.valid(now)]

    def _require_live(self, hid: str) -> None:
        lease = self.leases.get(hid)
        if lease is None or not lease.valid(self.now()):
            raise LeaseExpiredError(
                f"{hid}: no valid lease at t={self.now():.3f} "
                f"(expired {getattr(lease, 'expires_at', None)})")

    # ------------------------------------------------------------- routing
    def _candidates(self) -> list[HostCandidate]:
        """Routable hosts: valid lease AND replicated snapshot younger
        than the staleness bound; load = replicated load + optimistic
        count routed since that snapshot."""
        now = self.now()
        cands = []
        for hid in self.live_hosts():
            snap = self.snapshots.get(hid)
            if snap is None or now - snap["pulled_at"] > self.max_staleness:
                continue
            cands.append(HostCandidate(
                host_id=hid,
                load=int(snap["load"]) + self._routed.get(hid, 0),
                capacity=int(snap["capacity"])))
        return cands

    def submit(self, rid: Optional[int] = None,
               seed: Optional[int] = None) -> dict:
        """Admit ONE request to the fleet. Pre-admit failures (partition
        on delivery, fenced host, full host) re-route to the next
        candidate — safe, nothing was admitted. A partition AFTER the
        host admitted (ack loss) marks the rid in-doubt: it is recorded
        against that host and never re-routed, so the same request can
        never be served twice (I15)."""
        if rid is None:
            rid = self.mint_rid()
        if rid in self.residency or rid in self.in_doubt:
            raise FederationError(
                f"rid {rid} already admitted to "
                f"{self.residency.get(rid, '?')} (exactly-once admission)")
        last_err: Optional[Exception] = None
        tried = set()
        while True:
            cands = [c for c in self._candidates()
                     if c.host_id not in tried]
            try:
                cand = choose_host(self.policy, cands)
            except AdmissionError as e:
                self.rejections += 1
                raise (last_err or e)
            hid = cand.host_id
            tried.add(hid)
            host = self.hosts[hid]
            try:
                self.fabric.window("fed_submit_route")
                self.fabric.require(self.node_id, hid)
                host.submit(rid, epoch=self.epoch, seed=seed)
            except HostUnreachableError as e:
                last_err = e            # delivery failed: nothing admitted
                continue
            except SplitBrainError as e:
                self.leases.pop(hid, None)     # this host obeys a newer
                last_err = e                   # coordinator now
                continue
            except AdmissionError as e:
                last_err = e
                continue
            self.residency[rid] = hid
            self._routed[hid] = self._routed.get(hid, 0) + 1
            try:
                self.fabric.window("fed_submit_after_admit")
                self.fabric.require(self.node_id, hid)
            except HostUnreachableError:
                self.in_doubt.add(rid)
                return {"rid": rid, "host": hid, "in_doubt": True}
            return {"rid": rid, "host": hid, "in_doubt": False}

    def reconcile(self) -> dict:
        """Post-heal: resolve in-doubt admissions against the owner host
        (did the admit land before the ack was lost?) and drop residency
        entries whose admission turned out to have been lost."""
        confirmed, lost = [], []
        for rid in sorted(self.in_doubt):
            hid = self.residency.get(rid)
            if hid is None or not self.fabric.reachable(self.node_id, hid):
                continue
            if self.hosts[hid].owner_engine(rid) is not None:
                confirmed.append(rid)
            else:
                # a deferred migration that rolled FORWARD left the rid on
                # its destination: search the reachable fleet for the new
                # owner before declaring the admission lost
                moved = next(
                    (h2 for h2 in sorted(self.hosts) if h2 != hid
                     and self.fabric.reachable(self.node_id, h2)
                     and self.hosts[h2].owner_engine(rid) is not None),
                    None)
                if moved is not None:
                    self.residency[rid] = moved
                    confirmed.append(rid)
                else:
                    self.residency.pop(rid, None)
                    lost.append(rid)
            self.in_doubt.discard(rid)
        return {"confirmed": confirmed, "lost": lost}

    # ------------------------------------------------------------ migration
    def migrate_request(self, src_host: str, dst_host: str,
                        rid: Optional[int] = None,
                        src_tid: Optional[str] = None,
                        dst_tid: Optional[str] = None) -> dict:
        """Journaled cross-host request migration on the PR-7 path. The
        SOURCE manager journals the intent with the ``dst_host`` detail
        and drives the destination through a ``RemoteTenant`` proxy; a
        partition mid-flight surfaces as ``HostUnreachableError``, the
        manager's clean-failure path consults ``peer_lookup``, finds the
        peer unreachable, and DEFERS the entry — the source slot stays
        frozen (served by no one) until a post-heal ``recover`` resolves
        it against the target-owns predicate exactly once."""
        self._require_live(src_host)
        self._require_live(dst_host)
        self.fabric.require(self.node_id, src_host)
        src = self.hosts[src_host]
        dst = self.hosts[dst_host]
        src.check_epoch(self.epoch)
        # pick the source engine: the one serving ``rid``, else the first
        # with any migratable in-flight request
        src_tn = None
        if src_tid is not None:
            src_tn = src.mgr.tenants.get(src_tid)
        elif rid is not None:
            src_tn = src.owner_engine(rid)
        else:
            for tn in src.serve_targets():
                if (hasattr(tn, "peek_migratable")
                        and tn.peek_migratable() is not None):
                    src_tn = tn
                    break
        if src_tn is None:
            raise FederationError(
                f"migrate_request: no source engine on {src_host} "
                f"for rid={rid}")
        # pick the destination engine: explicitly named, else least loaded
        if dst_tid is not None:
            dst_tn = dst.mgr.tenants.get(dst_tid)
        else:
            targets = [t for t in dst.serve_targets()
                       if hasattr(t, "admit_migrated")]
            dst_tn = min(targets, key=Host._engine_load, default=None)
        if dst_tn is None:
            raise FederationError(
                f"migrate_request: no target engine on {dst_host}")
        proxy = RemoteTenant(self.fabric, src_host, dst_host, dst_tn)
        try:
            out = src.mgr.migrate_request(src_tn, proxy, rid,
                                          dst_host=dst_host)
        except HostUnreachableError:
            self.deferred_migrations += 1
            if rid is not None:
                self.in_doubt.add(rid)
            raise
        moved = out["rid"]
        self.residency[moved] = dst_host
        self.in_doubt.discard(moved)
        out["src_host"], out["dst_host"] = src_host, dst_host
        return out

    # ------------------------------------------------------------ telemetry
    def fleet_snapshot(self) -> TelemetrySnapshot:
        """The autoscaler's view of the whole fleet, built ONLY from
        replicated snapshots. ``age_s`` is the oldest included snapshot's
        age on the coordinator's clock — the staleness bound
        (``AutoscaleConfig.max_staleness_s``) suppresses actions planned
        from evidence older than that (I11 lifted to the federation)."""
        now = self.now()
        self._obs_epoch += 1
        engines, age, free_vfs = [], 0.0, 0
        slo = 1
        for i, hid in enumerate(sorted(self.snapshots)):
            snap = self.snapshots[hid]
            age = max(age, now - snap["pulled_at"])
            free_vfs += int(snap.get("free_vfs", 0))
            slo = max(slo, int(snap.get("max_load", 1)))
            for j, (tid, e) in enumerate(sorted(snap["engines"].items())):
                engines.append(EngineStats(
                    tid=f"{hid}/{tid}", index=i * 1000 + j,
                    status="running", load=int(e["load"])))
        return TelemetrySnapshot(
            epoch=self._obs_epoch, slo_max_load=slo,
            engines=tuple(engines), free_vfs=free_vfs, age_s=age)

    def plan_autoscale(self, autoscaler: Autoscaler
                       ) -> Optional[AutoscaleAction]:
        """One observation epoch over the replicated fleet view; returns
        the (at most one) action, or None — including the None forced by
        the staleness bound when every snapshot is partition-aged."""
        return autoscaler.observe(self.fleet_snapshot())

    # ------------------------------------------------------------- recovery
    def recover(self, host_ids: Optional[Iterable[str]] = None) -> dict:
        """Federation recovery: rebuild each named host's manager from
        its survivable pieces (any subset, any order — I16 asserts the
        result fingerprint is order- and repetition-invariant), then
        reconcile in-doubt admissions. Deferred cross-host entries
        resolve here iff their peer is reachable; otherwise they stay
        deferred for the next recover."""
        recovered = []
        for hid in sorted(host_ids if host_ids is not None else self.hosts):
            host = self.hosts[hid]
            host.recover()
            self._wire(host)
            recovered.append(hid)
        rec = self.reconcile()
        return {"recovered": recovered, **rec}

    # -------------------------------------------------------------- handoff
    def handoff(self, node_id: Optional[str] = None
                ) -> "FederationCoordinator":
        """Coordinator failover: mint the successor at epoch+1. Its first
        ``heartbeat_all`` fences every host it can reach; this (now
        stale) coordinator keeps running — and gets ``SplitBrainError``
        from any fenced host it still tries to drive, which is exactly
        invariant I15's fencing clause."""
        succ = FederationCoordinator(
            list(self.hosts.values()), clock=self.clock,
            fabric=self.fabric, policy=self.policy,
            lease_ttl=self.lease_ttl, max_staleness=self.max_staleness,
            epoch=self.epoch + 1,
            node_id=node_id or f"fed{self.epoch + 1}")
        succ.residency = dict(self.residency)
        succ.in_doubt = set(self.in_doubt)
        succ._next_rid = self._next_rid
        succ.snapshots = {hid: dict(s) for hid, s in self.snapshots.items()}
        succ.heartbeat_all()
        return succ

    def describe(self) -> dict:
        return {"node_id": self.node_id, "epoch": self.epoch,
                "policy": self.policy, "hosts": sorted(self.hosts),
                "live": self.live_hosts(),
                "leases": {h: dataclasses.asdict(l)
                           for h, l in self.leases.items()},
                "in_doubt": sorted(self.in_doubt),
                "deferred_migrations": self.deferred_migrations,
                "rejections": self.rejections}


__all__ = ["Fabric", "FederationCoordinator", "Lease", "RemoteTenant",
           "RID_STRIDE"]
