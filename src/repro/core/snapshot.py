"""ConfigSpaceSnapshot — what `pause` saves and `unpause` restores.

Paper §IV-B1 step 1: "save the PCI device config space including emulated
config space and MSI state". The TPU analogue of a VF's config space is the
complete logical placement description of the tenant:

  payload        the state pytree, staged to host (possibly qdma-packed)
  sharding_desc  PartitionSpec tree, serialized (how it was laid out)
  mesh_shape/axes the slice geometry it came from
  exec_keys      executable-cache keys (the "MSI state" — which interrupt
                 routes/compiled programs were live)
  steps_done     progress counters (config registers)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

from repro.core.staging import TransferStats


def serialize_specs(spec_tree) -> list:
    """PartitionSpec tree -> [(path, [axis|None|list]), ...]."""
    import jax
    from jax.sharding import PartitionSpec
    flat, _ = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=lambda x: isinstance(x, PartitionSpec))
    out = []
    for path, spec in flat:
        entry = [list(p) if isinstance(p, tuple) else p for p in spec]
        out.append((jax.tree_util.keystr(path), entry))
    return out


@dataclasses.dataclass
class ConfigSpaceSnapshot:
    tenant_id: str
    steps_done: int
    payload: Any                       # host-staged state pytree
    sharding_desc: list                # serialized spec tree
    mesh_shape: tuple
    mesh_axes: tuple
    exec_keys: list
    created_at: float = dataclasses.field(default_factory=time.time)
    stats: Optional[TransferStats] = None
    compressed: bool = False
    precopy_rounds: int = 0            # >0: taken via pause_vf_live

    def describe(self) -> dict:
        return {
            "tenant_id": self.tenant_id, "steps_done": self.steps_done,
            "mesh_shape": list(self.mesh_shape),
            "mesh_axes": list(self.mesh_axes),
            "exec_keys": [list(k) if isinstance(k, tuple) else k
                          for k in self.exec_keys],
            "bytes": (self.stats.bytes_moved if self.stats else None),
            "compressed": self.compressed,
            "precopy_rounds": self.precopy_rounds,
        }
