"""Canonical typed-error hierarchy for the SVFF stack.

Deliberately a LEAF module (imports nothing from the package) so every
layer — core, serve, sim, the federation — can raise and catch the same
classes without import cycles. ``repro.core.__init__`` re-exports the
whole hierarchy; the defining modules (``core.manager``,
``serve.paged``) import from here and re-export for backward
compatibility, so ``from repro.core.manager import ManagerError`` keeps
working and names stay identity-equal everywhere.

Hierarchy (pool/scheduler admission errors live in their own modules
because they subclass ``PoolError``):

    RuntimeError
    ├── ManagerError
    │   ├── UnknownTenantError
    │   └── FederationError
    │       ├── HostUnreachableError
    │       ├── LeaseExpiredError
    │       └── SplitBrainError
    ├── DoubleFreeError
    └── UnknownRequestError
"""
from __future__ import annotations


class ManagerError(RuntimeError):
    """Typed manager-level rejection (the base the sim harness accepts)."""


class UnknownTenantError(ManagerError):
    """Operation names a tenant the manager holds no state for (e.g.
    unpause of a tenant with no RAM snapshot). Typed so the sim harness
    never has to treat a blanket ``KeyError`` as an expected rejection."""


class DoubleFreeError(RuntimeError):
    """``free`` of a rid that holds no pages. With refcounted sharing a
    silent double-decref would corrupt pages still referenced by sibling
    requests, so this is a loud typed error, never a no-op."""


class UnknownRequestError(RuntimeError):
    """``extend``/``cow`` of a rid that holds no pages. The engine's lazy
    decode growth and CoW splits only ever name requests it placed, so an
    unknown rid here is a control-plane bug (stale slot map, migration
    race) — a loud typed error, never a silent KeyError/ValueError that
    callers can't distinguish from a malformed argument."""


# --------------------------------------------------------------- federation
class FederationError(ManagerError):
    """Base for cross-host control-plane rejections. A subclass of
    ``ManagerError`` so the sim harness's rejection set absorbs
    federation-plane failures the same way it absorbs single-host ones —
    a partition is an expected rejection, never a crash."""


class HostUnreachableError(FederationError):
    """A cross-host call could not traverse the fabric (network
    partition). Side-effect-free by construction: every federation path
    checks reachability BEFORE its destructive step, and a partition that
    strikes mid-migration defers the journal entry instead of guessing."""


class LeaseExpiredError(FederationError):
    """An operation was attempted against (or by) a host whose liveness
    lease has lapsed. The coordinator routes around expired hosts; a
    host acting on a lapsed lease must re-heartbeat first."""


class SplitBrainError(FederationError):
    """A stale coordinator (lease epoch below the host's fence) tried to
    admit or reconfigure. Lease epochs are fencing tokens: once a host
    has seen epoch N it rejects every op carrying an older epoch, so two
    coordinators can never both drive the same host (invariant I15)."""
