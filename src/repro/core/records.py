"""Attach records — the libvirt XML analogue (paper §IV-B3).

"each VF device is specified in an XML file that outlines its properties
... saved to maintain a record of the VF-VM association for future
reference, allowing for a seamless detach operation."

Records are JSON files per tenant under a records dir. The *attach* path
re-validates the record against the live pool (driver/device-id checks the
QDMA manager performs); the *unpause* path skips validation — part of the
honest cost asymmetry between attach and unpause.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional

from repro.core.fault import crashpoint
from repro.core.pool import DevicePool


class RecordError(RuntimeError):
    pass


class RecordStore:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, tenant_id: str) -> str:
        return os.path.join(self.dir, f"{tenant_id}.json")

    def write(self, tenant_id: str, vf_desc: dict, run_name: str) -> str:
        rec = {
            "tenant": tenant_id,
            "vf": vf_desc,
            "run": run_name,
            "driver": {"host": "vfio-pci", "guest": "qdma-vf"},
            "written_at": time.time(),
        }
        p = self._path(tenant_id)
        tmp = p + ".part"
        with open(tmp, "w") as f:
            json.dump(rec, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        # crash window: the .part file exists but the record does not —
        # reads ignore it, recovery sweeps it and rolls the op forward
        crashpoint("mid_record_write")
        os.replace(tmp, p)
        crashpoint("after_record_write")
        return p

    def read(self, tenant_id: str) -> dict:
        p = self._path(tenant_id)
        if not os.path.exists(p):
            raise RecordError(f"no attach record for {tenant_id}")
        with open(p) as f:
            return json.load(f)

    def remove(self, tenant_id: str):
        """Idempotent: removing a missing record is a no-op (recovery may
        replay a detach whose record removal already happened)."""
        p = self._path(tenant_id)
        if os.path.exists(p):
            os.remove(p)

    def list(self) -> list[str]:
        """Attached tenants by record file; ``*.part`` staging files from
        an interrupted write are never visible here."""
        return sorted(f[:-5] for f in os.listdir(self.dir)
                      if f.endswith(".json"))

    def part_files(self) -> list[str]:
        """Leftover ``*.part`` staging files (crash debris)."""
        return sorted(f for f in os.listdir(self.dir)
                      if f.endswith(".part"))

    def sweep_parts(self) -> int:
        """Remove crash debris; returns how many files were swept."""
        parts = self.part_files()
        for fn in parts:
            os.remove(os.path.join(self.dir, fn))
        return len(parts)

    def validate(self, tenant_id: str, pool: DevicePool) -> dict:
        """Attach-path re-validation (device id / driver name checks)."""
        rec = self.read(tenant_id)
        vf_id = rec["vf"]["vf_id"]
        if not vf_id.startswith(pool.pf_id[:-1][:-2]):
            pass  # different PF prefix is fine after repartition
        if rec["driver"]["host"] != "vfio-pci":
            raise RecordError(f"{tenant_id}: unexpected host driver "
                              f"{rec['driver']['host']}")
        mesh_shape = rec["vf"].get("mesh_shape", [])
        import math
        if math.prod(mesh_shape) > pool.num_devices:
            raise RecordError(f"{tenant_id}: record wants {mesh_shape} "
                              f"devices, pool has {pool.num_devices}")
        return rec
