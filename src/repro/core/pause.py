"""The pause / unpause mechanism — the paper's novel contribution (§IV-B1).

pause (3 steps, mirroring the QEMU vfio-pci implementation):
  1. save the config space — stage the tenant's device state to host
     (StagingEngine = QDMA queues), capture sharding layout, progress
     counters and executable-cache keys (MSI-state analogue);
  2. unregister the PCI device ops — the tenant drops its device handles
     but keeps its emulated view: queries still answered, I/O raises;
  3. unregister the VFIO device — delete device buffers and release the
     VF's devices ("exit from the IOMMU group"), freeing the pool to be
     repartitioned while the guest still sees its (paused) device.

unpause (2 steps):
  1. restore I/O — reallocate a slice (possibly different devices/shape),
     place the staged state with the new shardings (resharding is free
     here: device_put scatters host data straight into the new layout);
  2. restore config registers — progress counters and executable keys back
     into the tenant; on the same slice the compiled step is a cache hit
     (no re-realize), which is exactly where the paper's ~2% win comes from.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.core.pool import DevicePool
from repro.core.snapshot import ConfigSpaceSnapshot, serialize_specs
from repro.core.staging import StagingEngine
from repro.core.tenant import Tenant
from repro.core.vf import VFState, VirtualFunction


@dataclasses.dataclass
class PhaseTimings:
    phases: dict = dataclasses.field(default_factory=dict)

    def add(self, name: str, seconds: float):
        self.phases[name] = self.phases.get(name, 0.0) + seconds

    @property
    def total(self) -> float:
        return sum(self.phases.values())


class PauseError(RuntimeError):
    pass


def pause_vf(pool: DevicePool, vf: VirtualFunction, tenant: Tenant,
             staging: StagingEngine) -> tuple[ConfigSpaceSnapshot,
                                              PhaseTimings]:
    t = PhaseTimings()
    if vf.state != VFState.ATTACHED or vf.owner != tenant.tid:
        raise PauseError(f"{vf.vf_id} not attached to {tenant.tid}")
    if not vf.pausable:
        raise PauseError(f"{vf.vf_id} is not pausable")

    # -- step 1: save config space (+ MSI state) ---------------------------
    t0 = time.perf_counter()
    state = tenant.export_state()
    payload = staging.save(state)
    specs = tenant.export_specs()
    snap = ConfigSpaceSnapshot(
        tenant_id=tenant.tid, steps_done=tenant.steps_done, payload=payload,
        sharding_desc=serialize_specs(specs),
        mesh_shape=tuple(vf.mesh_shape), mesh_axes=tuple(vf.mesh_axes),
        exec_keys=list(tenant._exec_cache.keys()),
        stats=staging.last_stats, compressed=staging.compression != "none")
    t.add("save_config_space", time.perf_counter() - t0)

    # -- step 2: unregister PCI ops (guest keeps emulated view) -------------
    t0 = time.perf_counter()
    tenant.suspend()
    vf.emulated["status"] = "paused"
    vf.emulated["steps_done"] = tenant.steps_done
    t.add("unregister_pci", time.perf_counter() - t0)

    # -- step 3: unregister VFIO / exit IOMMU group --------------------------
    t0 = time.perf_counter()
    for leaf in jax.tree.leaves(state):
        try:
            leaf.delete()
        except Exception:
            pass
    vf.transition(VFState.PAUSED)
    vf.release_devices()
    t.add("unregister_vfio", time.perf_counter() - t0)
    return snap, t


def unpause_vf(pool: DevicePool, vf: VirtualFunction, tenant: Tenant,
               snap: ConfigSpaceSnapshot, staging: StagingEngine,
               num_devices: int | None = None) -> PhaseTimings:
    t = PhaseTimings()
    if vf.state != VFState.PAUSED:
        raise PauseError(f"{vf.vf_id} is not paused")

    # -- step 1: restore I/O connections --------------------------------------
    t0 = time.perf_counter()
    if not vf.devices:
        import math
        pool.allocate(vf, num_devices or math.prod(snap.mesh_shape))
    shardings = tenant.shardings_for(vf)
    state = staging.restore(snap.payload, shardings)
    jax.block_until_ready(state)
    vf.transition(VFState.ATTACHED)
    t.add("restore_io", time.perf_counter() - t0)

    # -- step 2: restore config registers --------------------------------------
    t0 = time.perf_counter()
    tenant.steps_done = snap.steps_done
    tenant.resume(state, vf)
    vf.emulated["status"] = "running"
    t.add("restore_config", time.perf_counter() - t0)
    return t
