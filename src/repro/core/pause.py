"""The pause / unpause mechanism — the paper's novel contribution (§IV-B1).

pause (3 steps, mirroring the QEMU vfio-pci implementation):
  1. save the config space — stage the tenant's device state to host
     (StagingEngine = QDMA queues), capture sharding layout, progress
     counters and executable-cache keys (MSI-state analogue);
  2. unregister the PCI device ops — the tenant drops its device handles
     but keeps its emulated view: queries still answered, I/O raises;
  3. unregister the VFIO device — delete device buffers and release the
     VF's devices ("exit from the IOMMU group"), freeing the pool to be
     repartitioned while the guest still sees its (paused) device.

unpause (2 steps):
  1. restore I/O — reallocate a slice (possibly different devices/shape),
     place the staged state with the new shardings (resharding is free
     here: device_put scatters host data straight into the new layout);
  2. restore config registers — progress counters and executable keys back
     into the tenant; on the same slice the compiled step is a cache hit
     (no re-realize), which is exactly where the paper's ~2% win comes from.

pause_vf_live — the pre-copy variant (QEMU live-migration shape, §Perf
HC5): iterative pre-copy rounds snapshot state to host while the tenant
KEEPS STEPPING (the staging engine's per-tenant memo absorbs each round),
then a final short stop-and-copy moves only the leaves dirtied since the
last round. ``PhaseTimings.stop_ms`` isolates the tenant-visible stall
(the stop-and-copy) from ``total`` (which also counts the background
pre-copy rounds); for plain ``pause_vf`` the two coincide.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.core.fault import crashpoint
from repro.core.pool import DevicePool
from repro.core.snapshot import ConfigSpaceSnapshot, serialize_specs
from repro.core.staging import StagingEngine
from repro.core.tenant import Tenant
from repro.core.vf import VFState, VirtualFunction


@dataclasses.dataclass
class PhaseTimings:
    phases: dict = dataclasses.field(default_factory=dict)
    #: phases NOT visible to the tenant (pre-copy rounds run while it steps)
    background: set = dataclasses.field(default_factory=set)

    def add(self, name: str, seconds: float, *, stop: bool = True):
        self.phases[name] = self.phases.get(name, 0.0) + seconds
        if not stop:
            self.background.add(name)

    @property
    def total(self) -> float:
        return sum(self.phases.values())

    @property
    def stop_s(self) -> float:
        """Tenant-visible stall in seconds (excludes background phases)."""
        return sum(v for k, v in self.phases.items()
                   if k not in self.background)

    @property
    def stop_ms(self) -> float:
        return self.stop_s * 1e3


class PauseError(RuntimeError):
    pass


def validate_pausable(vf: VirtualFunction, tenant: Tenant):
    if vf.state != VFState.ATTACHED or vf.owner != tenant.tid:
        raise PauseError(f"{vf.vf_id} not attached to {tenant.tid}")
    if not vf.pausable:
        raise PauseError(f"{vf.vf_id} is not pausable")


def _stop_and_copy(vf: VirtualFunction, tenant: Tenant,
                   staging: StagingEngine, t: PhaseTimings, *,
                   incremental: Optional[bool] = None,
                   precopy_rounds: int = 0,
                   sink: Optional[dict] = None) -> ConfigSpaceSnapshot:
    """The tenant-visible part of every pause: save config space, then the
    paper's unregister steps. With a warm pre-copy memo the save moves only
    dirty leaves, which is what shrinks ``stop_ms``.

    ``sink`` (the manager's host-RAM snapshot table) is populated BEFORE
    the destructive suspend: from that moment on the snapshot is the
    tenant's second state copy, so a crash after ``tenant.suspend()`` can
    always be rolled forward from it (crash-consistency; see
    ``SVFFManager.recover``)."""
    # -- step 1: save config space (+ MSI state) ---------------------------
    t0 = time.perf_counter()
    state = tenant.export_state()
    payload = staging.save(state, tenant=tenant.tid,
                           incremental=incremental)
    specs = tenant.export_specs()
    snap = ConfigSpaceSnapshot(
        tenant_id=tenant.tid, steps_done=tenant.steps_done, payload=payload,
        sharding_desc=serialize_specs(specs),
        mesh_shape=tuple(vf.mesh_shape), mesh_axes=tuple(vf.mesh_axes),
        exec_keys=list(tenant._exec_cache.keys()),
        stats=staging.last_stats, compressed=staging.compression != "none",
        precopy_rounds=precopy_rounds)
    if sink is not None:
        sink[tenant.tid] = snap
    t.add("save_config_space", time.perf_counter() - t0)
    # crash window: snapshot registered, tenant still running untouched —
    # recovery rolls the pause BACK (drop the snapshot, nothing else moved)
    crashpoint("after_snapshot_register")

    # -- step 2: unregister PCI ops (guest keeps emulated view) -------------
    t0 = time.perf_counter()
    tenant.suspend()
    vf.emulated["status"] = "paused"
    vf.emulated["steps_done"] = tenant.steps_done
    t.add("unregister_pci", time.perf_counter() - t0)
    # crash window: tenant suspended but the VF still ATTACHED holding its
    # devices — recovery rolls the pause FORWARD from the registered snap
    crashpoint("after_suspend")

    # -- step 3: unregister VFIO / exit IOMMU group --------------------------
    t0 = time.perf_counter()
    for leaf in jax.tree.leaves(state):
        try:
            leaf.delete()
        except Exception:
            pass
    vf.transition(VFState.PAUSED)
    vf.release_devices()
    t.add("unregister_vfio", time.perf_counter() - t0)
    # the memo's device refs die with the VF; host copies live in the snap
    staging.clear(tenant.tid)
    return snap


def pause_vf(pool: DevicePool, vf: VirtualFunction, tenant: Tenant,
             staging: StagingEngine,
             sink: Optional[dict] = None) -> tuple[ConfigSpaceSnapshot,
                                                   PhaseTimings]:
    t = PhaseTimings()
    validate_pausable(vf, tenant)
    snap = _stop_and_copy(vf, tenant, staging, t, sink=sink)
    return snap, t


def pause_vf_live(pool: DevicePool, vf: VirtualFunction, tenant: Tenant,
                  staging: StagingEngine, *, rounds: int = 2,
                  step_fn: Optional[Callable[[], None]] = None,
                  sink: Optional[dict] = None
                  ) -> tuple[ConfigSpaceSnapshot, PhaseTimings]:
    """Pre-copy live pause. ``rounds`` background snapshot rounds run while
    the tenant keeps working (``step_fn`` is the tenant's own stepping,
    invoked between rounds to model concurrent progress); the final
    stop-and-copy then moves only leaves dirtied since the last round.
    Requires nothing of the tenant beyond the usual pause protocol.
    ``rounds`` is clamped to >= 1: a live pause with no background round
    is just ``pause_vf``, and would trip invariant I7's
    "live pause ran no background pre-copy" check."""
    t = PhaseTimings()
    validate_pausable(vf, tenant)
    rounds = max(1, rounds)
    for r in range(rounds):
        t0 = time.perf_counter()
        staging.save(tenant.export_state(), tenant=tenant.tid,
                     incremental=True)
        t.add(f"precopy_{r}", time.perf_counter() - t0, stop=False)
        # crash window: a pre-copy round landed in the memo, nothing
        # guest-visible moved — recovery discards the memo and rolls back
        crashpoint("mid_precopy_round")
        if step_fn is not None:
            step_fn()             # tenant work: not part of the pause at all
    snap = _stop_and_copy(vf, tenant, staging, t, incremental=True,
                          precopy_rounds=rounds, sink=sink)
    return snap, t


def unpause_vf(pool: DevicePool, vf: VirtualFunction, tenant: Tenant,
               snap: ConfigSpaceSnapshot, staging: StagingEngine,
               num_devices: int | None = None) -> PhaseTimings:
    t = PhaseTimings()
    if vf.state != VFState.PAUSED:
        raise PauseError(f"{vf.vf_id} is not paused")

    # -- step 1: restore I/O connections --------------------------------------
    t0 = time.perf_counter()
    if not vf.devices:
        import math
        pool.allocate(vf, num_devices or math.prod(snap.mesh_shape))
    # crash window: devices (re)allocated but nothing restored — recovery
    # rolls BACK (release the devices, keep the snapshot, stay paused)
    crashpoint("before_unpause_restore")
    shardings = tenant.shardings_for(vf)
    state = staging.restore(snap.payload, shardings)
    jax.block_until_ready(state)
    vf.transition(VFState.ATTACHED)
    # crash window: VF back to ATTACHED but the tenant not yet resumed —
    # recovery rolls FORWARD (redo the restore from the retained snapshot)
    crashpoint("after_unpause_restore")
    t.add("restore_io", time.perf_counter() - t0)

    # -- step 2: restore config registers --------------------------------------
    t0 = time.perf_counter()
    tenant.steps_done = snap.steps_done
    tenant.resume(state, vf)
    vf.emulated["status"] = "running"
    t.add("restore_config", time.perf_counter() - t0)
    return t
