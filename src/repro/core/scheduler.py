"""VF placement scheduler — admission control + placement policies.

The paper's SVFF manager attaches a VM to "the first detached VF" (the
libvirt behaviour). At fleet scale that is a placement *policy decision*,
so the manager delegates it here. A ``Scheduler`` answers two questions:

  admit(pool, tenants, request)   may this attach proceed at all?
                                  (raises AdmissionError with the reason)
  select(pool, tenants, request)  which detached VF gets the tenant?

Policies (``make_scheduler(name)`` / ``RunConfig.placement``):

  first_fit   first detached VF in PF table order — the paper/libvirt
              behaviour, and the default.
  best_fit    detached VF with the FEWEST devices that still satisfies
              ``min_devices`` (bin-packing by device count: keeps big
              slices free for big tenants).
  fair_share  detached VF whose device count is closest to the fair share
              ``pool devices / (occupied tenants + 1)`` — spreads capacity
              evenly across tenants.

All policies are deterministic (ties break in PF table order) so the
scenario simulator in ``repro.sim`` can replay placements from a seed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

from repro.core.pool import DevicePool, PoolError
from repro.core.vf import VFState, VirtualFunction


class AdmissionError(PoolError):
    """Attach rejected by admission control (no capacity / bad request)."""


class GangPlacementError(AdmissionError):
    """Gang attach rejected: fewer than K placeable VFs. Raised BEFORE any
    member binds, so a failed gang admission leaves no leaked VFs and no
    half-bound stages."""


@dataclasses.dataclass(frozen=True)
class PlacementRequest:
    """What a tenant asks of the scheduler."""
    tenant_id: str
    min_devices: int = 1


class Scheduler:
    """Base policy: candidate filtering + admission; subclasses rank."""

    name = "base"

    # -- candidate set ------------------------------------------------------
    @staticmethod
    def candidates(pool: DevicePool,
                   request: PlacementRequest) -> list[VirtualFunction]:
        """Detached, unowned VFs large enough for the request, in PF table
        order (dict insertion order == creation order)."""
        return [vf for vf in pool.vfs.values()
                if vf.state == VFState.DETACHED and vf.owner is None
                and len(vf.devices) >= request.min_devices]

    # -- admission control --------------------------------------------------
    def admit(self, pool: DevicePool, tenants: Dict[str, object],
              request: PlacementRequest) -> None:
        tn = tenants.get(request.tenant_id)
        if tn is not None and getattr(tn, "status", None) in ("running",
                                                             "paused"):
            raise AdmissionError(
                f"{request.tenant_id} already holds VF "
                f"{getattr(tn, 'vf_id', None)} ({tn.status})")
        if request.min_devices < 1:
            raise AdmissionError(
                f"{request.tenant_id}: min_devices must be >= 1")
        if not self.candidates(pool, request):
            raise AdmissionError(
                f"no detached VF with >= {request.min_devices} device(s) "
                f"for {request.tenant_id} (increase num_vfs via reconf)")

    # -- placement ----------------------------------------------------------
    def choose(self, pool: DevicePool, tenants: Dict[str, object],
               request: PlacementRequest,
               cands: Sequence[VirtualFunction]) -> VirtualFunction:
        raise NotImplementedError

    def select(self, pool: DevicePool, tenants: Dict[str, object],
               request: PlacementRequest) -> VirtualFunction:
        self.admit(pool, tenants, request)
        return self.choose(pool, tenants, request,
                           self.candidates(pool, request))

    # -- gang placement -----------------------------------------------------
    def admit_gang(self, pool: DevicePool, tenants: Dict[str, object],
                   requests: Sequence[PlacementRequest]) -> None:
        """Admission for an all-or-nothing gang of K attaches: every member
        must be individually admissible AND there must be K DISTINCT
        candidate VFs. Raises ``GangPlacementError`` without touching the
        pool — atomicity by validation-before-mutation."""
        if not requests:
            raise GangPlacementError("empty gang placement request")
        for req in requests:
            try:
                self.admit(pool, tenants, req)
            except AdmissionError as e:
                raise GangPlacementError(
                    f"gang of {len(requests)}: member "
                    f"{req.tenant_id} not admissible: {e}") from e
        # K distinct VFs must exist for the WIDEST min_devices ordering:
        # greedily match each request (largest demand first) to a distinct
        # candidate; any unmatched request fails the whole gang
        taken: set = set()
        for req in sorted(requests, key=lambda r: -r.min_devices):
            got = next((vf for vf in self.candidates(pool, req)
                        if vf.vf_id not in taken), None)
            if got is None:
                raise GangPlacementError(
                    f"gang of {len(requests)}: only {len(taken)} distinct "
                    f"VF(s) placeable, member {req.tenant_id} "
                    f"(min_devices={req.min_devices}) has none left")
            taken.add(got.vf_id)

    def select_gang(self, pool: DevicePool, tenants: Dict[str, object],
                    requests: Sequence[PlacementRequest]
                    ) -> list[VirtualFunction]:
        """Pick K distinct VFs for a gang, in request order, using the
        policy's ``choose`` restricted to not-yet-taken candidates. Calls
        ``admit_gang`` first, so failure is typed and side-effect-free."""
        self.admit_gang(pool, tenants, requests)
        picks: list[VirtualFunction] = []
        taken: set = set()
        for req in requests:
            cands = [vf for vf in self.candidates(pool, req)
                     if vf.vf_id not in taken]
            vf = self.choose(pool, tenants, req, cands)
            picks.append(vf)
            taken.add(vf.vf_id)
        return picks

    def describe(self) -> dict:
        return {"policy": self.name}


class FirstFitScheduler(Scheduler):
    """PF table order — the paper's 'first detached VF' scan."""

    name = "first_fit"

    def choose(self, pool, tenants, request, cands):
        return cands[0]


class BestFitScheduler(Scheduler):
    """Smallest sufficient slice (bin-packing by device count)."""

    name = "best_fit"

    def choose(self, pool, tenants, request, cands):
        return min(cands, key=lambda vf: len(vf.devices))


class FairShareScheduler(Scheduler):
    """Slice closest to the per-tenant fair share of pool devices."""

    name = "fair_share"

    def choose(self, pool, tenants, request, cands):
        occupied = sum(1 for vf in pool.vfs.values()
                       if vf.owner is not None)
        share = pool.num_devices / (occupied + 1)
        return min(cands, key=lambda vf: abs(len(vf.devices) - share))


_POLICIES = {cls.name: cls for cls in
             (FirstFitScheduler, BestFitScheduler, FairShareScheduler)}
POLICY_NAMES = tuple(sorted(_POLICIES))
_INSTANCES: dict[str, Scheduler] = {}


def make_scheduler(policy: str) -> Scheduler:
    """Policy name -> (cached, stateless) scheduler instance."""
    if policy not in _POLICIES:
        raise KeyError(f"unknown placement policy {policy!r}; "
                       f"have {list(POLICY_NAMES)}")
    if policy not in _INSTANCES:
        _INSTANCES[policy] = _POLICIES[policy]()
    return _INSTANCES[policy]


# --------------------------------------------------------------------------
# cross-host admission routing (federation plane)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HostCandidate:
    """One host's slice of the routing decision: built from its replicated
    telemetry snapshot (``FederationCoordinator`` filters out hosts whose
    lease lapsed or whose snapshot is older than the staleness bound, so
    routing never acts on dead or stale evidence)."""
    host_id: str
    load: int                   # admitted work units (queued + in flight)
    capacity: int               # serving slots across the host's engines

    @property
    def headroom(self) -> int:
        return self.capacity - self.load


def choose_host(policy: str, candidates: Sequence[HostCandidate],
                need: int = 1) -> HostCandidate:
    """Route one admission across hosts with the SAME three policy names
    the VF scheduler uses, lifted to host granularity (deterministic,
    ties break in the candidates' given order — the coordinator passes
    hosts sorted by host_id):

      first_fit   first host with ``headroom >= need``
      best_fit    smallest sufficient headroom (pack hosts tightly; keeps
                  big headroom free for bursts)
      fair_share  largest headroom (spread load evenly)

    Raises ``AdmissionError`` when no live host has room."""
    if policy not in _POLICIES:
        raise KeyError(f"unknown placement policy {policy!r}; "
                       f"have {list(POLICY_NAMES)}")
    fits = [c for c in candidates if c.headroom >= need]
    if not fits:
        raise AdmissionError(
            f"no live host with headroom >= {need} "
            f"(candidates {[(c.host_id, c.headroom) for c in candidates]})")
    if policy == "first_fit":
        return fits[0]
    if policy == "best_fit":
        return min(fits, key=lambda c: c.headroom)
    return max(fits, key=lambda c: c.headroom)          # fair_share
