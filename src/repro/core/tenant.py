"""Tenant — the VM/guest analogue.

A tenant owns a *logical* workload (training loop or serving engine) and
never touches physical devices directly: binding is the Manager/VF's job.
Its step code is byte-identical across reconfigurations ("no driver
modification on the guest", paper §III). While PAUSED it keeps answering
queries from its emulated view (the guest still sees the device, fig. 2
right panel) but actual work raises DevicePausedError — "can not do any
actual I/O operations until the device is unpaused".
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MeshConfig, RunConfig
from repro.core.vf import VirtualFunction
from repro.data.pipeline import SyntheticSource
from repro.runtime.partitioning import ShardingRules
from repro.train.step import (batch_specs, init_train_state, make_train_step,
                              train_state_specs)


class DevicePausedError(RuntimeError):
    """I/O attempted on a paused device."""


class Tenant:
    def __init__(self, tid: str, run: RunConfig, *, workload: str = "train",
                 local_batch: int = 4, seq_len: int = 32, seed: int = 0):
        assert workload in ("train", "serve")
        self.tid = tid
        self.run = run.replace(seed=seed)
        self.workload = workload
        self.status = "created"        # created|running|paused|detached
        self.vf_id: Optional[str] = None
        self.steps_done = 0
        self._state = None             # device pytree while attached
        self._rules: Optional[ShardingRules] = None
        self._mesh = None
        self._exec_cache: dict = {}    # (kind, mesh_shape) -> compiled fn
        self._local_batch = local_batch
        self._seq = seq_len
        self._source = SyntheticSource(self.run, batch_override=local_batch,
                                       seq_override=seq_len)
        self.step_times: list[float] = []
        self._fail_next = False        # fault-injection hook (tests)

    # ------------------------------------------------------------------ utils
    def _make_rules(self, vf: VirtualFunction) -> ShardingRules:
        mesh_cfg = MeshConfig(tuple(vf.mesh_shape), tuple(vf.mesh_axes))
        return ShardingRules(mesh_cfg, self.run, vf.mesh())

    def state_shardings(self, rules: ShardingRules):
        specs = train_state_specs(self.run, rules)
        return rules.named(specs)

    # -- manager/pause protocol (duck-typed; repro.sim substitutes these) ----
    def shardings_for(self, vf: VirtualFunction):
        """Target shardings for placing this tenant's state on ``vf``."""
        return self.state_shardings(self._make_rules(vf))

    def state_template(self):
        """Shape-only pytree matching export_state (checkpoint restore)."""
        from repro.train.step import train_state_shapes
        return train_state_shapes(self.run)

    def export_specs(self):
        """PartitionSpec tree of the current layout (config-space save)."""
        return train_state_specs(self.run, self._rules)

    # --------------------------------------------------------------- lifecycle
    def bind(self, vf: VirtualFunction, state=None, *,
             flash: bool = True) -> float:
        """Attach to a VF slice: place (or adopt restored) state, ensure a
        compiled step executable exists ("bitstream flash" on first bind).
        Returns seconds spent compiling (0.0 on executable-cache hit)."""
        rules = self._make_rules(vf)
        self._rules = rules
        self._mesh = vf.mesh()
        if state is not None:
            self._state = state
        elif self._state is None:
            shardings = self.state_shardings(rules)
            rng = jax.random.key(self.run.seed)
            self._state = jax.jit(
                lambda r: init_train_state(self.run, r),
                out_shardings=shardings)(rng)
            jax.block_until_ready(self._state)
        compile_s = 0.0
        # Executable cache ("bitstream cache"): compiled code is bound to
        # the physical devices, so the key includes the slice identity — an
        # unpause onto the same slice is a cache hit (the paper's "skips
        # some of the realize operations"); migration to new devices pays
        # an honest recompile.
        key = (self.workload, tuple(vf.mesh_shape),
               tuple(d.id for d in vf.devices))
        if key not in self._exec_cache:
            t0 = time.perf_counter()
            step = make_train_step(self.run, rules)
            # batch shardings from the tenant's ACTUAL batch shapes (its
            # local batch may not divide a larger slice's data axis)
            from jax.sharding import PartitionSpec as P
            sample = self._source.batch_at(0)
            bspecs = rules.named({
                k: P(rules._fit(v.shape[0], rules.dp_axes),
                     *([None] * (v.ndim - 1)))
                for k, v in sample.items()})
            # pin state shardings on BOTH sides: the state must round-trip
            # through the executable bit-stable (otherwise XLA may re-lay
            # it out and the next call mismatches)
            sshard = self.state_shardings(rules)
            fn = jax.jit(step, in_shardings=(sshard, bspecs),
                         out_shardings=(sshard, None))
            if flash:   # eager compile = the "flash the bitstream" step
                batch = self._place_batch(self._source.batch_at(0), bspecs)
                fn = fn.lower(self._state, batch).compile()
            self._exec_cache[key] = (fn, bspecs)
            compile_s = time.perf_counter() - t0
        self._active_key = key
        self.vf_id = vf.vf_id
        self.status = "running"
        vf.emulated.update({"tenant": self.tid, "status": "running",
                            "steps_done": self.steps_done})
        return compile_s

    def _place_batch(self, batch, bspecs):
        return {k: jax.device_put(v, bspecs[k]) for k, v in batch.items()}

    # -- guest-visible work (the unmodified driver) -----------------------------
    def run_steps(self, n: int = 1) -> dict:
        if self.status == "paused":
            raise DevicePausedError(
                f"{self.tid}: device {self.vf_id} is paused")
        if self.status != "running":
            raise RuntimeError(f"{self.tid}: no device attached")
        if self._fail_next:
            self._fail_next = False
            raise RuntimeError(f"{self.tid}: injected device failure")
        fn, bspecs = self._exec_cache[self._active_key]
        metrics = {}
        for _ in range(n):
            t0 = time.perf_counter()
            batch = self._place_batch(self._source.batch_at(self.steps_done),
                                      bspecs)
            self._state, metrics = fn(self._state, batch)
            jax.block_until_ready(self._state)
            self.steps_done += 1
            self.step_times.append(time.perf_counter() - t0)
        return {k: float(v) for k, v in metrics.items()}

    # -- pause plumbing (called by core.pause, not by guests) --------------------
    def export_state(self):
        return self._state

    def suspend(self):
        """Paper step 2: unregister host-side handles; the guest keeps its
        emulated view (status queries still answered)."""
        self._state = None
        self._mesh = None
        self.status = "paused"

    def resume(self, state, vf: VirtualFunction):
        self._state = state
        self.status = "running"
        self.bind(vf, state=state)

    def detach(self):
        self._state = None
        self._mesh = None
        self._rules = None
        self.vf_id = None
        self.status = "detached"

    # -- guest-visible introspection (works while paused: emulated view) ---------
    def query(self) -> dict:
        return {"tenant": self.tid, "status": self.status,
                "vf": self.vf_id, "steps_done": self.steps_done,
                "workload": self.workload,
                "exec_keys": [list(map(str, k)) for k in self._exec_cache]}

    def loss(self) -> Optional[float]:
        return None

    def inject_failure(self):
        self._fail_next = True
