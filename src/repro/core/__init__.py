"""SVFF core — the paper's contribution as a composable module.

DevicePool (PF) -> VirtualFunction slices -> Tenants (VMs), with the novel
pause/unpause mechanism, init/reconf automation, QMP-style control plane,
and fault-tolerance built on the same snapshot machinery.
"""
from repro.core.autoscaler import (Autoscaler, AutoscaleAction,
                                   AutoscaleConfig, EngineStats,
                                   TelemetrySnapshot, justify_action)
from repro.core.fault import (CrashPlane, HeartbeatMonitor, InjectedCrash,
                              Supervisor, crash_plane, crashpoint)
from repro.core.journal import OpJournal
from repro.core.manager import ManagerError, SVFFManager, UnknownTenantError
from repro.core.pause import (PauseError, PhaseTimings, pause_vf,
                              pause_vf_live, unpause_vf)
from repro.core.pool import DevicePool, PoolError
from repro.core.qmp import ControlPlane
from repro.core.records import RecordStore
from repro.core.scheduler import (AdmissionError, PlacementRequest,
                                  Scheduler, make_scheduler, POLICY_NAMES)
from repro.core.snapshot import ConfigSpaceSnapshot
from repro.core.staging import StagingEngine, TransferStats
from repro.core.tenant import DevicePausedError, Tenant
from repro.core.vf import VFState, VFTransitionError, VirtualFunction

__all__ = [
    "AdmissionError", "Autoscaler", "AutoscaleAction", "AutoscaleConfig",
    "ConfigSpaceSnapshot", "ControlPlane", "CrashPlane", "EngineStats",
    "TelemetrySnapshot", "justify_action",
    "DevicePausedError", "DevicePool", "HeartbeatMonitor", "InjectedCrash",
    "ManagerError", "OpJournal", "PauseError", "PhaseTimings",
    "PlacementRequest", "PoolError", "POLICY_NAMES", "RecordStore",
    "SVFFManager", "Scheduler", "StagingEngine", "Supervisor", "Tenant",
    "TransferStats", "UnknownTenantError", "VFState", "VFTransitionError",
    "VirtualFunction", "crash_plane", "crashpoint", "make_scheduler",
    "pause_vf", "pause_vf_live", "unpause_vf",
]
