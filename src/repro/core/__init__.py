"""SVFF core — the paper's contribution as a composable module.

DevicePool (PF) -> VirtualFunction slices -> Tenants (VMs), with the novel
pause/unpause mechanism, init/reconf automation, QMP-style control plane,
fault-tolerance built on the same snapshot machinery, and (PR 10) the
federated multi-host control plane (``Host`` / ``FederationCoordinator``).

This package is also the canonical home of the typed error hierarchy:
every error a caller may want to catch — manager, scheduler, paged-KV and
federation alike — is importable from ``repro.core`` directly (the classes
live in leaf module ``repro.core.errors`` plus ``repro.core.scheduler``;
historic homes such as ``repro.core.manager.ManagerError`` and
``repro.serve.paged.DoubleFreeError`` remain as re-exports).
"""
from repro.core.autoscaler import (Autoscaler, AutoscaleAction,
                                   AutoscaleConfig, EngineStats,
                                   TelemetrySnapshot, justify_action)
from repro.core.errors import (DoubleFreeError, FederationError,
                               HostUnreachableError, LeaseExpiredError,
                               ManagerError, SplitBrainError,
                               UnknownRequestError, UnknownTenantError)
from repro.core.fault import (CrashPlane, HeartbeatMonitor, InjectedCrash,
                              Supervisor, crash_plane, crashpoint)
from repro.core.federation import (Fabric, FederationCoordinator, Lease,
                                   RemoteTenant)
from repro.core.host import Host, HostTelemetry
from repro.core.journal import OpJournal
from repro.core.manager import SVFFManager
from repro.core.pause import (PauseError, PhaseTimings, pause_vf,
                              pause_vf_live, unpause_vf)
from repro.core.pool import DevicePool, PoolError
from repro.core.qmp import ControlPlane
from repro.core.records import RecordStore
from repro.core.scheduler import (AdmissionError, GangPlacementError,
                                  HostCandidate, PlacementRequest,
                                  Scheduler, choose_host, make_scheduler,
                                  POLICY_NAMES)
from repro.core.snapshot import ConfigSpaceSnapshot
from repro.core.staging import StagingEngine, TransferStats
from repro.core.tenant import DevicePausedError, Tenant
from repro.core.vf import VFState, VFTransitionError, VirtualFunction

__all__ = [
    "AdmissionError", "Autoscaler", "AutoscaleAction", "AutoscaleConfig",
    "ConfigSpaceSnapshot", "ControlPlane", "CrashPlane", "DoubleFreeError",
    "EngineStats", "Fabric", "FederationCoordinator", "FederationError",
    "GangPlacementError", "Host", "HostCandidate", "HostTelemetry",
    "HostUnreachableError", "Lease", "LeaseExpiredError",
    "TelemetrySnapshot", "justify_action",
    "DevicePausedError", "DevicePool", "HeartbeatMonitor", "InjectedCrash",
    "ManagerError", "OpJournal", "PauseError", "PhaseTimings",
    "PlacementRequest", "PoolError", "POLICY_NAMES", "RecordStore",
    "RemoteTenant", "SVFFManager", "Scheduler", "SplitBrainError",
    "StagingEngine", "Supervisor", "Tenant",
    "TransferStats", "UnknownRequestError", "UnknownTenantError",
    "VFState", "VFTransitionError",
    "VirtualFunction", "choose_host", "crash_plane", "crashpoint",
    "make_scheduler", "pause_vf", "pause_vf_live", "unpause_vf",
]
