"""StagingEngine — the Xilinx QDMA analogue (paper §IV-A).

QDMA moves VF memory between device and host through descriptor queues.
Here the engine moves tenant state pytrees HBM<->host through a pool of
transfer queues (threaded device_get/device_put streams), with an optional
on-device pack stage (``qdma_pack`` kernel: blockwise int8 quantization)
that shrinks the bytes crossing the slow link — the TPU-native rendering of
"DMA optimized for high bandwidth transfers".

Compression is OFF by default: the paper-faithful pause path is bit-exact.
The int8 path is the beyond-paper optimization measured in EXPERIMENTS.md
§Perf (pause-path hillclimb).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import time
from typing import Any, Optional

import jax
import numpy as np


@dataclasses.dataclass
class TransferStats:
    bytes_moved: int = 0
    logical_bytes: int = 0
    seconds: float = 0.0
    num_leaves: int = 0
    queues: int = 1

    @property
    def bandwidth_gbps(self) -> float:
        return self.bytes_moved / max(self.seconds, 1e-9) / 1e9


@dataclasses.dataclass
class QuantizedLeaf:
    """Host-side packed leaf: blockwise int8 + per-block scales."""
    q: np.ndarray                     # int8, original shape
    scale: np.ndarray                 # fp32, shape[:-1] + (blocks,)
    dtype: str
    block: int


def _nbytes(x) -> int:
    if isinstance(x, QuantizedLeaf):
        return x.q.nbytes + x.scale.nbytes
    return np.asarray(x).nbytes


class StagingEngine:
    def __init__(self, num_queues: int = 8, compression: str = "none",
                 block: int = 256, min_quant_size: int = 4096,
                 incremental: bool = False):
        assert compression in ("none", "int8")
        self.num_queues = num_queues
        self.compression = compression
        self.block = block
        self.min_quant_size = min_quant_size
        # incremental snapshots (§Perf HC3): leaves that are the SAME device
        # array object as in the previous save are not re-transferred (their
        # host copy is reused). Sound because jax arrays are immutable —
        # identity implies identical contents. Serving tenants hit this for
        # their params (only the KV cache changes between pauses).
        self.incremental = incremental
        self._memo: dict = {}
        self.last_stats: Optional[TransferStats] = None

    # -- device -> host (pause / checkpoint) -----------------------------------
    def save(self, tree: Any) -> Any:
        from repro.kernels import ops as kops
        t0 = time.perf_counter()
        flat_p, treedef = jax.tree_util.tree_flatten_with_path(tree)
        logical = sum(_nbytes(jax.device_get(x)) if not isinstance(
            x, jax.Array) else x.nbytes for _, x in flat_p)
        skipped = 0

        def fetch(path_x):
            nonlocal skipped
            path, x = path_x
            key = jax.tree_util.keystr(path)
            if (self.incremental and isinstance(x, jax.Array)):
                prev = self._memo.get(key)
                if prev is not None and prev[0] is x:
                    skipped += _nbytes(prev[1])
                    return prev[1]                      # identical array
            if (self.compression == "int8" and isinstance(x, jax.Array)
                    and x.dtype in (np.dtype("float32"), np.dtype("bfloat16"))
                    and x.size >= self.min_quant_size
                    and x.shape[-1] % self.block == 0):
                q, scale = kops.qdma_pack(x, block=self.block)
                host = QuantizedLeaf(q=np.asarray(jax.device_get(q)),
                                     scale=np.asarray(jax.device_get(scale)),
                                     dtype=str(x.dtype), block=self.block)
            else:
                host = np.asarray(jax.device_get(x))
            if self.incremental and isinstance(x, jax.Array):
                self._memo[key] = (x, host)
            return host

        # QDMA-style queues: round-robin leaves over transfer streams
        with cf.ThreadPoolExecutor(max_workers=self.num_queues) as ex:
            host_flat = list(ex.map(fetch, flat_p))
        dt = time.perf_counter() - t0
        moved = sum(_nbytes(x) for x in host_flat) - skipped
        self.last_stats = TransferStats(
            bytes_moved=moved, logical_bytes=logical, seconds=dt,
            num_leaves=len(host_flat), queues=self.num_queues)
        return jax.tree_util.tree_unflatten(treedef, [
            _Opaque(x) if isinstance(x, QuantizedLeaf) else x
            for x in host_flat])

    # -- host -> device (unpause / restore) -------------------------------------
    def restore(self, staged: Any, shardings: Any = None) -> Any:
        from repro.kernels import ops as kops
        t0 = time.perf_counter()
        flat, treedef = jax.tree_util.tree_flatten(
            staged, is_leaf=lambda x: isinstance(x, _Opaque))
        if shardings is not None:
            sflat = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda s: hasattr(s, "device_set"))
            assert len(sflat) == len(flat), (len(sflat), len(flat))
        else:
            sflat = [None] * len(flat)

        def place(args):
            x, sh = args
            if isinstance(x, _Opaque):
                ql: QuantizedLeaf = x.leaf
                q = jax.device_put(ql.q, sh)
                scale = jax.device_put(
                    ql.scale, None if sh is None else _scale_sharding(sh))
                return kops.qdma_unpack(q, scale, dtype=ql.dtype)
            return jax.device_put(x, sh)

        with cf.ThreadPoolExecutor(max_workers=self.num_queues) as ex:
            dev_flat = list(ex.map(place, zip(flat, sflat)))
        dt = time.perf_counter() - t0
        self.last_stats = TransferStats(
            bytes_moved=sum(_nbytes(x.leaf if isinstance(x, _Opaque) else x)
                            for x in flat),
            logical_bytes=sum(x.nbytes for x in dev_flat),
            seconds=dt, num_leaves=len(dev_flat), queues=self.num_queues)
        return jax.tree_util.tree_unflatten(treedef, dev_flat)


class _Opaque:
    """Wrapper so a QuantizedLeaf traverses pytrees as a single leaf."""
    def __init__(self, leaf: QuantizedLeaf):
        self.leaf = leaf


def _scale_sharding(sh):
    """Scales have one fewer trailing dim granularity; replicate for
    simplicity (they are tiny)."""
    try:
        from jax.sharding import NamedSharding, PartitionSpec
        if isinstance(sh, NamedSharding):
            return NamedSharding(sh.mesh, PartitionSpec())
    except Exception:
        pass
    return None
