"""StagingEngine — the Xilinx QDMA analogue (paper §IV-A).

QDMA moves VF memory between device and host through descriptor queues.
Here the engine moves tenant state pytrees HBM<->host through a pipelined
descriptor engine: leaves are split into fixed-size row-chunk DESCRIPTORS
(so one huge leaf no longer serializes a single queue), and each descriptor
flows through an overlapped 3-stage pipeline:

  save:     on-device pack (``qdma_pack_rows``: blockwise int8, or a plain
            device-side row slice) -> D2H over ``num_queues`` transfer
            streams -> host assemble into the leaf's output buffer
  restore:  host burst -> H2D (batched ``device_put`` per queue) ->
            on-device unpack / concatenate

Every pack/slice for descriptor i+1 is dispatched before descriptor i's
D2H completes (jax dispatch is asynchronous), which is the double-buffering
of the QDMA descriptor ring: the device prepares the next descriptor while
the previous one crosses the link.

Transports (``transport=``):
  borrow   host-device grids (CPU backend): ``device_get`` BORROWS the
           device buffer zero-copy, so non-packed descriptors of one leaf
           are coalesced into a single borrow — forcing row-chunk copies
           there would only add memcpys. Packed descriptors still stream
           chunk-granular (the pack kernel writes fresh buffers anyway).
  stream   real accelerators: every descriptor is an explicit device-side
           row slice D2H'd independently, so all queues stay busy
           regardless of tree shape.
  auto     borrow on the CPU backend, stream elsewhere.

Dirty tracking (``incremental=True``):
  identity  a leaf that is the SAME immutable jax array object as in the
            previous save is not re-transferred (its host copy is reused).
  digest    additionally, mutated-but-EQUAL leaves are skipped via a cheap
            on-device content fingerprint (``qdma_digest``; crc32 for host
            numpy leaves) — this is what makes pre-copy live pause cheap:
            the final stop-and-copy moves only leaves whose bytes actually
            changed since the last pre-copy round.
The memo is scoped PER TENANT (``save(tree, tenant=...)``) and released
via ``clear(tenant)`` — the manager calls it on detach and after pause, so
the memo cannot grow without bound across tenants.

Compression is OFF by default: the paper-faithful pause path is bit-exact.
The int8 path is the beyond-paper optimization measured in EXPERIMENTS.md
§Perf (pause-path hillclimb, HC1-HC5).

``pipeline=False`` preserves the PR-1 engine (whole-leaf round-robin over
queues) as the benchmark baseline — see ``benchmarks/pause_path.py``.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import math
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np

from repro.core.fault import crashpoint

_GLOBAL = "__global__"


@dataclasses.dataclass
class TransferStats:
    bytes_moved: int = 0        # host-repr bytes that crossed the link
    logical_bytes: int = 0      # unpacked logical bytes of the tree
    seconds: float = 0.0
    num_leaves: int = 0
    queues: int = 1
    skipped_bytes: int = 0      # host-repr bytes reused from the memo
    num_descriptors: int = 0
    transport: str = "borrow"

    @property
    def bandwidth_gbps(self) -> float:
        return self.bytes_moved / max(self.seconds, 1e-9) / 1e9


@dataclasses.dataclass
class QuantizedLeaf:
    """Host-side packed leaf: blockwise int8 + per-block scales."""
    q: np.ndarray                     # int8, original shape
    scale: np.ndarray                 # fp32, shape[:-1] + (blocks,)
    dtype: str
    block: int


class _Opaque:
    """Wrapper so a QuantizedLeaf traverses pytrees as a single leaf."""
    def __init__(self, leaf: QuantizedLeaf):
        self.leaf = leaf


def _nbytes(x) -> int:
    """Host-representation bytes — the symmetric save/restore unit of
    account: a quantized leaf counts its packed q+scale bytes, once."""
    if isinstance(x, _Opaque):
        x = x.leaf
    if isinstance(x, QuantizedLeaf):
        return x.q.nbytes + x.scale.nbytes
    return np.asarray(x).nbytes


@dataclasses.dataclass
class _Memo:
    ref: Any            # device array object (identity check) or None
    digest: Any         # content fingerprint tuple or None
    host: Any           # host copy (ndarray or QuantizedLeaf)


@dataclasses.dataclass
class _Descriptor:
    leaf: int           # flat leaf index
    chunk: int
    lo: int             # row range in the leaf's 2-D (rows, L) view
    rows: int
    nbytes: int         # estimated D2H bytes (queue balancing)
    packed: bool
    dev: Any = None     # device array / (q, scale) awaiting D2H
    host: Any = None    # fetched host buffer(s)


class StagingEngine:
    def __init__(self, num_queues: int = 8, compression: str = "none",
                 block: int = 256, min_quant_size: int = 4096,
                 incremental: bool = False, pipeline: bool = True,
                 chunk_bytes: int = 32 << 20, transport: str = "auto",
                 dirty: str = "identity"):
        assert compression in ("none", "int8")
        assert transport in ("auto", "borrow", "stream")
        assert dirty in ("identity", "digest")
        self.num_queues = num_queues
        self.compression = compression
        self.block = block
        self.min_quant_size = min_quant_size
        self.incremental = incremental
        self.pipeline = pipeline
        self.chunk_bytes = max(1, int(chunk_bytes))
        self.transport = transport
        self.dirty = dirty
        self._memos: dict[str, dict[str, _Memo]] = {}
        self._pool: Optional[cf.ThreadPoolExecutor] = None
        self.last_stats: Optional[TransferStats] = None

    # -- memo (per-tenant incremental state) -----------------------------------
    def _memo_for(self, tenant: Optional[str]) -> dict:
        return self._memos.setdefault(tenant or _GLOBAL, {})

    def memo_size(self, tenant: Optional[str] = None) -> int:
        if tenant is None:
            return sum(len(m) for m in self._memos.values())
        return len(self._memos.get(tenant or _GLOBAL, {}))

    def clear(self, tenant: Optional[str] = None) -> None:
        """Drop incremental-snapshot state. ``clear(tid)`` releases one
        tenant's memo (called by the manager on detach and after pause);
        ``clear()`` drops everything."""
        if tenant is None:
            self._memos.clear()
        else:
            self._memos.pop(tenant, None)

    def _digest_dispatch(self, x):
        """Start a digest: for device leaves the kernel is dispatched
        asynchronously (the (2,) uint32 result is materialized later by
        ``_digest_finalize``), so many leaves' digests run concurrently
        and overlap the first D2H bursts."""
        if isinstance(x, jax.Array):
            from repro.kernels import ops as kops
            return ["dev", x.shape, str(x.dtype), kops.qdma_digest(x)]
        a = np.ascontiguousarray(np.asarray(x))
        try:
            crc = zlib.crc32(a)             # buffer protocol: no copy
        except (TypeError, ValueError, BufferError):
            crc = zlib.crc32(a.tobytes())   # exotic dtypes (e.g. bf16)
        return ("crc", a.shape, str(a.dtype), crc)

    @staticmethod
    def _digest_finalize(dg):
        if isinstance(dg, list):          # pending device digest
            return ("dev", dg[1], dg[2],
                    tuple(int(v) for v in np.asarray(dg[3])))
        return dg

    def _digest(self, x):
        return self._digest_finalize(self._digest_dispatch(x))

    def _memo_hit(self, memo: dict, key: str, x, incremental: bool,
                  digest=None):
        """(host copy of x if it provably hasn't changed since the last
        save, else None; digest of x if one was computed — callers hand it
        back to ``_memo_put`` so a missed leaf is digested exactly once).
        ``digest`` lets the pipelined save pass a pre-dispatched digest."""
        if not incremental:
            return None, None
        e = memo.get(key)
        if e is not None and isinstance(x, jax.Array) and e.ref is x:
            return e.host, e.digest   # immutable: identity => equal bytes
        dg = None
        if self.dirty == "digest" and isinstance(x, (jax.Array, np.ndarray)):
            dg = self._digest_finalize(
                digest if digest is not None else self._digest_dispatch(x))
            if e is not None and e.digest is not None and dg == e.digest:
                # refresh the entry: the next save of this same object is
                # a free identity hit, and the superseded device array is
                # released instead of staying pinned by the stale ref
                memo[key] = _Memo(ref=x if isinstance(x, jax.Array)
                                  else None, host=e.host, digest=dg)
                return e.host, dg
        return None, dg

    def _memo_put(self, memo, key, x, host, incremental: bool, digest=None):
        if not incremental:
            return
        if isinstance(x, jax.Array):
            memo[key] = _Memo(ref=x, host=host, digest=digest)
        elif self.dirty == "digest" and isinstance(x, np.ndarray):
            memo[key] = _Memo(ref=None, host=host, digest=digest)

    # -- execution helpers ------------------------------------------------------
    def _transport_mode(self) -> str:
        if self.transport != "auto":
            return self.transport
        return "borrow" if jax.default_backend() == "cpu" else "stream"

    def _executor(self) -> cf.ThreadPoolExecutor:
        if self._pool is None:
            self._pool = cf.ThreadPoolExecutor(
                max_workers=max(1, self.num_queues),
                thread_name_prefix="qdma")
        return self._pool

    def close(self) -> None:
        """Join the transfer-queue threads. Safe to call repeatedly; the
        engine lazily respawns them if used again."""
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    def _row_chunks(self, nbytes: int, R: int) -> list[tuple[int, int]]:
        """Split R rows into [lo, hi) descriptor ranges of ~chunk_bytes
        each (single whole-leaf range when chunking can't help)."""
        n = 1
        if R > 1 and nbytes > self.chunk_bytes:
            n = min(R, math.ceil(nbytes / self.chunk_bytes))
        return [(R * c // n, R * (c + 1) // n) for c in range(n)]

    @staticmethod
    def _row_view_dims(x) -> tuple[int, int]:
        """(rows, L) of the 2-D row view of a leaf (scalars: (1, 1))."""
        L = x.shape[-1] if x.ndim else 1
        return ((x.size // L) if L else 0), L

    def _balance(self, items, nq, weight):
        """Greedy longest-processing-time split of items over nq queues."""
        queues = [[] for _ in range(nq)]
        load = [0] * nq
        for it in sorted(items, key=weight, reverse=True):
            i = load.index(min(load))
            queues[i].append(it)
            load[i] += weight(it)
        return [q for q in queues if q]

    # -- device -> host (pause / checkpoint) -----------------------------------
    def save(self, tree: Any, tenant: Optional[str] = None,
             incremental: Optional[bool] = None) -> Any:
        if not self.pipeline:
            return self._save_legacy(tree, tenant, incremental)
        from repro.kernels import ops as kops
        incremental = self.incremental if incremental is None else incremental
        transport = self._transport_mode()
        t0 = time.perf_counter()
        flat_p, treedef = jax.tree_util.tree_flatten_with_path(tree)
        memo = self._memo_for(tenant)
        n = len(flat_p)
        host_flat: list = [None] * n
        logical = skipped = 0
        descs: list[_Descriptor] = []
        digests: dict[int, Any] = {}    # leaf idx -> digest computed at miss
        # transactional publication: memo writes are BUFFERED here and
        # committed only once the whole snapshot is assembled — a crash
        # mid-save (InjectedCrash or real) leaves the memo, and therefore
        # every future incremental save, exactly as before this save
        memo_puts: list = []            # (key, x, host, digest)

        # -- stage -1: pre-dispatch digest kernels for identity misses so
        # they all run concurrently on device (finalized leaf-by-leaf in
        # stage 0, overlapping the first D2H bursts)
        pending: dict[int, Any] = {}
        if incremental and self.dirty == "digest":
            for i, (path, x) in enumerate(flat_p):
                if isinstance(x, jax.Array):
                    e = memo.get(jax.tree_util.keystr(path))
                    if e is None or e.ref is not x:
                        pending[i] = self._digest_dispatch(x)

        # -- stage 0: dirty filter + stage 1: descriptor dispatch (async) ----
        for i, (path, x) in enumerate(flat_p):
            key = jax.tree_util.keystr(path)
            logical += x.nbytes if isinstance(x, jax.Array) else _nbytes(x)
            hit, digests[i] = self._memo_hit(memo, key, x, incremental,
                                             digest=pending.get(i))
            if hit is not None:
                host_flat[i] = hit
                skipped += _nbytes(hit)
                continue
            if not isinstance(x, jax.Array):
                # materialize a real copy: a pause snapshot is the
                # tenant's ONLY state copy, so it must not alias a host
                # buffer the tenant may later mutate in place
                host = np.array(x)
                host_flat[i] = host
                memo_puts.append((key, x, host, digests[i]))
                continue
            descs.extend(self._dispatch_leaf(i, x, transport, kops))

        # -- stage 2: D2H descriptor queues (burst-batched device_get) --------
        bursts = self._balance(descs, max(1, min(self.num_queues,
                                                 len(descs) or 1)),
                               lambda d: d.nbytes)

        # crash window: descriptors dispatched (and host leaves staged)
        # but the D2H queues have not drained — the half-built snapshot
        # and its buffered memo updates must never become observable
        crashpoint("mid_pipeline_chunk")

        def fetch(burst):
            got = jax.device_get([d.dev for d in burst])
            for d, h in zip(burst, got):
                d.host = h
        if len(bursts) <= 1:
            for b in bursts:
                fetch(b)
        else:
            list(self._executor().map(fetch, bursts))

        # -- stage 3: host assemble ------------------------------------------
        by_leaf: dict[int, list[_Descriptor]] = {}
        for d in descs:
            by_leaf.setdefault(d.leaf, []).append(d)
        for i, ds in by_leaf.items():
            path, x = flat_p[i]
            host = self._assemble(x, sorted(ds, key=lambda d: d.chunk))
            host_flat[i] = host
            memo_puts.append((jax.tree_util.keystr(path), x, host,
                              digests[i]))

        # -- publish: the snapshot is complete, commit the memo updates ------
        for key, x, host, dg in memo_puts:
            self._memo_put(memo, key, x, host, incremental, digest=dg)

        dt = time.perf_counter() - t0
        moved = sum(_nbytes(h) for h in host_flat) - skipped
        self.last_stats = TransferStats(
            bytes_moved=moved, logical_bytes=logical, seconds=dt,
            num_leaves=n, queues=self.num_queues, skipped_bytes=skipped,
            num_descriptors=len(descs), transport=transport)
        return jax.tree_util.tree_unflatten(treedef, [
            _Opaque(h) if isinstance(h, QuantizedLeaf) else h
            for h in host_flat])

    def _pack_eligible(self, x) -> bool:
        return (self.compression == "int8" and x.ndim >= 1
                and x.dtype in (np.dtype("float32"), np.dtype("bfloat16"))
                and x.size >= self.min_quant_size
                and x.shape[-1] % self.block == 0)

    def _dispatch_leaf(self, i, x, transport, kops) -> list[_Descriptor]:
        """Split leaf i into descriptors and dispatch their device-side
        stage (pack kernel / row slice); returns descriptors whose D2H is
        pending. Dispatch is async, so descriptor i+1's pack overlaps
        descriptor i's D2H."""
        packed = self._pack_eligible(x)
        R, L = self._row_view_dims(x)
        chunkable = packed or transport == "stream"
        ranges = self._row_chunks(x.nbytes, R) if chunkable else [(0, R)]
        out = []
        x2 = None
        if len(ranges) > 1 and not packed:
            x2 = x.reshape(R, L)
        per_chunk = max(1, x.nbytes // len(ranges))
        for c, (lo, hi) in enumerate(ranges):
            d = _Descriptor(leaf=i, chunk=c, lo=lo, rows=hi - lo,
                            nbytes=per_chunk, packed=packed)
            if packed:
                d.dev = kops.qdma_pack_rows(x, lo, rows=d.rows,
                                            block=self.block)
                d.nbytes = max(1, per_chunk // x.dtype.itemsize)  # ~int8
            elif x2 is not None:
                d.dev = jax.lax.slice_in_dim(x2, lo, hi, axis=0)
            else:
                d.dev = x          # whole-leaf borrow / single stream chunk
            out.append(d)
        return out

    def _assemble(self, x, ds: list[_Descriptor]):
        """Stage 3: combine a leaf's fetched descriptor chunks back into
        one host buffer (bit-exact: row-chunking commutes with reshape)."""
        if ds[0].packed:
            q2 = np.concatenate([np.asarray(d.host[0]) for d in ds], axis=0) \
                if len(ds) > 1 else np.asarray(ds[0].host[0])
            s2 = np.concatenate([np.asarray(d.host[1]) for d in ds], axis=0) \
                if len(ds) > 1 else np.asarray(ds[0].host[1])
            return QuantizedLeaf(
                q=q2.reshape(x.shape),
                scale=s2.reshape(x.shape[:-1] + (s2.shape[-1],)),
                dtype=str(x.dtype), block=self.block)
        if len(ds) == 1:
            return np.asarray(ds[0].host)
        rows = np.concatenate([np.asarray(d.host) for d in ds], axis=0)
        return rows.reshape(x.shape)

    # -- host -> device (unpause / restore) -------------------------------------
    def restore(self, staged: Any, shardings: Any = None) -> Any:
        if not self.pipeline:
            return self._restore_legacy(staged, shardings)
        from repro.kernels import ops as kops
        t0 = time.perf_counter()
        flat, treedef = jax.tree_util.tree_flatten(
            staged, is_leaf=lambda x: isinstance(x, _Opaque))
        sflat = self._sharding_leaves(shardings, len(flat))
        n = len(flat)
        dev_flat: list = [None] * n

        plain = [(i, x, sh) for i, (x, sh) in enumerate(zip(flat, sflat))
                 if not isinstance(x, _Opaque)]
        packed = [(i, x, sh) for i, (x, sh) in enumerate(zip(flat, sflat))
                  if isinstance(x, _Opaque)]

        # packed leaves first: their H2D + on-device unpack is dispatched
        # asynchronously, overlapping the plain bursts below (stage overlap
        # on restore mirrors the save pipeline in reverse)
        for i, x, sh in packed:
            dev_flat[i] = self._restore_packed(x.leaf, sh, kops)

        # plain leaves: burst-batched device_put per queue
        nq = max(1, min(self.num_queues, len(plain) or 1))
        bursts = self._balance(plain, nq, lambda it: _nbytes(it[1]))

        def put(burst):
            nosh = [(i, x) for i, x, sh in burst if sh is None]
            withsh = [(i, x, sh) for i, x, sh in burst if sh is not None]
            if nosh:
                res = jax.device_put([x for _, x in nosh])
                for (i, _), r in zip(nosh, res):
                    dev_flat[i] = r
            if withsh:
                res = jax.device_put([x for _, x, _ in withsh],
                                     [sh for _, _, sh in withsh])
                for (i, _, _), r in zip(withsh, res):
                    dev_flat[i] = r
        if len(bursts) <= 1:
            for b in bursts:
                put(b)
        else:
            list(self._executor().map(put, bursts))

        jax.block_until_ready([d for d in dev_flat if d is not None])
        dt = time.perf_counter() - t0
        self.last_stats = TransferStats(
            bytes_moved=sum(_nbytes(x) for x in flat),
            logical_bytes=sum(np.asarray(x).nbytes if not hasattr(x, "nbytes")
                              else x.nbytes for x in dev_flat),
            seconds=dt, num_leaves=n, queues=self.num_queues,
            num_descriptors=len(plain) + len(packed),
            transport=self._transport_mode())
        return jax.tree_util.tree_unflatten(treedef, dev_flat)

    def _sharding_leaves(self, shardings, n: int) -> list:
        if shardings is None:
            return [None] * n
        sflat = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: hasattr(s, "device_set"))
        assert len(sflat) == n, (len(sflat), n)
        return sflat

    def _restore_packed(self, ql: QuantizedLeaf, sh, kops):
        """H2D + on-device dequantize, chunk-granular in stream mode so
        upload of chunk i+1 overlaps unpack of chunk i."""
        ssh = None if sh is None else _scale_sharding(sh)
        R, L = self._row_view_dims(ql.q)
        ranges = (self._row_chunks(ql.q.nbytes, R)
                  if self._transport_mode() == "stream" else [(0, R)])
        if len(ranges) == 1:
            q = jax.device_put(ql.q, sh)
            scale = jax.device_put(ql.scale, ssh)
            return kops.qdma_unpack(q, scale, dtype=ql.dtype)
        import jax.numpy as jnp
        q2 = ql.q.reshape(R, L)
        s2 = ql.scale.reshape(R, ql.scale.shape[-1])
        parts = []
        for lo, hi in ranges:
            qd = jax.device_put(q2[lo:hi])
            sd = jax.device_put(s2[lo:hi])
            parts.append(kops.qdma_unpack(qd, sd, dtype=ql.dtype))
        out = jnp.concatenate(parts, axis=0).reshape(ql.q.shape)
        if sh is not None:
            out = jax.device_put(out, sh)
        return out

    # -- PR-1 baseline engine (whole-leaf round-robin) --------------------------
    def _save_legacy(self, tree: Any, tenant: Optional[str],
                     incremental: Optional[bool]) -> Any:
        from repro.kernels import ops as kops
        incremental = self.incremental if incremental is None else incremental
        t0 = time.perf_counter()
        flat_p, treedef = jax.tree_util.tree_flatten_with_path(tree)
        memo = self._memo_for(tenant)
        logical = sum(x.nbytes if isinstance(x, jax.Array) else _nbytes(x)
                      for _, x in flat_p)
        skipped = 0

        def fetch(path_x):
            nonlocal skipped
            path, x = path_x
            key = jax.tree_util.keystr(path)
            hit, dg = self._memo_hit(memo, key, x, incremental)
            if hit is not None:
                skipped += _nbytes(hit)
                return hit, None
            crashpoint("mid_pipeline_chunk")
            if isinstance(x, jax.Array) and self._pack_eligible(x):
                q, scale = kops.qdma_pack(x, block=self.block)
                host = QuantizedLeaf(q=np.asarray(jax.device_get(q)),
                                     scale=np.asarray(jax.device_get(scale)),
                                     dtype=str(x.dtype), block=self.block)
            else:
                host = np.asarray(jax.device_get(x))
            return host, (key, x, host, dg)

        # QDMA-style queues: round-robin leaves over transfer streams
        with cf.ThreadPoolExecutor(max_workers=self.num_queues) as ex:
            fetched = list(ex.map(fetch, flat_p))
        host_flat = [h for h, _ in fetched]
        # transactional publication (see the pipelined save): memo commits
        # only after every leaf crossed the link
        for _, put in fetched:
            if put is not None:
                self._memo_put(memo, *put[:3], incremental, digest=put[3])
        dt = time.perf_counter() - t0
        moved = sum(_nbytes(x) for x in host_flat) - skipped
        self.last_stats = TransferStats(
            bytes_moved=moved, logical_bytes=logical, seconds=dt,
            num_leaves=len(host_flat), queues=self.num_queues,
            skipped_bytes=skipped, num_descriptors=len(host_flat),
            transport="legacy")
        return jax.tree_util.tree_unflatten(treedef, [
            _Opaque(x) if isinstance(x, QuantizedLeaf) else x
            for x in host_flat])

    def _restore_legacy(self, staged: Any, shardings: Any = None) -> Any:
        from repro.kernels import ops as kops
        t0 = time.perf_counter()
        flat, treedef = jax.tree_util.tree_flatten(
            staged, is_leaf=lambda x: isinstance(x, _Opaque))
        sflat = self._sharding_leaves(shardings, len(flat))

        def place(args):
            x, sh = args
            if isinstance(x, _Opaque):
                ql: QuantizedLeaf = x.leaf
                q = jax.device_put(ql.q, sh)
                scale = jax.device_put(
                    ql.scale, None if sh is None else _scale_sharding(sh))
                return kops.qdma_unpack(q, scale, dtype=ql.dtype)
            return jax.device_put(x, sh)

        with cf.ThreadPoolExecutor(max_workers=self.num_queues) as ex:
            dev_flat = list(ex.map(place, zip(flat, sflat)))
        jax.block_until_ready(dev_flat)
        dt = time.perf_counter() - t0
        self.last_stats = TransferStats(
            bytes_moved=sum(_nbytes(x) for x in flat),
            logical_bytes=sum(x.nbytes for x in dev_flat),
            seconds=dt, num_leaves=len(dev_flat), queues=self.num_queues,
            num_descriptors=len(dev_flat), transport="legacy")
        return jax.tree_util.tree_unflatten(treedef, dev_flat)


def _scale_sharding(sh):
    """Scales have one fewer trailing dim granularity; replicate for
    simplicity (they are tiny)."""
    try:
        from jax.sharding import NamedSharding, PartitionSpec
        if isinstance(sh, NamedSharding):
            return NamedSharding(sh.mesh, PartitionSpec())
    except Exception:
        pass
    return None
