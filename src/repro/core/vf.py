"""VirtualFunction — the SR-IOV VF analogue (paper §II-B, §IV).

A VF is a slice of the device pool: an ordered set of devices plus the mesh
shape/axes a tenant's state is sharded over. Its lifecycle mirrors the
VFIO device states in the paper (fig. 2):

  DETACHED  — exists in the PF's VF table, bound to no tenant (left panel)
  ATTACHED  — bound to a tenant; tenant state lives on its devices (center)
  PAUSED    — tenant still *sees* it (emulated view answers queries) but it
              holds no devices: its host-side resources were released so
              the pool can be repartitioned (right panel)

Transitions are validated — e.g. a PAUSED VF cannot be detached without
unpausing first, exactly like the QEMU implementation refuses config-space
writes on a paused vfio-pci device.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh


class VFState(enum.Enum):
    DETACHED = "detached"
    ATTACHED = "attached"
    PAUSED = "paused"
    ERROR = "error"


_ALLOWED = {
    (VFState.DETACHED, VFState.ATTACHED),
    (VFState.ATTACHED, VFState.PAUSED),
    (VFState.PAUSED, VFState.ATTACHED),    # unpause
    (VFState.ATTACHED, VFState.DETACHED),
    (VFState.ERROR, VFState.DETACHED),     # FLR-style recovery
}


class VFTransitionError(RuntimeError):
    pass


@dataclass
class VirtualFunction:
    vf_id: str                              # BDF-style id, e.g. "0000:03:00.4"
    devices: tuple = ()                     # jax devices (empty when PAUSED)
    mesh_shape: tuple = (1, 1)
    mesh_axes: tuple = ("data", "model")
    state: VFState = VFState.DETACHED
    owner: Optional[str] = None             # tenant id
    pausable: bool = True                   # paper: active for Xilinx devices
    # emulated view survives pause (the guest's config-space mirror)
    emulated: dict = field(default_factory=dict)

    def mesh(self) -> Mesh:
        assert self.devices, f"{self.vf_id} holds no devices ({self.state})"
        import numpy as np
        devs = np.array(self.devices).reshape(self.mesh_shape)
        return Mesh(devs, self.mesh_axes)

    @property
    def num_devices(self) -> int:
        return int(math.prod(self.mesh_shape))

    def transition(self, new: VFState):
        if (self.state, new) not in _ALLOWED:
            raise VFTransitionError(
                f"{self.vf_id}: illegal transition {self.state.value} -> "
                f"{new.value}")
        self.state = new

    # -- paper fig. 2 panels --------------------------------------------------
    def release_devices(self) -> tuple:
        """'exit from IOMMU group' — drop device ownership, keep identity."""
        devs, self.devices = self.devices, ()
        return devs

    def assign_devices(self, devices: Sequence, mesh_shape: tuple):
        assert len(devices) == math.prod(mesh_shape)
        self.devices = tuple(devices)
        self.mesh_shape = tuple(mesh_shape)

    def describe(self) -> dict:
        return {
            "vf_id": self.vf_id, "state": self.state.value,
            "owner": self.owner, "mesh_shape": list(self.mesh_shape),
            "mesh_axes": list(self.mesh_axes),
            "devices": [str(d) for d in self.devices],
            "pausable": self.pausable,
            "emulated": dict(self.emulated),
        }
