"""SVFFManager — the framework's automation layer (paper §IV-B3).

Provides the two user-facing operations:

  init(num_vfs, tenants)   first-time setup: rescan, partition ("set #VF"),
                           flash (compile executables), attach tenants.
  reconf(num_vfs, ...)     change the VF partition. With pause enabled
                           (default), live tenants are PAUSED — not removed
                           from their guests — the pool is repartitioned,
                           and tenants are unpaused onto the new layout.
                           With pause disabled, the standard SR-IOV
                           detach/attach cycle runs instead (the paper's
                           baseline column in Tables I/II).

Every reconf returns per-macro-step timings matching Table II rows:
  rescan / remove_vf / change_num_vf / add_vf.

The manager also owns the fault-tolerance paths (migrate a straggler's
tenant via pause->rebind; detach snapshots double as restart checkpoints).
"""
from __future__ import annotations

import os
import time
from typing import Optional, Sequence

import jax

from repro.configs.base import RunConfig
from repro.core.pool import DevicePool, PoolError
from repro.core.pause import (PhaseTimings, pause_vf, pause_vf_live,
                              unpause_vf)
from repro.core.records import RecordStore
from repro.core.scheduler import (PlacementRequest, Scheduler,
                                  make_scheduler)
from repro.core.snapshot import ConfigSpaceSnapshot
from repro.core.staging import StagingEngine
from repro.core.tenant import Tenant
from repro.core.vf import VFState, VirtualFunction
from repro.checkpoint.store import CheckpointStore


class SVFFManager:
    def __init__(self, pool: DevicePool, *,
                 staging: Optional[StagingEngine] = None,
                 workdir: str = "/tmp/svff",
                 pause_enabled: bool = True,
                 scheduler: "Scheduler | str | None" = None):
        self.pool = pool
        self.staging = staging or StagingEngine()
        self.pause_enabled = pause_enabled
        self.records = RecordStore(os.path.join(workdir, "records"))
        self.detach_store_dir = os.path.join(workdir, "detached")
        self.tenants: dict[str, Tenant] = {}
        self.snapshots: dict[str, ConfigSpaceSnapshot] = {}   # RAM (paused)
        self._detach_counter = 0
        if isinstance(scheduler, str):
            scheduler = make_scheduler(scheduler)
        # None -> resolve per attach from the tenant's RunConfig.placement
        self.scheduler: Optional[Scheduler] = scheduler

    # ------------------------------------------------------------------ attach
    def _scheduler_for(self, tenant: Tenant) -> Scheduler:
        if self.scheduler is not None:
            return self.scheduler
        return make_scheduler(getattr(tenant.run, "placement", "first_fit"))

    def _free_vf(self, tenant: Tenant) -> VirtualFunction:
        """Placement-policy delegation (was: first detached VF scan)."""
        sched = self._scheduler_for(tenant)
        return sched.select(self.pool, self.tenants,
                            PlacementRequest(tenant_id=tenant.tid))

    def attach(self, tenant: Tenant, vf_id: Optional[str] = None,
               state=None) -> PhaseTimings:
        """Full attach path: record validation + bind + record write."""
        t = PhaseTimings()
        t0 = time.perf_counter()
        sched = self._scheduler_for(tenant)
        req = PlacementRequest(tenant_id=tenant.tid)
        if vf_id:
            # explicit placement still goes through admission control —
            # e.g. a double attach must not leak the tenant's current VF
            sched.admit(self.pool, self.tenants, req)
            vf = self.pool.find(vf_id)
        else:
            vf = sched.select(self.pool, self.tenants, req)
        if vf.state != VFState.DETACHED:
            # validate BEFORE any mutation: a late VFTransitionError would
            # leave owner/tenant state half-updated
            raise PoolError(
                f"cannot attach {tenant.tid}: {vf.vf_id} is "
                f"{vf.state.value}, not detached")
        try:   # attach re-validates any existing record (QDMA-manager checks)
            self.records.validate(tenant.tid, self.pool)
        except Exception:
            pass
        t.add("validate", time.perf_counter() - t0)

        t0 = time.perf_counter()
        if state is None:
            store = CheckpointStore(self.detach_store_dir)
            step = self._detached_steps(store).get(tenant.tid)
            if step is not None:
                # restore from the disk snapshot the detach wrote
                shardings = tenant.shardings_for(vf)
                like = tenant.state_template()
                state = store.restore(step, like, shardings)
                meta = store.metadata(step)
                tenant.steps_done = meta.get("steps_done",
                                             tenant.steps_done)
        compile_s = tenant.bind(vf, state=state)
        vf.owner = tenant.tid
        vf.transition(VFState.ATTACHED)
        self.tenants[tenant.tid] = tenant
        t.add("bind", time.perf_counter() - t0)
        t.add("compile", compile_s)

        t0 = time.perf_counter()
        self.records.write(tenant.tid, vf.describe(), tenant.run.model.name)
        t.add("record", time.perf_counter() - t0)
        return t

    def _detached_steps(self, store: Optional[CheckpointStore] = None
                        ) -> dict:
        """tenant_id -> checkpoint step for disk-parked detach snapshots."""
        store = store or CheckpointStore(self.detach_store_dir)
        out = {}
        for s in store.steps():
            meta = store.metadata(s)
            out[meta.get("tenant_id", "?")] = s
        return out

    # ------------------------------------------------------------------ detach
    def detach(self, tenant: Tenant) -> PhaseTimings:
        """Standard SR-IOV detach: snapshot to DISK, unbind, free devices.
        The guest loses the device (tenant.status = detached)."""
        t = PhaseTimings()
        vf = self.pool.find(tenant.vf_id)
        if vf.state != VFState.ATTACHED or vf.owner != tenant.tid:
            # validate BEFORE the disk snapshot / unbind: detaching e.g. a
            # PAUSED VF must fail atomically (paper: unpause first)
            raise PoolError(
                f"cannot detach {tenant.tid}: {vf.vf_id} is "
                f"{vf.state.value} (owner {vf.owner})")
        t0 = time.perf_counter()
        state = tenant.export_state()
        payload = self.staging.save(state, tenant=tenant.tid)
        self._detach_counter += 1
        store = CheckpointStore(self.detach_store_dir, keep=0)
        store.save(self._detach_counter, payload,
                   metadata={"tenant_id": tenant.tid,
                             "steps_done": tenant.steps_done})
        t.add("snapshot_disk", time.perf_counter() - t0)

        t0 = time.perf_counter()
        for leaf in jax.tree.leaves(state):
            try:
                leaf.delete()
            except Exception:
                pass
        tenant.detach()
        vf.owner = None
        vf.emulated.clear()
        # NOTE: unlike pause, detach does NOT release devices — the VF
        # still exists on the bus with its resources (SR-IOV semantics);
        # only set_num_vfs / pause change device ownership.
        vf.transition(VFState.DETACHED)
        self.records.remove(tenant.tid)
        # the staging memo's device refs are dead after unbind; drop them so
        # the memo stays bounded across tenant churn
        self.staging.clear(tenant.tid)
        t.add("unbind", time.perf_counter() - t0)
        return t

    # ------------------------------------------------------------------ pause
    def pause(self, tenant: Tenant) -> PhaseTimings:
        vf = self.pool.find(tenant.vf_id)
        snap, t = pause_vf(self.pool, vf, tenant, self.staging)
        self.snapshots[tenant.tid] = snap        # held in host RAM
        return t

    def pause_live(self, tenant: Tenant, *, rounds: int = 2,
                   step_fn=None) -> PhaseTimings:
        """Pre-copy live pause: the tenant keeps stepping through
        ``rounds`` background snapshot rounds (``step_fn`` models its
        concurrent work); only the final stop-and-copy — ``t.stop_ms`` —
        stalls it."""
        vf = self.pool.find(tenant.vf_id)
        snap, t = pause_vf_live(self.pool, vf, tenant, self.staging,
                                rounds=rounds, step_fn=step_fn)
        self.snapshots[tenant.tid] = snap        # held in host RAM
        return t

    def unpause(self, tenant: Tenant, vf_id: Optional[str] = None,
                num_devices: Optional[int] = None) -> PhaseTimings:
        # the RAM snapshot is the paused tenant's ONLY state copy — drop
        # it only after the unpause fully succeeded, so a failed unpause
        # (bad vf_id, no free devices) stays retryable
        snap = self.snapshots[tenant.tid]
        vf = (self.pool.find(vf_id) if vf_id
              else self.pool.find(tenant.vf_id))
        t = unpause_vf(self.pool, vf, tenant, snap, self.staging,
                       num_devices=num_devices)
        vf.owner = tenant.tid
        del self.snapshots[tenant.tid]
        return t

    # ------------------------------------------------------------------ init
    def init(self, num_vfs: int, tenants: Sequence[Tenant],
             devices_per_vf: Optional[int] = None) -> PhaseTimings:
        t = PhaseTimings()
        t0 = time.perf_counter()
        self.pool.rescan()
        t.add("rescan", time.perf_counter() - t0)

        t0 = time.perf_counter()
        self.pool.set_num_vfs(num_vfs, devices_per_vf)
        t.add("change_num_vf", time.perf_counter() - t0)

        for tn in tenants:
            ta = self.attach(tn)
            t.add("add_vf", ta.total)
        return t

    # ------------------------------------------------------------------ reconf
    def reconf(self, num_vfs: int, new_tenants: Sequence[Tenant] = (),
               devices_per_vf: Optional[int] = None,
               use_pause: Optional[bool] = None) -> dict:
        """The paper's reconfiguration cycle. Returns Table-II style timings
        (seconds): {rescan, remove_vf, change_num_vf, add_vf, total}."""
        use_pause = self.pause_enabled if use_pause is None else use_pause
        timings = {}

        # 1. rescan — be sure every PF/VF on the bus is discovered
        t0 = time.perf_counter()
        self.pool.rescan()
        timings["rescan"] = time.perf_counter() - t0

        # 2. remove VF — pause (live guests keep their device) or detach
        t0 = time.perf_counter()
        live = [tn for tn in self.tenants.values()
                if tn.status == "running"]
        for tn in live:
            if use_pause:
                self.pause(tn)
            else:
                self.detach(tn)
        timings["remove_vf"] = time.perf_counter() - t0

        # 3. change #VF on the PF
        t0 = time.perf_counter()
        self.pool.set_num_vfs(num_vfs, devices_per_vf)
        timings["change_num_vf"] = time.perf_counter() - t0

        # 4. add VF — unpause previously-paused tenants; attach new ones
        t0 = time.perf_counter()
        for tn in live:
            if use_pause:
                # paused VFs kept their identity; give them devices again
                vf = self.pool.find(tn.vf_id)
                if not vf.devices:
                    self.pool.allocate(
                        vf, devices_per_vf
                        or max(1, self.pool.num_devices // max(num_vfs, 1)))
                self.unpause(tn)
            else:
                self.attach(tn)
        for tn in new_tenants:
            self.attach(tn)
        timings["add_vf"] = time.perf_counter() - t0
        timings["total"] = sum(timings.values())
        return timings

    # --------------------------------------------------------- fault tolerance
    def migrate(self, tenant: Tenant) -> dict:
        """Straggler/failure mitigation: move a tenant to fresh devices via
        pause -> release -> allocate elsewhere -> unpause."""
        t0 = time.perf_counter()
        vf = self.pool.find(tenant.vf_id)
        n = vf.num_devices
        self.pause(tenant)
        # prefer devices not in the old slice
        self.pool.allocate(vf, n)
        self.unpause(tenant)
        return {"migrate_s": time.perf_counter() - t0,
                "new_devices": [str(d) for d in vf.devices]}

    def query(self) -> dict:
        return {"pool": self.pool.query(),
                "tenants": {t.tid: t.query() for t in self.tenants.values()},
                "paused_snapshots": {k: v.describe()
                                     for k, v in self.snapshots.items()},
                "pause_enabled": self.pause_enabled,
                "scheduler": (self.scheduler.describe() if self.scheduler
                              else {"policy": "per-tenant"})}
