"""SVFFManager — the framework's automation layer (paper §IV-B3).

Provides the two user-facing operations:

  init(num_vfs, tenants)   first-time setup: rescan, partition ("set #VF"),
                           flash (compile executables), attach tenants.
  reconf(num_vfs, ...)     change the VF partition. With pause enabled
                           (default), live tenants are PAUSED — not removed
                           from their guests — the pool is repartitioned,
                           and tenants are unpaused onto the new layout.
                           With pause disabled, the standard SR-IOV
                           detach/attach cycle runs instead (the paper's
                           baseline column in Tables I/II).

Every reconf returns per-macro-step timings matching Table II rows:
  rescan / remove_vf / change_num_vf / add_vf.

The manager also owns the fault-tolerance paths (migrate a straggler's
tenant via pause->rebind; detach snapshots double as restart checkpoints).
"""
from __future__ import annotations

import os
import time
from typing import Optional, Sequence

import jax

from repro.configs.base import RunConfig
from repro.core.errors import (HostUnreachableError, ManagerError,
                               UnknownTenantError)
from repro.core.fault import InjectedCrash, crashpoint
from repro.core.journal import OpJournal, PENDING
from repro.core.pool import DevicePool, PoolError
from repro.core.pause import (PauseError, PhaseTimings, validate_pausable,
                              pause_vf, pause_vf_live, unpause_vf)
from repro.core.records import RecordStore
from repro.core.scheduler import (PlacementRequest, Scheduler,
                                  make_scheduler)
from repro.core.snapshot import ConfigSpaceSnapshot
from repro.core.staging import StagingEngine
from repro.core.tenant import Tenant
from repro.core.vf import VFState, VirtualFunction
from repro.checkpoint.store import CheckpointStore


# ManagerError / UnknownTenantError now live in the canonical hierarchy
# (repro.core.errors); imported above and re-exported here so existing
# ``from repro.core.manager import ManagerError`` call sites keep working.
__all__ = ["ManagerError", "SVFFManager", "UnknownTenantError"]


class SVFFManager:
    def __init__(self, pool: DevicePool, *,
                 staging: Optional[StagingEngine] = None,
                 workdir: str = "/tmp/svff",
                 pause_enabled: bool = True,
                 scheduler: "Scheduler | str | None" = None,
                 records: Optional[RecordStore] = None,
                 journal: Optional[OpJournal] = None,
                 peer_lookup=None):
        #: federation hook — ``peer_lookup(host_id, tid) -> tenant|None``
        #: resolves a tenant living on ANOTHER host (raising
        #: ``HostUnreachableError`` when the fabric is partitioned).
        #: ``None`` keeps the single-host behaviour everywhere.
        self.peer_lookup = peer_lookup
        self.pool = pool
        self.staging = staging or StagingEngine()
        self.pause_enabled = pause_enabled
        self.workdir = workdir
        self.records = records or RecordStore(os.path.join(workdir,
                                                           "records"))
        self.journal = journal or OpJournal(os.path.join(workdir,
                                                         "journal"))
        self.detach_store_dir = os.path.join(workdir, "detached")
        self.tenants: dict[str, Tenant] = {}
        self.snapshots: dict[str, ConfigSpaceSnapshot] = {}   # RAM (paused)
        self._detach_counter = 0
        if isinstance(scheduler, str):
            scheduler = make_scheduler(scheduler)
        # None -> resolve per attach from the tenant's RunConfig.placement
        self.scheduler: Optional[Scheduler] = scheduler

    # ------------------------------------------------------------- WAL helper
    def _resolve_failed(self, seq: int) -> None:
        """Inline self-heal for a CLEAN (non-crash) failure between
        ``journal.begin`` and ``journal.commit`` on a live manager: the
        pending intent is reconciled with exactly the recovery logic a
        restarted manager would apply (roll forward if the destructive
        step ran, back otherwise), so no pending entry ever outlives the
        op and I8 holds without requiring a restart. Never masks the
        original exception."""
        try:
            e = self.journal.read(seq)
            if e["status"] == PENDING:
                self._recover_entry(e, self.snapshots)
        except Exception:
            pass

    # ------------------------------------------------------------------ attach
    def _scheduler_for(self, tenant: Tenant) -> Scheduler:
        if self.scheduler is not None:
            return self.scheduler
        return make_scheduler(getattr(tenant.run, "placement", "first_fit"))

    def _free_vf(self, tenant: Tenant) -> VirtualFunction:
        """Placement-policy delegation (was: first detached VF scan)."""
        sched = self._scheduler_for(tenant)
        return sched.select(self.pool, self.tenants,
                            PlacementRequest(tenant_id=tenant.tid))

    def attach(self, tenant: Tenant, vf_id: Optional[str] = None,
               state=None) -> PhaseTimings:
        """Full attach path: record validation + bind + record write."""
        t = PhaseTimings()
        t0 = time.perf_counter()
        sched = self._scheduler_for(tenant)
        req = PlacementRequest(tenant_id=tenant.tid)
        if vf_id:
            # explicit placement still goes through admission control —
            # e.g. a double attach must not leak the tenant's current VF
            sched.admit(self.pool, self.tenants, req)
            vf = self.pool.find(vf_id)
        else:
            vf = sched.select(self.pool, self.tenants, req)
        if vf.state != VFState.DETACHED:
            # validate BEFORE any mutation: a late VFTransitionError would
            # leave owner/tenant state half-updated
            raise PoolError(
                f"cannot attach {tenant.tid}: {vf.vf_id} is "
                f"{vf.state.value}, not detached")
        try:   # attach re-validates any existing record (QDMA-manager checks)
            self.records.validate(tenant.tid, self.pool)
        except Exception:
            pass
        t.add("validate", time.perf_counter() - t0)

        t0 = time.perf_counter()
        if state is None:
            store = CheckpointStore(self.detach_store_dir)
            step = self._detached_steps(store).get(tenant.tid)
            if step is not None:
                # restore from the disk snapshot the detach wrote (read-
                # only preparation: a corrupt snapshot must fail BEFORE
                # the WAL entry exists, so the failure stays a clean,
                # I8-preserving rejection)
                shardings = tenant.shardings_for(vf)
                like = tenant.state_template()
                state = store.restore(step, like, shardings)
                meta = store.metadata(step)
                tenant.steps_done = meta.get("steps_done",
                                             tenant.steps_done)
        # WAL: every check passed — log the intent before the first mutation
        entry = self.journal.begin("attach", tenant.tid, vf_id=vf.vf_id)
        try:
            compile_s = tenant.bind(vf, state=state)
            vf.owner = tenant.tid
            vf.transition(VFState.ATTACHED)
            self.tenants[tenant.tid] = tenant
            t.add("bind", time.perf_counter() - t0)
            t.add("compile", compile_s)

            t0 = time.perf_counter()
            self.records.write(tenant.tid, vf.describe(),
                               tenant.run.model.name)
            t.add("record", time.perf_counter() - t0)
            self.journal.commit(entry)
        except InjectedCrash:
            raise                      # a crash leaves the intent pending
        except Exception:
            # clean failure (e.g. compile error): self-heal the intent —
            # rolled back if bind never completed, forward otherwise
            self._resolve_failed(entry)
            raise
        return t

    def _detached_steps(self, store: Optional[CheckpointStore] = None
                        ) -> dict:
        """tenant_id -> checkpoint step for disk-parked detach snapshots."""
        store = store or CheckpointStore(self.detach_store_dir)
        out = {}
        for s in store.steps():
            meta = store.metadata(s)
            out[meta.get("tenant_id", "?")] = s
        return out

    # ------------------------------------------------------------------ detach
    def detach(self, tenant: Tenant) -> PhaseTimings:
        """Standard SR-IOV detach: snapshot to DISK, unbind, free devices.
        The guest loses the device (tenant.status = detached)."""
        t = PhaseTimings()
        vf = self.pool.find(tenant.vf_id)
        if vf.state != VFState.ATTACHED or vf.owner != tenant.tid:
            # validate BEFORE the disk snapshot / unbind: detaching e.g. a
            # PAUSED VF must fail atomically (paper: unpause first)
            raise PoolError(
                f"cannot detach {tenant.tid}: {vf.vf_id} is "
                f"{vf.state.value} (owner {vf.owner})")
        # WAL: record the intent (and the disk-snapshot step it will use,
        # so a rollback can delete the orphan) before the first write
        entry = self.journal.begin("detach", tenant.tid, vf_id=vf.vf_id,
                                   step=self._detach_counter + 1)
        try:
            t0 = time.perf_counter()
            state = tenant.export_state()
            payload = self.staging.save(state, tenant=tenant.tid)
            self._detach_counter += 1
            store = CheckpointStore(self.detach_store_dir, keep=0)
            store.save(self._detach_counter, payload,
                       metadata={"tenant_id": tenant.tid,
                                 "steps_done": tenant.steps_done})
            t.add("snapshot_disk", time.perf_counter() - t0)
            # crash window: disk snapshot written, guest still bound —
            # recovery rolls BACK (delete the orphan, tenant keeps running)
            crashpoint("after_detach_snapshot")

            t0 = time.perf_counter()
            for leaf in jax.tree.leaves(state):
                try:
                    leaf.delete()
                except Exception:
                    pass
            tenant.detach()
            vf.owner = None
            vf.emulated.clear()
            # NOTE: unlike pause, detach does NOT release devices — the VF
            # still exists on the bus with its resources (SR-IOV
            # semantics); only set_num_vfs / pause change device ownership.
            vf.transition(VFState.DETACHED)
            # crash window: unbind complete but the attach record still on
            # disk — recovery rolls FORWARD (remove the record, commit)
            crashpoint("after_unbind")
            self.records.remove(tenant.tid)
            # the staging memo's device refs are dead after unbind; drop
            # them so the memo stays bounded across tenant churn
            self.staging.clear(tenant.tid)
            t.add("unbind", time.perf_counter() - t0)
            self.journal.commit(entry)
        except InjectedCrash:
            raise                      # a crash leaves the intent pending
        except Exception:
            self._resolve_failed(entry)
            raise
        return t

    # ------------------------------------------------------------------ pause
    def pause(self, tenant: Tenant) -> PhaseTimings:
        vf = self.pool.find(tenant.vf_id)
        validate_pausable(vf, tenant)           # reject BEFORE the WAL entry
        entry = self.journal.begin("pause", tenant.tid, vf_id=vf.vf_id)
        try:
            # the sink registers the snapshot in host RAM before the
            # destructive suspend, which is what makes mid-pause crashes
            # recoverable (see core/pause.py)
            snap, t = pause_vf(self.pool, vf, tenant, self.staging,
                               sink=self.snapshots)
            self.journal.commit(entry)
        except InjectedCrash:
            raise
        except Exception:
            self._resolve_failed(entry)
            raise
        return t

    def pause_live(self, tenant: Tenant, *, rounds: int = 2,
                   step_fn=None) -> PhaseTimings:
        """Pre-copy live pause: the tenant keeps stepping through
        ``rounds`` background snapshot rounds (``step_fn`` models its
        concurrent work); only the final stop-and-copy — ``t.stop_ms`` —
        stalls it."""
        vf = self.pool.find(tenant.vf_id)
        validate_pausable(vf, tenant)
        entry = self.journal.begin("pause_live", tenant.tid, vf_id=vf.vf_id)
        try:
            snap, t = pause_vf_live(self.pool, vf, tenant, self.staging,
                                    rounds=rounds, step_fn=step_fn,
                                    sink=self.snapshots)
            self.journal.commit(entry)
        except InjectedCrash:
            raise
        except Exception:
            self._resolve_failed(entry)
            raise
        return t

    def unpause(self, tenant: Tenant, vf_id: Optional[str] = None,
                num_devices: Optional[int] = None) -> PhaseTimings:
        # the RAM snapshot is the paused tenant's ONLY state copy — drop
        # it only after the unpause fully succeeded, so a failed unpause
        # (bad vf_id, no free devices) stays retryable
        if tenant.tid not in self.snapshots:
            raise UnknownTenantError(
                f"cannot unpause {tenant.tid}: no RAM snapshot "
                f"(status {getattr(tenant, 'status', '?')})")
        snap = self.snapshots[tenant.tid]
        vf = (self.pool.find(vf_id) if vf_id
              else self.pool.find(tenant.vf_id))
        if vf.state != VFState.PAUSED:
            raise PauseError(f"{vf.vf_id} is not paused")
        entry = self.journal.begin("unpause", tenant.tid, vf_id=vf.vf_id)
        try:
            t = unpause_vf(self.pool, vf, tenant, snap, self.staging,
                           num_devices=num_devices)
            vf.owner = tenant.tid
            del self.snapshots[tenant.tid]
            self.journal.commit(entry)
        except InjectedCrash:
            raise
        except Exception:
            # clean rejection/failure (e.g. no free devices): self-heal
            # the intent so the op stays retryable with the snapshot kept
            self._resolve_failed(entry)
            raise
        return t

    # ------------------------------------------------------------------ init
    def init(self, num_vfs: int, tenants: Sequence[Tenant],
             devices_per_vf: Optional[int] = None) -> PhaseTimings:
        t = PhaseTimings()
        t0 = time.perf_counter()
        self.pool.rescan()
        t.add("rescan", time.perf_counter() - t0)

        t0 = time.perf_counter()
        self.pool.set_num_vfs(num_vfs, devices_per_vf)
        t.add("change_num_vf", time.perf_counter() - t0)

        for tn in tenants:
            # a gang lead (an engine spanning K VFs) attaches its whole
            # gang atomically; everything else takes the single-VF path
            if getattr(tn, "gang_shells", None):
                ta = self.attach_group(tn)
            else:
                ta = self.attach(tn)
            t.add("add_vf", ta.total)
        return t

    # ------------------------------------------------------------------ reconf
    def reconf(self, num_vfs: int, new_tenants: Sequence[Tenant] = (),
               devices_per_vf: Optional[int] = None,
               use_pause: Optional[bool] = None) -> dict:
        """The paper's reconfiguration cycle. Returns Table-II style timings
        (seconds): {rescan, remove_vf, change_num_vf, add_vf, total}."""
        use_pause = self.pause_enabled if use_pause is None else use_pause
        timings = {}

        # 1. rescan — be sure every PF/VF on the bus is discovered
        t0 = time.perf_counter()
        self.pool.rescan()
        timings["rescan"] = time.perf_counter() - t0

        # 2. remove VF — pause (live guests keep their device) or detach
        t0 = time.perf_counter()
        live = [tn for tn in self.tenants.values()
                if tn.status == "running"]
        for tn in live:
            if use_pause:
                self.pause(tn)
            else:
                self.detach(tn)
        timings["remove_vf"] = time.perf_counter() - t0

        # 3. change #VF on the PF
        t0 = time.perf_counter()
        self.pool.set_num_vfs(num_vfs, devices_per_vf)
        timings["change_num_vf"] = time.perf_counter() - t0

        # 4. add VF — unpause previously-paused tenants; attach new ones
        t0 = time.perf_counter()
        for tn in live:
            if use_pause:
                # paused VFs kept their identity; give them devices again
                vf = self.pool.find(tn.vf_id)
                if not vf.devices:
                    self.pool.allocate(
                        vf, devices_per_vf
                        or max(1, self.pool.num_devices // max(num_vfs, 1)))
                self.unpause(tn)
            else:
                self.attach(tn)
        for tn in new_tenants:
            if getattr(tn, "gang_shells", None):
                self.attach_group(tn)
            else:
                self.attach(tn)
        timings["add_vf"] = time.perf_counter() - t0
        timings["total"] = sum(timings.values())
        return timings

    # --------------------------------------------------------- fault tolerance
    def migrate(self, tenant: Tenant) -> dict:
        """Straggler/failure mitigation: move a tenant to fresh devices via
        pause -> release -> allocate elsewhere -> unpause. The migrate
        itself is journaled, and its pause/unpause halves journal their
        own entries — so a crash mid-migrate recovers the inner op first,
        then resolves the migrate (forward if the tenant came back running,
        rolled back to a clean paused state otherwise)."""
        t0 = time.perf_counter()
        vf = self.pool.find(tenant.vf_id)
        validate_pausable(vf, tenant)
        entry = self.journal.begin("migrate", tenant.tid, vf_id=vf.vf_id)
        try:
            n = vf.num_devices
            old = tuple(vf.devices)
            self.pause(tenant)
            # prefer devices not in the old (possibly sick) slice
            self.pool.allocate(vf, n, avoid=old)
            self.unpause(tenant)
            self.journal.commit(entry)
        except InjectedCrash:
            raise
        except Exception:
            # inner ops self-heal their own entries first; the migrate
            # intent then resolves against wherever the tenant landed
            self._resolve_failed(entry)
            raise
        return {"migrate_s": time.perf_counter() - t0,
                "new_devices": [str(d) for d in vf.devices]}

    def migrate_request(self, src: Tenant, dst: Tenant,
                        rid: Optional[int] = None, *,
                        dst_host: Optional[str] = None) -> dict:
        """Request-granular live migration: ship ONE in-flight request's
        KV block chain from ``src`` to ``dst`` through the staging
        descriptor pipeline and resume it there token-identically (I10).
        The paper's pause/migrate story pushed down from VF granularity
        to request granularity.

        Ordering is chosen so every step before the source release is
        non-destructive: peek (pure) -> WAL begin -> extract (freeze +
        copy) -> ship -> admit on target -> release on source -> commit.
        A clean failure anywhere (typically target ``CacheExhausted``)
        rolls back via ``_resolve_failed``: the target admitted nothing,
        the source thaws the frozen slot and keeps serving the request —
        the caller may simply retry. Crash windows are catalogued in
        ``sim/chaos.py`` (mid_extract / mid_ship / after_target_admit /
        before_source_free); ``recover`` rolls forward iff the target
        owns the request (invariant I13: live on exactly one engine,
        source pages freed iff target committed)."""
        t0 = time.perf_counter()
        for role, tn in (("source", src), ("target", dst)):
            if getattr(tn, "status", None) != "running":
                raise ManagerError(
                    f"migrate_request: {role} {tn.tid} is "
                    f"{getattr(tn, 'status', None)}, not running")
        if src.tid == dst.tid:
            raise ManagerError(
                f"migrate_request: source and target are both {src.tid}")
        for tn, attr in ((src, "extract_request"), (dst, "admit_migrated")):
            if not hasattr(tn, attr):
                raise ManagerError(
                    f"migrate_request: {tn.tid} lacks the request-"
                    f"migration protocol ({attr})")
        rid = src.peek_migratable(rid)
        if rid is None:
            raise ManagerError(
                f"migrate_request: {src.tid} has no migratable in-flight "
                "request")
        # ``dst_host`` marks a CROSS-HOST migration (federation plane):
        # the destination tenant lives under another host's manager, so
        # recovery resolves the entry through ``peer_lookup`` — and
        # DEFERS it (entry stays pending) when that host is unreachable,
        # because resolving blind risks serving the request twice (I15).
        details = {"dst": dst.tid, "rid": rid}
        if dst_host is not None:
            details["dst_host"] = dst_host
        entry = self.journal.begin("migrate_request", src.tid,
                                   vf_id=src.vf_id, **details)
        mig_key = f"{src.tid}/mig:{rid}"
        try:
            payload = src.extract_request(rid)
            if payload is None:
                raise ManagerError(
                    f"migrate_request: {src.tid} lost request {rid} "
                    "between peek and extract")
            # crash window: chain gathered host-side, slot frozen,
            # nothing destructive yet -> recovery rolls BACK
            crashpoint("migrate_mid_extract")
            shipped = self.staging.save(payload["state"], tenant=mig_key)
            # crash window: descriptor pipeline mid-flight, target
            # untouched -> recovery rolls BACK
            crashpoint("migrate_mid_ship")
            state = self.staging.restore(shipped, None)
            dst.admit_migrated(payload, state)
            # crash window: target committed, source still frozen ->
            # recovery rolls FORWARD (source releases its copy)
            crashpoint("migrate_after_target_admit")
            # crash window: same predicate, last instant before the only
            # destructive step -> recovery rolls FORWARD
            crashpoint("migrate_before_source_free")
            src.release_request(rid)
            self.staging.clear(mig_key)
            self.journal.commit(entry)
        except InjectedCrash:
            raise                      # a crash leaves the intent pending
        except Exception:
            # clean failure (target exhausted, admission rejected): the
            # recovery predicate sees the target does not own the request
            # and rolls back — frozen slot thaws, source keeps serving
            self._resolve_failed(entry)
            raise
        return {"rid": rid, "src": src.tid, "dst": dst.tid,
                "blocks": payload.get("chain_len", 0),
                "migrate_request_s": time.perf_counter() - t0}

    # ------------------------------------------------------------- gang ops
    def _gang_shells(self, lead: Tenant) -> tuple:
        shells = tuple(getattr(lead, "gang_shells", ()) or ())
        if not shells:
            raise ManagerError(
                f"{lead.tid} is not a gang lead (no gang_shells)")
        return shells

    def attach_group(self, lead: Tenant) -> PhaseTimings:
        """All-or-nothing attach of a pipeline gang: the lead (stage 0)
        plus K-1 shell members, one VF each. Admission runs through the
        scheduler's ``admit_gang`` BEFORE the WAL entry, so a capacity
        rejection is a typed ``GangPlacementError`` with zero side
        effects. Each member attach journals its own entry inside the
        gang window; the gang entry's recovery predicate — every member
        running — rolls the gang forward iff it fully formed, and
        otherwise detaches whichever members bound (no leaked VFs, no
        half-bound stages)."""
        shells = self._gang_shells(lead)
        k = int(getattr(lead, "stage_width", 1))
        if not 1 <= k <= len(shells) + 1:
            raise ManagerError(
                f"attach_group: {lead.tid} width K={k} exceeds its "
                f"{len(shells) + 1} gang slots")
        members = [lead] + list(shells[:k - 1])
        sched = self._scheduler_for(lead)
        sched.admit_gang(self.pool, self.tenants,
                         [PlacementRequest(tenant_id=m.tid)
                          for m in members])
        entry = self.journal.begin("attach_group", lead.tid, k=k,
                                   members=[m.tid for m in members])
        t = PhaseTimings()
        try:
            for i, m in enumerate(members):
                tm = self.attach(m)
                t.add("add_vf", tm.total)
                if i == 0:
                    # crash window: lead bound, shells not — recovery
                    # rolls BACK (detach the lead, abort the gang)
                    crashpoint("gang_mid_member")
            # crash window: every member bound, gang entry still pending
            # — recovery rolls FORWARD (commit)
            crashpoint("gang_before_commit")
            self.journal.commit(entry)
        except InjectedCrash:
            raise
        except Exception:
            # clean failure (e.g. a member's bind raised): the recovery
            # predicate sees a partial gang and detaches the bound members
            self._resolve_failed(entry)
            raise
        return t

    def detach_group(self, lead: Tenant) -> PhaseTimings:
        """Detach the whole gang (shells first, lead last). Recovery is
        forward-only: a detach_group intent always completes — whichever
        members survived the crash still bound are detached on recovery."""
        shells = self._gang_shells(lead)
        if getattr(lead, "status", None) != "running":
            raise ManagerError(
                f"detach_group: {lead.tid} is "
                f"{getattr(lead, 'status', None)}, not running")
        members = [s for s in shells
                   if getattr(s, "status", None) == "running"] + [lead]
        entry = self.journal.begin("detach_group", lead.tid,
                                   members=[m.tid for m in members])
        t = PhaseTimings()
        try:
            for m in members:
                tm = self.detach(m)
                t.add("remove_vf", tm.total)
            self.journal.commit(entry)
        except InjectedCrash:
            raise
        except Exception:
            self._resolve_failed(entry)
            raise
        return t

    def reshape(self, lead: Tenant, k_new: int, *,
                drop: Optional[str] = None) -> dict:
        """Re-instantiate a live gang at width ``k_new`` by attaching idle
        shells (grow) or detaching active ones (shrink), then selecting
        the precomputed stage template via ``lead.apply_reshape``. The
        lead keeps serving throughout — the KV cache and every request
        byte are untouched, so token streams stay bit-identical (I10).
        ``drop`` names the shell to shed first (the VF-loss fallback
        path). Recovery predicate: the gang holds exactly ``k_new``
        running members -> roll forward (re-select the template, commit);
        otherwise undo the member deltas and abort — either way the gang
        matches exactly one registered template (I14)."""
        t0 = time.perf_counter()
        shells = self._gang_shells(lead)
        if getattr(lead, "status", None) != "running":
            raise ManagerError(
                f"reshape: {lead.tid} is "
                f"{getattr(lead, 'status', None)}, not running")
        k_old = int(getattr(lead, "stage_width", 1))
        if k_new == k_old:
            raise ManagerError(
                f"reshape: {lead.tid} already at K={k_old}")
        if not (hasattr(lead, "has_template") and lead.has_template(k_new)):
            raise ManagerError(
                f"reshape: {lead.tid} has no stage template for "
                f"K={k_new}")
        active = [s for s in shells
                  if getattr(s, "status", None) == "running"]
        added: list = []
        dropped: list = []
        if k_new > k_old:
            if drop is not None:
                raise ManagerError(
                    "reshape: drop= only applies to a shrink")
            need = k_new - k_old
            idle = [s for s in shells
                    if getattr(s, "status", None) != "running"]
            if len(idle) < need:
                raise ManagerError(
                    f"reshape: {lead.tid} K={k_old}->{k_new} needs "
                    f"{need} idle shell(s), has {len(idle)}")
            added = idle[:need]
            sched = self._scheduler_for(lead)
            sched.admit_gang(self.pool, self.tenants,
                             [PlacementRequest(tenant_id=s.tid)
                              for s in added])
        else:
            need = k_old - k_new
            order = list(reversed(active))       # shed highest stage first
            if drop is not None:
                victim = next((s for s in active if s.tid == drop), None)
                if victim is None:
                    raise ManagerError(
                        f"reshape: {drop} is not an active shell of "
                        f"{lead.tid}")
                order = [victim] + [s for s in order if s.tid != drop]
            if len(active) < need:
                raise ManagerError(
                    f"reshape: {lead.tid} K={k_old}->{k_new} sheds "
                    f"{need} shell(s), only {len(active)} active")
            dropped = order[:need]
        entry = self.journal.begin(
            "reshape", lead.tid, vf_id=getattr(lead, "vf_id", None),
            k_old=k_old, k_new=k_new,
            added=[s.tid for s in added],
            dropped=[s.tid for s in dropped])
        try:
            # crash window: intent logged, no member touched — recovery
            # rolls BACK (the gang still holds k_old members), so the
            # outcome is deterministic for grow AND shrink directions
            crashpoint("reshape_mid_members")
            for s in added:
                self.attach(s)
            for s in dropped:
                self.detach(s)
            # crash window: member set already at k_new, template not yet
            # selected — recovery rolls FORWARD (apply_reshape + commit)
            crashpoint("reshape_before_commit")
            lead.apply_reshape(k_new)
            self.journal.commit(entry)
        except InjectedCrash:
            raise
        except Exception:
            # clean failure (e.g. a grow attach rejected): the recovery
            # predicate counts a partial gang and undoes the member deltas
            self._resolve_failed(entry)
            raise
        return {"k_old": k_old, "k_new": k_new,
                "added": [s.tid for s in added],
                "dropped": [s.tid for s in dropped],
                "reshape_s": time.perf_counter() - t0}

    def query(self) -> dict:
        return {"pool": self.pool.query(),
                "tenants": {t.tid: t.query() for t in self.tenants.values()},
                "paused_snapshots": {k: v.describe()
                                     for k, v in self.snapshots.items()},
                "pause_enabled": self.pause_enabled,
                "journal_pending": len(self.journal.pending()),
                "scheduler": (self.scheduler.describe() if self.scheduler
                              else {"policy": "per-tenant"})}

    # ------------------------------------------------------------- recovery
    @classmethod
    def recover(cls, journal: "OpJournal | str", pool: DevicePool,
                records: "RecordStore | str",
                staging: Optional[StagingEngine] = None, *,
                tenants: Optional[dict] = None,
                snapshots: Optional[dict] = None,
                workdir: Optional[str] = None,
                pause_enabled: bool = True,
                scheduler: "Scheduler | str | None" = None,
                peer_lookup=None) -> "SVFFManager":
        """Rebuild a manager after the previous one died mid-operation.

        What survives a manager crash — and is therefore handed in — is
        exactly what lives OUTSIDE the manager process: the journal and
        attach records on disk, the device pool (bus state), the guest
        ``tenants`` themselves, and the host-RAM ``snapshots`` table the
        pause path registers into before suspending. Recovery:

          1. sweeps crash debris (``*.part`` files, torn checkpoint tmp
             dirs) and drops every staging memo (device refs are dead);
          2. reconciles each PENDING journal entry newest-first against
             the surviving state, rolling the op FORWARD when its
             destructive step already happened (suspend done, unbind done,
             restore done) and BACK otherwise, then resolves the entry;
          3. adopts the surviving tenants/snapshots and re-derives
             counters (detach step numbering) from disk.

        The result satisfies invariants I1-I9; calling ``recover`` again
        on it is a no-op (I9: recovery idempotence).
        """
        if isinstance(records, str):
            records = RecordStore(records)
        if isinstance(journal, str):
            journal = OpJournal(journal)
        workdir = workdir or os.path.dirname(records.dir.rstrip(os.sep))
        staging = staging or StagingEngine()
        mgr = cls(pool, staging=staging, workdir=workdir,
                  pause_enabled=pause_enabled, scheduler=scheduler,
                  records=records, journal=journal,
                  peer_lookup=peer_lookup)

        # -- 1. sweep crash debris; a fresh process holds no device memos
        staging.clear()
        records.sweep_parts()
        journal.sweep_parts()
        store = CheckpointStore(mgr.detach_store_dir, keep=0)
        store.sweep_tmp()

        # -- 2. adopt survivors (resolution below may mutate them)
        tenants = dict(tenants or {})
        snapshots = dict(snapshots) if snapshots is not None else {}
        mgr.tenants = {
            tid: tn for tid, tn in tenants.items()
            if getattr(tn, "status", None) in ("running", "paused",
                                               "detached")}

        # -- 3. reconcile pending intents, newest first (inner ops of a
        # compound op like migrate resolve before the compound entry)
        for e in reversed(journal.pending()):
            mgr._recover_entry(e, snapshots)

        # -- 4. final state: snapshots table is exactly the paused tenants
        mgr.snapshots = {
            tid: s for tid, s in snapshots.items()
            if getattr(mgr.tenants.get(tid), "status", None) == "paused"}
        mgr._detach_counter = max(store.steps(), default=0)
        return mgr

    def _recover_entry(self, e: dict, snapshots: dict) -> None:
        """Roll one pending journal entry forward or back. The decision is
        read off the surviving state: if the op's destructive step already
        ran (the guest was suspended / unbound / its VF re-attached), the
        op completes; otherwise it never happened."""
        op, tid, vf_id = e["op"], e["tenant"], e.get("vf_id")
        seq = e["seq"]
        tn = self.tenants.get(tid)
        vf = self.pool.vfs.get(vf_id) if vf_id else None
        status = getattr(tn, "status", None)

        if op == "attach":
            bound = (status == "running" and vf is not None
                     and getattr(tn, "vf_id", None) == vf.vf_id)
            if bound:
                # bind completed; the pool update and/or record may be
                # missing — finish them (forward), idempotently
                if vf.owner is None:
                    vf.owner = tid
                if vf.state == VFState.DETACHED:
                    vf.transition(VFState.ATTACHED)
                self.records.write(tid, vf.describe(), tn.run.model.name)
                self.journal.commit(seq, recovered="forward")
            else:
                # bind never ran — nothing to undo beyond a stray record
                self.records.remove(tid)
                self.journal.abort(seq, recovered="rollback")

        elif op == "detach":
            if status == "detached":
                # unbind done: finish by dropping the record + memo
                self.records.remove(tid)
                self.staging.clear(tid)
                self.journal.commit(seq, recovered="forward")
            else:
                # guest still bound: delete the orphan disk snapshot
                # (complete or torn) the failed detach may have written
                store = CheckpointStore(self.detach_store_dir, keep=0)
                step = e["details"].get("step")
                if step is not None:
                    store.remove(step)
                store.sweep_tmp()
                self.staging.clear(tid)
                self.journal.abort(seq, recovered="rollback")

        elif op in ("pause", "pause_live"):
            if status == "paused":
                # suspend ran: the registered snapshot is now the only
                # state copy — roll forward to a fully-paused VF
                if tid not in snapshots:
                    raise RuntimeError(
                        f"recovery: {tid} suspended but no snapshot "
                        "registered (unrecoverable)")
                if vf is not None:
                    if vf.state == VFState.ATTACHED:
                        vf.transition(VFState.PAUSED)
                    if vf.devices:
                        vf.release_devices()
                    vf.emulated["status"] = "paused"
                    vf.emulated["steps_done"] = tn.steps_done
                self.staging.clear(tid)
                self.journal.commit(seq, recovered="forward")
            else:
                # guest untouched: drop the half-taken snapshot + memo
                snapshots.pop(tid, None)
                self.staging.clear(tid)
                self.journal.abort(seq, recovered="rollback")

        elif op == "unpause":
            if status == "running":
                # fully resumed; only the bookkeeping commit was lost
                snapshots.pop(tid, None)
                if vf is not None:
                    vf.owner = tid
                self.journal.commit(seq, recovered="forward")
            elif status == "paused" and vf is not None:
                if vf.state == VFState.PAUSED:
                    # restore never ran — roll back: devices (if any were
                    # re-allocated) return to the pool, snapshot retained
                    if vf.devices:
                        vf.release_devices()
                    self.journal.abort(seq, recovered="rollback")
                else:
                    # VF re-attached but guest not resumed — roll forward:
                    # redo the restore from the retained snapshot
                    snap = snapshots.get(tid)
                    if snap is None:
                        raise RuntimeError(
                            f"recovery: {tid} mid-unpause but no snapshot "
                            "registered (unrecoverable)")
                    state = self.staging.restore(snap.payload,
                                                 tn.shardings_for(vf))
                    tn.steps_done = snap.steps_done
                    tn.resume(state, vf)
                    vf.owner = tid
                    vf.emulated["status"] = "running"
                    snapshots.pop(tid, None)
                    self.journal.commit(seq, recovered="forward")
            else:
                self.journal.abort(seq, recovered="rollback")

        elif op == "migrate":
            # inner pause/unpause entries were reconciled first (newest-
            # first order), so the tenant is already in a clean state:
            # running -> the migrate completed; paused -> it stalled after
            # the pause half, which is a clean (resumable) rollback point
            if status == "running":
                self.journal.commit(seq, recovered="forward")
            else:
                self.journal.abort(seq, recovered="rollback")

        elif op == "migrate_request":
            # request-granular migration. Predicate: the TARGET owns the
            # request => the admit committed, roll FORWARD (source frees
            # its copy); otherwise roll BACK (target drops any partial
            # admission, source thaws the frozen slot and keeps serving).
            # Every callee is idempotent, so double recovery (I9) holds.
            # Cross-host entries (details carry ``dst_host``) resolve the
            # target through ``peer_lookup``; when the destination host
            # is unreachable the entry is DEFERRED — left pending with
            # the frozen source slot intact — because the target may have
            # admitted, and rolling back blind would serve the request on
            # two hosts (I15). The next ``recover`` after the partition
            # heals resolves it exactly once (I16).
            rid = e["details"].get("rid")
            dst_host = e["details"].get("dst_host")
            dtn = self.tenants.get(e["details"].get("dst"))
            if dtn is None and dst_host and self.peer_lookup is not None:
                try:
                    dtn = self.peer_lookup(dst_host, e["details"]["dst"])
                except HostUnreachableError:
                    self.journal.defer(seq, deferred_cross_host=True)
                    return
            self.staging.clear(f"{tid}/mig:{rid}")
            dst_owns = (dtn is not None and hasattr(dtn, "owns_request")
                        and dtn.owns_request(rid))
            if dst_owns:
                if tn is not None and hasattr(tn, "release_request"):
                    tn.release_request(rid)
                self.journal.commit(seq, recovered="forward")
            else:
                if dtn is not None and hasattr(dtn, "abort_incoming"):
                    dtn.abort_incoming(rid)
                if tn is not None and hasattr(tn, "abort_migration"):
                    tn.abort_migration(rid)
                self.journal.abort(seq, recovered="rollback")

        elif op == "attach_group":
            # member attach entries are NEWER than the gang entry, so by
            # newest-first order each member is already cleanly running or
            # cleanly unbound. Predicate: the gang fully formed -> forward.
            members = [self.tenants.get(m)
                       for m in e["details"].get("members", [])]
            if members and all(getattr(m, "status", None) == "running"
                               for m in members):
                self.journal.commit(seq, recovered="forward")
            else:
                # partial gang: detach whichever members bound — no leaked
                # VFs, no half-bound stages (the lead ends detached, its
                # state parked on disk like any failed single attach)
                for m in members:
                    if getattr(m, "status", None) == "running":
                        self.detach(m)
                self.journal.abort(seq, recovered="rollback")

        elif op == "detach_group":
            # forward-only: a detach_group intent always completes
            for mid in e["details"].get("members", []):
                mt = self.tenants.get(mid)
                if getattr(mt, "status", None) == "running":
                    self.detach(mt)
            self.journal.commit(seq, recovered="forward")

        elif op == "reshape":
            # predicate: the gang holds exactly k_new running members ->
            # the member deltas completed, roll forward by (re-)selecting
            # the k_new template (idempotent); otherwise undo the deltas
            # back to k_old. Either way the live gang matches exactly one
            # registered template (I14).
            det = e["details"]
            k_old, k_new = det.get("k_old"), det.get("k_new")
            shells = tuple(getattr(tn, "gang_shells", ()) or ())
            alive = int(status == "running") + sum(
                1 for s in shells
                if getattr(s, "status", None) == "running")
            if tn is not None and status == "running" and alive == k_new:
                tn.apply_reshape(k_new)
                self.journal.commit(seq, recovered="forward")
            else:
                for mid in det.get("added", []):
                    mt = self.tenants.get(mid)
                    if getattr(mt, "status", None) == "running":
                        self.detach(mt)
                for s in shells:
                    if (s.tid in det.get("dropped", [])
                            and getattr(s, "status", None) != "running"):
                        self.attach(s)
                if tn is not None and hasattr(tn, "apply_reshape"):
                    tn.apply_reshape(k_old)       # no-op: width never moved
                self.journal.abort(seq, recovered="rollback")

        else:                                     # unknown op: never applied
            self.journal.abort(seq, recovered="rollback")
