"""ControlPlane — the QMP analogue (paper §IV-B2).

The paper registers a new QMP command (``device_pause <id> <status>``) in
QEMU's monitor; when executed, the monitor calls the device class's pause
callback. Here: a JSON command bus with registered handlers dispatching
into the SVFFManager, plus an optional Unix-socket server speaking
newline-delimited JSON — so external tooling can drive reconfiguration
exactly like libvirt drives QEMU.

Protocol: request  {"execute": <cmd>, "arguments": {...}}
          response {"return": ...} | {"error": {"class", "desc"}}
"""
from __future__ import annotations

import json
import os
import socket
import threading
from typing import Callable, Optional

from repro.core.fault import InjectedCrash, crashpoint
from repro.core.manager import SVFFManager
from repro.core.tenant import DevicePausedError


class QMPError(RuntimeError):
    pass


class ControlPlane:
    def __init__(self, manager: SVFFManager):
        self.manager = manager
        self._commands: dict[str, Callable] = {}
        self._register_builtin()
        self._server: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------- commands
    def register(self, name: str, fn: Callable):
        self._commands[name] = fn

    def _register_builtin(self):
        m = self.manager

        def device_pause(args):
            tid = args["id"]
            pause = bool(args.get("pause", True))
            tn = m.tenants.get(tid)
            if tn is None:
                raise QMPError(f"no tenant {tid}")
            vf = m.pool.find(tn.vf_id)
            if not vf.pausable:
                raise QMPError(f"{vf.vf_id} does not support pause")
            if pause:
                t = m.pause(tn)
            else:
                t = m.unpause(tn)
            return {"timings": t.phases, "status": tn.status}

        def device_add(args):
            tid = args["id"]
            tn = m.tenants.get(tid)
            if tn is None:
                raise QMPError(f"unknown tenant {tid} (register first)")
            t = m.attach(tn, args.get("vf"))
            return {"timings": t.phases, "vf": tn.vf_id}

        def device_del(args):
            tn = m.tenants.get(args["id"])
            if tn is None:
                raise QMPError(f"no tenant {args['id']}")
            t = m.detach(tn)
            return {"timings": t.phases}

        self.register("device_pause", device_pause)
        self.register("device_add", device_add)
        self.register("device_del", device_del)
        self.register("system-rescan",
                      lambda a: {"devices": m.pool.rescan()})
        self.register("query-vfs", lambda a: m.pool.query())
        self.register("query-status", lambda a: m.query())
        self.register("reconf",
                      lambda a: m.reconf(int(a["num_vfs"]),
                                         use_pause=a.get("use_pause")))
        self.register("query-tenant",
                      lambda a: m.tenants[a["id"]].query())

    def execute(self, request: dict) -> dict:
        cmd = request.get("execute")
        args = request.get("arguments", {}) or {}
        if cmd not in self._commands:
            return {"error": {"class": "CommandNotFound",
                              "desc": f"unknown command {cmd!r}"}}
        try:
            ret = self._commands[cmd](args)
            # crash window: the command ran but the monitor dies before the
            # response leaves — the client sees a timeout; every journaled
            # mutation is already committed, so recovery has nothing to do
            # and an idempotent re-query observes the applied state
            crashpoint("qmp_timeout")
            return {"return": ret}
        except InjectedCrash:
            raise          # chaos: the monitor dies, no error response
        except (QMPError, DevicePausedError, KeyError, RuntimeError) as e:
            return {"error": {"class": type(e).__name__, "desc": str(e)}}

    def execute_json(self, line: str) -> str:
        try:
            req = json.loads(line)
        except json.JSONDecodeError as e:
            return json.dumps({"error": {"class": "JSONParse",
                                         "desc": str(e)}})
        return json.dumps(self.execute(req))

    # ------------------------------------------------------------- socket
    def serve_unix(self, path: str) -> threading.Thread:
        if os.path.exists(path):
            os.remove(path)
        srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        srv.bind(path)
        srv.listen(4)
        srv.settimeout(0.2)

        def loop():
            greeting = json.dumps(
                {"QMP": {"version": "svff-0.1",
                         "capabilities": ["device_pause"]}})
            while not self._stop.is_set():
                try:
                    conn, _ = srv.accept()
                except socket.timeout:
                    continue
                with conn:
                    conn.sendall((greeting + "\n").encode())
                    buf = b""
                    conn.settimeout(2.0)
                    try:
                        while not self._stop.is_set():
                            chunk = conn.recv(65536)
                            if not chunk:
                                break
                            buf += chunk
                            while b"\n" in buf:
                                line, buf = buf.split(b"\n", 1)
                                if line.strip():
                                    resp = self.execute_json(line.decode())
                                    conn.sendall((resp + "\n").encode())
                    except socket.timeout:
                        pass
            srv.close()

        self._server = threading.Thread(target=loop, daemon=True)
        self._server.start()
        return self._server

    def shutdown(self):
        self._stop.set()
        if self._server:
            self._server.join(timeout=3)
