"""Fault tolerance: heartbeats, straggler detection, automatic recovery.

At 1000+ node scale, slow or dead workers are routine. The SVFF mechanism
gives a clean recovery primitive: a straggling tenant is *paused* (its
state leaves the sick devices) and *unpaused* onto healthy ones — the
tenant's loop never observes a teardown, exactly like a guest surviving a
reconfiguration. Checkpoint/restart (launch/train.py --resume) covers the
host-loss case the pause path cannot.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core.manager import SVFFManager
from repro.core.tenant import Tenant


@dataclass
class Heartbeat:
    last_beat: float = 0.0
    step_times: list = field(default_factory=list)

    def beat(self, step_time: float):
        self.last_beat = time.time()
        self.step_times.append(step_time)
        if len(self.step_times) > 64:
            self.step_times = self.step_times[-64:]


class HeartbeatMonitor:
    """Tracks per-tenant step latencies; flags stragglers and the dead."""

    def __init__(self, straggler_factor: float = 3.0,
                 dead_after_s: float = 30.0):
        self.straggler_factor = straggler_factor
        self.dead_after_s = dead_after_s
        self.beats: dict[str, Heartbeat] = {}

    def record(self, tenant_id: str, step_time: float):
        self.beats.setdefault(tenant_id, Heartbeat()).beat(step_time)

    def _median(self) -> Optional[float]:
        recent = [hb.step_times[-1] for hb in self.beats.values()
                  if hb.step_times]
        return statistics.median(recent) if recent else None

    def stragglers(self) -> list[str]:
        med = self._median()
        if med is None or med == 0:
            return []
        return [tid for tid, hb in self.beats.items()
                if hb.step_times and
                hb.step_times[-1] > self.straggler_factor * med]

    def dead(self) -> list[str]:
        now = time.time()
        return [tid for tid, hb in self.beats.items()
                if hb.last_beat and now - hb.last_beat > self.dead_after_s]


class Supervisor:
    """Runs tenants under monitoring; migrates stragglers automatically."""

    def __init__(self, manager: SVFFManager,
                 monitor: Optional[HeartbeatMonitor] = None):
        self.manager = manager
        self.monitor = monitor or HeartbeatMonitor()
        self.events: list[dict] = []

    def run_round(self, steps: int = 1) -> dict:
        """One supervision round: every running tenant advances `steps`;
        failures trigger migration; stragglers are rebound."""
        results = {}
        for tid, tn in list(self.manager.tenants.items()):
            if tn.status != "running":
                continue
            try:
                metrics = tn.run_steps(steps)
                self.monitor.record(tid, tn.step_times[-1])
                results[tid] = metrics
            except RuntimeError as e:                 # device failure
                self.events.append({"kind": "failure", "tenant": tid,
                                    "err": str(e), "t": time.time()})
                info = self.manager.migrate(tn)
                self.events.append({"kind": "migrated", "tenant": tid,
                                    **info})
                results[tid] = {"recovered": True}
        for tid in self.monitor.stragglers():
            tn = self.manager.tenants.get(tid)
            if tn is not None and tn.status == "running":
                self.events.append({"kind": "straggler", "tenant": tid})
                info = self.manager.migrate(tn)
                self.events.append({"kind": "migrated", "tenant": tid,
                                    **info})
                self.monitor.beats.pop(tid, None)
        return results
