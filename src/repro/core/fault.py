"""Fault tolerance: heartbeats, straggler detection, automatic recovery —
and the crash-injection plane the chaos harness arms.

At 1000+ node scale, slow or dead workers are routine. The SVFF mechanism
gives a clean recovery primitive: a straggling tenant is *paused* (its
state leaves the sick devices) and *unpaused* onto healthy ones — the
tenant's loop never observes a teardown, exactly like a guest surviving a
reconfiguration. Checkpoint/restart (launch/train.py --resume) covers the
host-loss case the pause path cannot.

Crash plane
-----------
``crashpoint(name)`` marks a named crash window in the manager/staging
stack (see ``repro.sim.chaos.CRASH_POINTS`` for the catalogue). In
production it is a no-op; the chaos harness arms one point at a time via
``crash_plane.arm(name)`` and the next execution of that window raises
``InjectedCrash`` — modelling the management process dying there. The
harness then rebuilds a manager with ``SVFFManager.recover`` and asserts
the full invariant suite. This module is intentionally a leaf (no manager
import at module scope) so every core module can call ``crashpoint``.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


# ---------------------------------------------------------------------------
# crash injection
# ---------------------------------------------------------------------------
class InjectedCrash(RuntimeError):
    """Raised at an armed crash point — the management plane 'dies' here.

    Deliberately NOT a subclass of any rejection type the sim harness
    tolerates: an injected crash must never be absorbed as an "expected
    rejection"; it either reaches the chaos handler or fails the test.
    """

    def __init__(self, point: str):
        super().__init__(f"injected crash at {point!r}")
        self.point = point


class CrashPlane:
    """One-shot crash-point trigger. ``arm(name)`` primes the plane; the
    next ``fire(name)`` for that point disarms it and raises
    ``InjectedCrash`` (one crash per arm, so recovery code re-entering the
    same window does not crash again). ``hits`` counts every window
    executed while armed — tests use it to prove a point was reached."""

    def __init__(self):
        import threading
        self._lock = threading.Lock()
        self.armed: Optional[str] = None
        self.fired: Optional[str] = None
        self.hits: list[str] = []

    def arm(self, point: str) -> None:
        with self._lock:
            self.armed = point
            self.fired = None

    def disarm(self) -> None:
        with self._lock:
            self.armed = None

    def fire(self, point: str) -> None:
        # cheap unarmed fast path; the lock makes the one-shot exact even
        # when windows run on staging queue threads
        if self.armed is None:
            return
        with self._lock:
            if self.armed is None:
                return
            self.hits.append(point)
            if point != self.armed:
                return
            self.armed = None          # one-shot: recovery must not re-crash
            self.fired = point
        raise InjectedCrash(point)


#: process-wide plane; the sim arms it through ``repro.sim.chaos``
crash_plane = CrashPlane()


def crashpoint(name: str) -> None:
    """Named crash window — no-op unless the chaos plane armed ``name``."""
    crash_plane.fire(name)


# ---------------------------------------------------------------------------
# heartbeats / stragglers
# ---------------------------------------------------------------------------
@dataclass
class Heartbeat:
    # None = never beat; 0.0 is a VALID beat time under an injected
    # virtual clock (a falsy-check here once made t=0 beats invisible)
    last_beat: Optional[float] = None
    step_times: list = field(default_factory=list)

    def beat(self, step_time: float, now: float):
        self.last_beat = now
        self.step_times.append(step_time)
        if len(self.step_times) > 64:
            self.step_times = self.step_times[-64:]


class HeartbeatMonitor:
    """Tracks per-tenant step latencies; flags stragglers and the dead.

    ``clock`` is any zero-arg callable returning seconds (default wall
    clock); the sim passes ``VirtualClock.now`` so dead/straggler
    thresholds are deterministic and testable."""

    def __init__(self, straggler_factor: float = 3.0,
                 dead_after_s: float = 30.0,
                 clock: Callable[[], float] = time.time):
        self.straggler_factor = straggler_factor
        self.dead_after_s = dead_after_s
        self.clock = clock
        self.beats: dict[str, Heartbeat] = {}

    def record(self, tenant_id: str, step_time: float):
        self.beats.setdefault(tenant_id, Heartbeat()).beat(step_time,
                                                          self.clock())

    def _median(self) -> Optional[float]:
        recent = [hb.step_times[-1] for hb in self.beats.values()
                  if hb.step_times]
        return statistics.median(recent) if recent else None

    def stragglers(self) -> list[str]:
        med = self._median()
        if med is None or med == 0:
            return []
        return [tid for tid, hb in self.beats.items()
                if hb.step_times and
                hb.step_times[-1] > self.straggler_factor * med]

    def dead(self) -> list[str]:
        now = self.clock()
        return [tid for tid, hb in self.beats.items()
                if hb.last_beat is not None
                and now - hb.last_beat > self.dead_after_s]


class Supervisor:
    """Runs tenants under monitoring; migrates stragglers automatically."""

    def __init__(self, manager, monitor: Optional[HeartbeatMonitor] = None,
                 clock: Callable[[], float] = time.time):
        self.manager = manager
        self.clock = clock
        self.monitor = monitor or HeartbeatMonitor(clock=clock)
        self.events: list[dict] = []

    def run_round(self, steps: int = 1) -> dict:
        """One supervision round: every running tenant advances `steps`;
        failures trigger migration; stragglers are rebound."""
        results = {}
        for tid, tn in list(self.manager.tenants.items()):
            if tn.status != "running":
                continue
            try:
                metrics = tn.run_steps(steps)
                self.monitor.record(tid, tn.step_times[-1])
                results[tid] = metrics
            except InjectedCrash:
                raise                                 # chaos: not a failure
            except RuntimeError as e:                 # device failure
                self.events.append({"kind": "failure", "tenant": tid,
                                    "err": str(e), "t": self.clock()})
                info = self.manager.migrate(tn)
                self.events.append({"kind": "migrated", "tenant": tid,
                                    **info})
                results[tid] = {"recovered": True}
        for tid in self.monitor.stragglers():
            tn = self.manager.tenants.get(tid)
            if tn is not None and tn.status == "running":
                self.events.append({"kind": "straggler", "tenant": tid,
                                    "t": self.clock()})
                info = self.manager.migrate(tn)
                self.events.append({"kind": "migrated", "tenant": tid,
                                    **info})
                self.monitor.beats.pop(tid, None)
        return results
