"""Autoscaler — the elastic SLO control plane's policy loop.

The paper's automation story ends at "reconfigure when asked"; SYNERGY
and Virtio-FPGA make the case that FPGA virtualization pays off when a
scheduler *oversubscribes and rebalances dynamically*. This module closes
that loop: a telemetry snapshot of the serving fleet goes in, at most one
reconfiguration action comes out, and the executor (``ServeFleet`` for
real engines, the scenario harness for sim tenants) applies it through
the EXISTING journaled manager ops — attach / detach / reconf / migrate —
so crash recovery (PR 3) covers autoscaler-initiated actions for free.

Action kinds:

  scale_out   spawn (or re-attach a parked) engine tenant on a fresh VF —
              the cheap path attaches to an existing detached VF, the
              grow path runs the paper's full reconf cycle (+1 VF)
  scale_in    drain + detach an IDLE engine; its state parks on disk and
              its VF (still holding devices, SR-IOV semantics) becomes
              the next scale_out's cheap path
  rebalance   pick the most-loaded / least-loaded running pair, move
              queued (not-yet-admitted) requests hot -> cold — requests
              that have emitted nothing are free to move (I10-safe) —
              then live-migrate IN-FLIGHT requests through the journaled
              request-migration op (KV block chains ship hot -> cold,
              token streams unchanged), and finally migrate the hot
              victim via pause -> fresh devices -> unpause without
              dropping its in-flight batch
  reshape     change a pipeline gang's stage width K -> K±1 through the
              journaled reshape op: grow the hottest gang when the engine
              count is maxed but VFs remain; shrink a gang whose MEASURED
              schedule bubble shows it burning a VF on idle ticks

The policy is deliberately conservative and fully deterministic:

  * hysteresis — a condition must hold for ``hysteresis`` consecutive
    observation epochs before it triggers (one hot sample never scales);
  * cooldown — after any action the loop is silent for ``cooldown``
    epochs, so oscillating load cannot flap the fleet;
  * every ``Action`` carries the ``TelemetrySnapshot`` it was planned
    from, and ``justify_action`` re-derives the action's necessary
    conditions from that snapshot alone — invariant **I11** (sim) checks
    it after every autoscale op, so an action the telemetry does not
    support is a caught bug, not a silent misconfiguration.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """One engine's slice of a telemetry snapshot (cheap to build: counts
    and pre-aggregated window percentiles, no per-request data)."""
    tid: str
    index: int                  # creation order — the placement tie-break
    status: str                 # created|running|paused|detached
    load: int = 0               # queued + in-flight prefill + active slots
    queue_depth: int = 0
    inflight: int = 0
    prefill_jobs: int = 0
    ttft_p95_ms: float = 0.0
    itl_p95_ms: float = 0.0
    rejected: int = 0           # fleet-side rejections attributed here
    # paged-KV cache pressure (zeros when the engine is dense): the pool
    # can thrash while queues stay short, so queue depth alone is blind
    cache_exhausted: int = 0    # cumulative CacheExhausted events
    defrag_events: int = 0      # cumulative production defragment() passes
    pages_in_use: int = 0       # allocator pages currently owned
    pages_free: int = 0         # allocator pages currently free
    # request live migration (zeros when the fleet never migrates):
    # attempts/outcomes are attributed to the SOURCE engine; stall ticks
    # count decode iterations a slot sat frozen mid-hand-off
    migrations_attempted: int = 0
    migrations_completed: int = 0
    migrations_aborted: int = 0
    migration_blocks_shipped: int = 0
    migration_stall_ticks: int = 0
    # pipeline width (1 for single-VF engines): the second action
    # dimension. ``stage_loads``/``bubble_frac`` are MEASURED from the
    # engine's GPipe schedule walls (runtime.pipeline.schedule_stats),
    # so a width action is justified by evidence, not geometry
    stage_width: int = 1
    stage_width_max: int = 1
    stage_loads: tuple = ()
    bubble_frac: float = 0.0


@dataclasses.dataclass(frozen=True)
class TelemetrySnapshot:
    """What the policy loop reads: per-engine stats plus the capacity
    facts (free VFs / growth headroom) that gate scale-out."""
    epoch: int
    slo_max_load: int
    engines: tuple = ()
    free_vfs: int = 0           # detached, unowned, device-holding VFs
    grow_budget: int = 0        # extra VFs a reconf could still create
    rejected_recent: int = 0    # fleet-wide rejections since last snapshot
    age_s: float = 0.0          # age of the OLDEST evidence in this view
                                # (0 for a locally-built snapshot; the
                                # federation stamps replicated snapshots
                                # and a partition makes them grow old)

    def running(self) -> tuple:
        return tuple(e for e in self.engines if e.status == "running")

    def hot_threshold(self, cfg: "AutoscaleConfig") -> int:
        return max(1, math.ceil(cfg.scale_out_load * self.slo_max_load))

    def describe(self) -> dict:
        return {"epoch": self.epoch,
                "engines": {e.tid: e.load for e in self.engines},
                "free_vfs": self.free_vfs,
                "grow_budget": self.grow_budget}


@dataclasses.dataclass(frozen=True)
class AutoscaleAction:
    """One planned reconfiguration. ``snapshot`` is the evidence — I11
    re-derives the action's preconditions from it, nothing else."""
    kind: str                   # scale_out | scale_in | rebalance | reshape
    snapshot: TelemetrySnapshot
    victim: Optional[str] = None    # scale_in: engine to park;
                                    # rebalance/reshape: the engine acted on
    target: Optional[str] = None    # rebalance: the cold engine
    width: Optional[int] = None     # reshape: the new stage width K'
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    scale_out_load: float = 0.75    # hot = load >= this x slo_max_load
    rebalance_gap: int = 8          # hot-cold load gap that triggers a move
    hysteresis: int = 2             # consecutive epochs before acting
    cooldown: int = 4               # silent epochs after any action
    min_engines: int = 1
    max_engines: int = 8
    rebalance_migrate: bool = True  # migrate the hot victim after stealing
    pinned: tuple = ()              # engines never eligible for scale_in
                                    # (e.g. the fleet's ingress engine)
    reshape_bubble: float = 0.5     # shrink a gang when its MEASURED
                                    # schedule bubble reaches this share
    max_staleness_s: float = math.inf   # refuse to act on a snapshot whose
                                        # evidence is older than this (the
                                        # federation sets it so a partition
                                        # cannot drive scaling from a stale
                                        # replicated view — I11's age arm)


ACTION_KINDS = ("scale_out", "scale_in", "rebalance", "reshape")


def justify_action(action: AutoscaleAction,
                   cfg: AutoscaleConfig) -> Optional[str]:
    """Re-derive ``action``'s necessary conditions from the snapshot it
    carries; returns an error string when the telemetry does not support
    the action (the I11 violation text), else None. Deliberately
    stateless: hysteresis/cooldown are policy NICETIES, but an action is
    only ever legal if its instantaneous preconditions held in the
    snapshot it read."""
    snap = action.snapshot
    if snap.age_s > cfg.max_staleness_s:
        return (f"action planned from stale telemetry: age "
                f"{snap.age_s:.3f}s > bound {cfg.max_staleness_s:.3f}s")
    running = snap.running()
    by_tid = {e.tid: e for e in running}
    if action.kind == "scale_out":
        thr = snap.hot_threshold(cfg)
        if not any(e.load >= thr for e in running):
            return (f"scale_out with no engine at load >= {thr} "
                    f"(loads {[e.load for e in running]})")
        if snap.free_vfs <= 0 and snap.grow_budget <= 0:
            return "scale_out without a free VF or growth headroom"
        if len(running) >= cfg.max_engines:
            return (f"scale_out past max_engines={cfg.max_engines} "
                    f"({len(running)} running)")
    elif action.kind == "scale_in":
        e = by_tid.get(action.victim)
        if e is None:
            return f"scale_in victim {action.victim!r} not running"
        if e.load != 0 or e.prefill_jobs:
            return (f"scale_in of busy engine {e.tid} (load {e.load}, "
                    f"{e.prefill_jobs} prefill jobs)")
        if len(running) <= cfg.min_engines:
            return (f"scale_in below min_engines={cfg.min_engines}")
        if e.tid in cfg.pinned:
            return f"scale_in of pinned engine {e.tid}"
    elif action.kind == "rebalance":
        v, t = by_tid.get(action.victim), by_tid.get(action.target)
        if v is None or t is None:
            return (f"rebalance pair {action.victim!r}->{action.target!r} "
                    "not both running")
        if v.load - t.load < cfg.rebalance_gap:
            return (f"rebalance without imbalance: {v.tid}@{v.load} vs "
                    f"{t.tid}@{t.load} < gap {cfg.rebalance_gap}")
        if v.queue_depth <= 0 and v.inflight <= 0:
            # queued requests move for free; in-flight ones move through
            # the journaled request-migration op — either justifies it
            return (f"rebalance with nothing queued or in flight on "
                    f"{v.tid} to move")
    elif action.kind == "reshape":
        e = by_tid.get(action.victim)
        if e is None:
            return f"reshape victim {action.victim!r} not running"
        w = action.width
        if w is None or w < 1 or w == e.stage_width:
            return (f"reshape of {e.tid} to width {w!r} from "
                    f"{e.stage_width}")
        if w > e.stage_width_max:
            return (f"reshape of {e.tid} to width {w} past its "
                    f"template ceiling {e.stage_width_max}")
        if w > e.stage_width:
            thr = snap.hot_threshold(cfg)
            if e.load < thr:
                return (f"grow-reshape of {e.tid} at load {e.load} < "
                        f"hot threshold {thr}")
            if snap.free_vfs < w - e.stage_width:
                return (f"grow-reshape of {e.tid} needs "
                        f"{w - e.stage_width} free VF(s), have "
                        f"{snap.free_vfs}")
        else:
            # shrinking trades latency of a LIVE gang for capacity: only
            # measured idleness (bubble) or full idleness justifies it
            if e.bubble_frac < cfg.reshape_bubble and e.load != 0:
                return (f"shrink-reshape of busy {e.tid} with measured "
                        f"bubble {e.bubble_frac:.2f} < "
                        f"{cfg.reshape_bubble}")
    else:
        return f"unknown action kind {action.kind!r}"
    return None


class Autoscaler:
    """The decision loop: feed it one ``TelemetrySnapshot`` per epoch
    (``observe``), get back at most one ``AutoscaleAction``. Priority
    when several conditions hold: rebalance (cheapest — moves queued
    work) > scale_out (adds capacity) > scale_in (returns capacity);
    scale_in never fires while any engine is hot."""

    def __init__(self, cfg: Optional[AutoscaleConfig] = None):
        self.cfg = cfg or AutoscaleConfig()
        self.history: list[AutoscaleAction] = []
        self._cooldown = 0
        self._hot_streak = 0
        self._idle_streak: dict[str, int] = {}

    # ------------------------------------------------------------------
    def observe(self, snap: TelemetrySnapshot
                ) -> Optional[AutoscaleAction]:
        cfg = self.cfg
        if snap.age_s > cfg.max_staleness_s:
            # stale evidence plans nothing AND advances nothing: streaks
            # and cooldown freeze, so one fresh post-heal snapshot cannot
            # combine with pre-partition streak state to trigger an action
            return None
        running = snap.running()
        thr = snap.hot_threshold(cfg)
        hot = [e for e in running if e.load >= thr]

        # streak bookkeeping happens every epoch, cooldown or not, so a
        # condition that persists through the cooldown fires right after
        self._hot_streak = self._hot_streak + 1 if hot else 0
        live = set()
        for e in running:
            live.add(e.tid)
            idle = e.load == 0 and e.prefill_jobs == 0
            self._idle_streak[e.tid] = (
                self._idle_streak.get(e.tid, 0) + 1 if idle else 0)
        for tid in list(self._idle_streak):
            if tid not in live:
                del self._idle_streak[tid]

        if self._cooldown > 0:
            self._cooldown -= 1
            return None

        action = self._plan(snap, running, hot, thr)
        if action is not None:
            self._cooldown = cfg.cooldown
            self._hot_streak = 0
            self.history.append(action)
        return action

    # ------------------------------------------------------------------
    def _plan(self, snap, running, hot, thr) -> Optional[AutoscaleAction]:
        cfg = self.cfg
        if hot and self._hot_streak >= cfg.hysteresis:
            hottest = max(hot, key=lambda e: (e.load, -e.index))
            if len(running) >= 2:
                coldest = min(running, key=lambda e: (e.load, e.index))
                if (hottest.load - coldest.load >= cfg.rebalance_gap
                        and (hottest.queue_depth > 0
                             or hottest.inflight > 0)):
                    return AutoscaleAction(
                        "rebalance", snap, victim=hottest.tid,
                        target=coldest.tid,
                        reason=(f"{hottest.tid}@{hottest.load} vs "
                                f"{coldest.tid}@{coldest.load} "
                                f">= gap {cfg.rebalance_gap}"))
            if (len(running) < cfg.max_engines
                    and (snap.free_vfs > 0 or snap.grow_budget > 0)):
                return AutoscaleAction(
                    "scale_out", snap,
                    reason=(f"{hottest.tid} at load {hottest.load} >= "
                            f"hot threshold {thr}"))
            # engine count maxed but free VFs remain: widen the hottest
            # gang instead (one more pipeline stage absorbs the load
            # without another engine's params copy)
            wide = [e for e in hot if e.stage_width < e.stage_width_max]
            if snap.free_vfs > 0 and wide:
                victim = max(wide, key=lambda e: (e.load, -e.index))
                return AutoscaleAction(
                    "reshape", snap, victim=victim.tid,
                    width=victim.stage_width + 1,
                    reason=(f"{victim.tid} at load {victim.load} >= "
                            f"{thr} with engines maxed; widening "
                            f"K={victim.stage_width}->"
                            f"{victim.stage_width + 1}"))
            return None
        if not hot:
            # a gang whose MEASURED schedule bubble crossed the threshold
            # is burning a VF on idle ticks: narrow it first — cheaper
            # than parking a whole engine, and the freed VF becomes the
            # next scale_out/grow-reshape's cheap path
            bubbly = [e for e in running
                      if e.stage_width > 1
                      and e.bubble_frac >= cfg.reshape_bubble]
            if bubbly:
                victim = max(bubbly,
                             key=lambda e: (e.bubble_frac, -e.index))
                return AutoscaleAction(
                    "reshape", snap, victim=victim.tid,
                    width=victim.stage_width - 1,
                    reason=(f"{victim.tid} measured bubble "
                            f"{victim.bubble_frac:.2f} >= "
                            f"{cfg.reshape_bubble}; narrowing "
                            f"K={victim.stage_width}->"
                            f"{victim.stage_width - 1}"))
        if not hot and len(running) > cfg.min_engines:
            idle = [e for e in running
                    if e.tid not in cfg.pinned
                    and self._idle_streak.get(e.tid, 0) >= cfg.hysteresis]
            if idle:
                # park the NEWEST idle engine: the oldest engines carry
                # the longest-lived executables/caches and stay
                victim = max(idle, key=lambda e: e.index)
                return AutoscaleAction(
                    "scale_in", snap, victim=victim.tid,
                    reason=(f"{victim.tid} idle for >= "
                            f"{cfg.hysteresis} epochs"))
        return None
