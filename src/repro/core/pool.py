"""DevicePool — the Physical Function analogue (paper §II-B).

The pool owns the host's accelerator devices and carves them into VFs.
Like SR-IOV, changing the VF partition requires every VF to be host-
detached (ATTACHED VFs block ``set_num_vfs`` — that is precisely the
limitation the pause functionality works around: PAUSED VFs hold no
devices, so repartitioning proceeds while tenants keep their logical
device).

Invariants (property-tested):
  * device sets of device-holding VFs are pairwise disjoint (IOMMU groups)
  * every VF's devices all come from this pool
  * len(devices(vf)) == prod(vf.mesh_shape)
"""
from __future__ import annotations

import math
import time
from typing import Optional, Sequence

import jax

from repro.core.vf import VFState, VirtualFunction


class PoolError(RuntimeError):
    pass


def _default_mesh_shape(n: int) -> tuple:
    """Factor n into a 2D (data, model) mesh, as square as possible."""
    best = (n, 1)
    for d in range(1, int(math.isqrt(n)) + 1):
        if n % d == 0:
            best = (n // d, d)
    return best


class DevicePool:
    def __init__(self, devices: Optional[Sequence] = None,
                 pf_id: str = "0000:03:00.0", max_vfs: int = 252):
        # paper §IV-A: QDMA supports up to 4 PFs x 252 VFs
        self.pf_id = pf_id
        self.max_vfs = max_vfs
        self._devices = tuple(devices) if devices is not None else None
        self.vfs: dict[str, VirtualFunction] = {}
        self._rescanned = False

    # -- discovery ("pci rescan", Table II step 1) -----------------------------
    def rescan(self) -> int:
        t0 = time.perf_counter()
        if self._devices is None:
            self._devices = tuple(jax.devices())
        # validation sweep: confirm every device answers (a cheap put/get,
        # like reading the vendor id of each function on the bus).
        # Simulated pools (repro.sim) hold plain tokens, which have no bus
        # to probe — only real jax devices get the put/get.
        for d in self._devices:
            if isinstance(d, jax.Device):
                jax.device_put(0, d).block_until_ready()
        self._rescanned = True
        self.last_rescan_s = time.perf_counter() - t0
        return len(self._devices)

    @property
    def devices(self) -> tuple:
        if not self._rescanned:
            self.rescan()
        return self._devices

    @property
    def num_devices(self) -> int:
        return len(self.devices)

    # -- VF table ----------------------------------------------------------------
    def _check_invariants(self):
        seen = {}
        for vf in self.vfs.values():
            assert len(vf.devices) in (0, math.prod(vf.mesh_shape))
            for d in vf.devices:
                if d in seen:
                    raise PoolError(
                        f"device {d} owned by both {seen[d]} and {vf.vf_id}"
                        " (IOMMU isolation violated)")
                if d not in self.devices:
                    raise PoolError(f"{vf.vf_id} holds foreign device {d}")
                seen[d] = vf.vf_id

    def set_num_vfs(self, n: int, devices_per_vf: Optional[int] = None,
                    mesh_axes: tuple = ("data", "model")) -> list:
        """The SR-IOV 'echo N > sriov_numvfs' analogue.

        Fails if any VF still holds devices in ATTACHED state — the SR-IOV
        limitation the paper describes (§IV-B1): "it requires the removal
        of all the VFs ... before changing it". PAUSED VFs are fine (they
        hold no devices) and survive the repartition.
        """
        if n > self.max_vfs:
            raise PoolError(f"{n} > max_vfs {self.max_vfs}")
        blockers = [vf.vf_id for vf in self.vfs.values()
                    if vf.state == VFState.ATTACHED]
        if blockers:
            raise PoolError(
                f"cannot change #VF while VFs are attached: {blockers} "
                "(detach or pause them first)")
        paused = {k: vf for k, vf in self.vfs.items()
                  if vf.state == VFState.PAUSED}
        self.vfs = dict(paused)          # paused VFs keep their identity
        if n == 0:
            self._check_invariants()
            return []
        per = devices_per_vf or max(1, self.num_devices // n)
        if per * n > self.num_devices:
            raise PoolError(
                f"{n} VFs x {per} devices exceed pool of {self.num_devices}")
        shape = _default_mesh_shape(per)
        created = []
        for i in range(n):
            vf_id = f"{self.pf_id[:-1]}{i + 1}"      # BDF-style .1, .2, ...
            if vf_id in self.vfs:                     # paused survivor
                continue
            vf = VirtualFunction(vf_id=vf_id, mesh_axes=mesh_axes)
            vf.assign_devices(
                self.devices[i * per:(i + 1) * per], shape)
            self.vfs[vf_id] = vf
            created.append(vf)
        self._check_invariants()
        return created

    def free_devices(self) -> list:
        used = {d for vf in self.vfs.values() for d in vf.devices}
        return [d for d in self.devices if d not in used]

    def allocate(self, vf: VirtualFunction, num: int,
                 avoid: Sequence = ()):
        """(Re)assign ``num`` free devices to a VF (unpause onto a possibly
        different slice). ``avoid`` devices are used only as a last resort
        — migration passes the sick slice here so the tenant actually
        lands elsewhere whenever the pool allows it."""
        free = self.free_devices()
        if len(free) < num:
            raise PoolError(f"need {num} devices, only {len(free)} free")
        avoided = set(avoid)
        ordered = ([d for d in free if d not in avoided]
                   + [d for d in free if d in avoided])
        vf.assign_devices(ordered[:num], _default_mesh_shape(num))
        self._check_invariants()

    def find(self, vf_id: str) -> VirtualFunction:
        if vf_id not in self.vfs:
            raise PoolError(f"no such VF {vf_id}")
        return self.vfs[vf_id]

    def query(self) -> dict:
        return {
            "pf_id": self.pf_id,
            "num_devices": self.num_devices,
            "num_vfs": len(self.vfs),
            "free_devices": len(self.free_devices()),
            "vfs": [vf.describe() for vf in self.vfs.values()],
        }
