"""OpJournal — write-ahead intent log for reconfiguration operations.

Every mutating manager op (attach / detach / pause / pause_live / unpause
/ migrate) follows the WAL discipline:

    entry = journal.begin(op, tenant, vf_id=..., ...)   # BEFORE any mutation
    ... mutate pool / tenant / records / snapshots ...
    journal.commit(entry)                               # AFTER the last one

A crash anywhere in between leaves a *pending* entry on disk;
``SVFFManager.recover`` reconciles each pending entry against the
surviving state (pool, guests, records, RAM snapshots) and either rolls
the op forward to completion or rolls it back, then resolves the entry.

Durability follows the same discipline as ``RecordStore``/
``CheckpointStore``: each entry is one JSON file written to ``*.part``,
flushed + fsync'd, then atomically renamed into place; status changes
(pending -> committed | aborted) rewrite the file the same way, so a
crash mid-write can at worst leave a ``*.part`` file (ignored on read,
swept by recovery) — never a torn entry.
"""
from __future__ import annotations

import copy
import json
import os
from typing import Optional

PENDING = "pending"
COMMITTED = "committed"
ABORTED = "aborted"

#: canonical catalogue of journaled ops -> the tenant status a COMMITTED
#: entry implies. Single source of truth for recovery, the I8 replay in
#: sim/invariants.py, and the chaos harness's outcome checks.
COMPLETED_STATUS = {"attach": "running", "detach": "detached",
                    "pause": "paused", "pause_live": "paused",
                    "unpause": "running", "migrate": "running",
                    # request-granular live migration: the SOURCE tenant
                    # (the journaled tenant) keeps serving its batch, so a
                    # committed entry still implies "running"
                    "migrate_request": "running",
                    # gang ops: the journaled tenant is the gang LEAD; its
                    # shell members journal their own attach/detach entries
                    # inside the gang window, and a reshape leaves the lead
                    # serving throughout
                    "attach_group": "running",
                    "detach_group": "detached",
                    "reshape": "running"}

#: ops recovery knows how to reconcile (and I8 knows how to replay)
JOURNALED_OPS = tuple(COMPLETED_STATUS)


class JournalError(RuntimeError):
    pass


def _fsync_dir(path: str) -> None:
    """Make a rename durable (no-op on platforms without dir fsync)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class OpJournal:
    def __init__(self, directory: str, *,
                 compact_every: Optional[int] = None,
                 compact_keep: int = 32):
        """``compact_every``: auto-compaction threshold — whenever the
        number of RESOLVED entries on disk reaches it, ``compact`` runs
        with ``keep=compact_keep`` (newest resolved entries retained, so
        the I8 replay still sees every tenant's latest committed op).
        ``None`` (the default) keeps the pre-federation behaviour:
        compaction is an explicit operator action only. Long federation
        runs pass ``compact_every`` so the WAL stays bounded."""
        self.dir = directory
        if compact_every is not None and compact_every < 1:
            raise ValueError(f"compact_every must be >= 1, "
                             f"got {compact_every}")
        self.compact_every = compact_every
        self.compact_keep = compact_keep
        os.makedirs(directory, exist_ok=True)
        self._seq = self._max_seq()
        # entry cache: the invariant checker replays the journal after
        # every op — without this, each check re-reads every entry file.
        # The files stay the source of truth (a fresh OpJournal over the
        # same dir reloads them); the cache only assumes no SECOND writer
        # mutates the directory behind this instance's back.
        self._cache: Optional[dict[int, dict]] = None

    # ------------------------------------------------------------------ files
    def _path(self, seq: int) -> str:
        return os.path.join(self.dir, f"op_{seq:08d}.json")

    def _max_seq(self) -> int:
        mx = 0
        for fn in os.listdir(self.dir):
            if fn.startswith("op_") and fn.endswith(".json"):
                try:
                    mx = max(mx, int(fn[3:-5]))
                except ValueError:
                    pass
        return mx

    def _load(self) -> dict[int, dict]:
        if self._cache is None:
            cache: dict[int, dict] = {}
            for fn in sorted(os.listdir(self.dir)):
                if fn.startswith("op_") and fn.endswith(".json"):
                    with open(os.path.join(self.dir, fn)) as f:
                        e = json.load(f)
                    cache[e["seq"]] = e
            self._cache = cache
        return self._cache

    def _write(self, entry: dict) -> None:
        p = self._path(entry["seq"])
        tmp = p + ".part"
        with open(tmp, "w") as f:
            json.dump(entry, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, p)
        _fsync_dir(self.dir)
        self._load()[entry["seq"]] = copy.deepcopy(entry)

    # ------------------------------------------------------------------ WAL
    def begin(self, op: str, tenant: str,
              vf_id: Optional[str] = None, **details) -> int:
        """Log the intent to run ``op`` on ``tenant``; returns the entry
        seq. Must be called after validation but BEFORE the first
        mutation, so a rejected op never leaves a pending entry."""
        if op not in JOURNALED_OPS:
            raise JournalError(f"unknown journaled op {op!r}")
        self._seq += 1
        entry = {"seq": self._seq, "op": op, "tenant": tenant,
                 "vf_id": vf_id, "status": PENDING, "details": details}
        self._write(entry)
        return self._seq

    def _resolve(self, seq: int, status: str, **extra) -> None:
        entry = self.read(seq)
        if entry["status"] != PENDING:
            raise JournalError(
                f"entry {seq} already {entry['status']}, cannot {status}")
        entry["status"] = status
        entry["details"].update(extra)
        self._write(entry)
        if self.compact_every is not None:
            resolved = sum(1 for e in self._load().values()
                           if e["status"] != PENDING)
            if resolved >= self.compact_every:
                self.compact(keep=self.compact_keep)

    def defer(self, seq: int, **extra) -> None:
        """Mark a PENDING entry as deferred (details updated, status kept
        pending): recovery could not resolve it — typically a cross-host
        migrate whose destination host is unreachable — and will retry on
        the next ``recover``. Idempotent: re-deferring with the same
        details never rewrites the file, so a double recovery stays a
        bit-identical no-op (I16)."""
        entry = self.read(seq)
        if entry["status"] != PENDING:
            raise JournalError(
                f"entry {seq} already {entry['status']}, cannot defer")
        if all(entry["details"].get(k) == v for k, v in extra.items()):
            return
        entry["details"].update(extra)
        self._write(entry)

    def commit(self, seq: int, **extra) -> None:
        self._resolve(seq, COMMITTED, **extra)

    def abort(self, seq: int, **extra) -> None:
        """Mark an entry rolled back (state returned to the pre-op one)."""
        self._resolve(seq, ABORTED, **extra)

    # ------------------------------------------------------------------ read
    def read(self, seq: int) -> dict:
        e = self._load().get(seq)
        if e is None:
            raise JournalError(f"no journal entry {seq}")
        return copy.deepcopy(e)

    def entries(self) -> list[dict]:
        """All entries in begin (seq) order; ``*.part`` files ignored.
        Returns defensive copies — use ``iter_entries`` in hot read-only
        paths (the invariant checker replays the journal after every op)."""
        return [copy.deepcopy(e) for e in self.iter_entries()]

    def iter_entries(self):
        """Entries in seq order WITHOUT copying — read-only: mutating a
        yielded dict corrupts the cache."""
        return sorted(self._load().values(), key=lambda e: e["seq"])

    def pending(self) -> list[dict]:
        return [copy.deepcopy(e) for e in self.iter_entries()
                if e["status"] == PENDING]

    def sweep_parts(self) -> int:
        """Remove torn ``*.part`` files left by a crash mid-write."""
        n = 0
        for fn in os.listdir(self.dir):
            if fn.endswith(".part"):
                os.remove(os.path.join(self.dir, fn))
                n += 1
        return n

    def compact(self, keep: int = 0) -> int:
        """Drop resolved entries (all but the newest ``keep``); pending
        entries are never dropped. Returns how many were removed.

        Triggered automatically only when ``compact_every`` was set (the
        federation's bounded-WAL mode); otherwise an explicit operator/
        offline action. Compaction drops an OLDEST-first prefix of the
        resolved entries, so whenever any entry of a tenant survives its
        NEWEST committed one survives — the I8 replay (which keys each
        tenant on its latest committed op) stays sound, merely vacuous
        for tenants whose entire history was dropped."""
        resolved = [e for e in self.entries() if e["status"] != PENDING]
        drop = resolved[:-keep] if keep else resolved
        for e in drop:
            os.remove(self._path(e["seq"]))
            self._load().pop(e["seq"], None)
        return len(drop)
