"""Attention: GQA reference implementation + kernel dispatch.

``kernel_backend='reference'`` is pure jnp (used by CPU tests and by the
dry-run so ``cost_analysis`` counts true attention FLOPs). ``'pallas'``
routes to the Pallas TPU kernels (validated in interpret mode on CPU).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _group(q, num_kv):
    B, S, H, hd = q.shape
    G = H // num_kv
    return q.reshape(B, S, num_kv, G, hd), G


def attention_ref(q, k, v, *, causal: bool, q_offset=0,
                  kv_len=None) -> jax.Array:
    """q: (B,Sq,H,hd); k,v: (B,T,K,hd). GQA-aware, fp32 softmax.

    q_offset: absolute position of q[0] (prefill continuation / decode).
    kv_len: optional valid KV length (int or scalar array) — masks t >= kv_len.
    """
    B, Sq, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    qg, G = _group(q, K)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    mask = None
    if causal:
        s_pos = jnp.arange(Sq)[:, None] + q_offset
        t_pos = jnp.arange(T)[None, :]
        mask = t_pos <= s_pos                                  # (Sq, T)
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    if kv_len is not None:
        kv_len = jnp.asarray(kv_len)
        if kv_len.ndim == 0:                                   # scalar
            lm = (jnp.arange(T) < kv_len)[None, None, None, None, :]
        else:                                                  # per-batch (B,)
            lm = (jnp.arange(T)[None, :] <
                  kv_len[:, None])[:, None, None, None, :]
        logits = jnp.where(lm, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, hd)


def attention(q, k, v, *, causal: bool, backend: str = "reference",
              q_offset=0, kv_len=None, interpret: bool = False) -> jax.Array:
    # q_offset may be a traced offset (chunked prefill) — only a static 0
    # may take the fused kernel, and a tracer must not be bool()'d
    if (backend == "pallas" and kv_len is None
            and isinstance(q_offset, int) and q_offset == 0):
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal,
                                    interpret=interpret)
    return attention_ref(q, k, v, causal=causal, q_offset=q_offset,
                         kv_len=kv_len)


def decode_attention(q, k_cache, v_cache, pos, *, backend: str = "reference",
                     interpret: bool = False) -> jax.Array:
    """Single-token decode. q: (B,1,H,hd); caches: (B,S,K,hd); pos: scalar —
    the index the current token was just written to (attend to <= pos).
    Per-slot pos (B,) is the continuous-batching shape; pos[b] < 0 marks an
    inactive slot (kv_len 0 — its output is meaningless and discarded)."""
    if backend == "pallas" and jnp.asarray(pos).ndim == 0:
        from repro.kernels import ops as kops
        return kops.flash_decode(q, k_cache, v_cache, pos,
                                 interpret=interpret)
    return attention_ref(q, k_cache, v_cache, causal=False, kv_len=pos + 1)


def paged_decode_attention(q, k_pages, v_pages, tables, pos, *,
                           k_scale=None, v_scale=None,
                           backend: str = "reference",
                           interpret: bool = False) -> jax.Array:
    """Single-token decode over the paged KV pool. q: (B,1,H,hd);
    k_pages/v_pages: (P,page,K,hd); tables: (B,NP) int32 page ids; pos:
    (B,) int32 last valid logical index (attend <= pos; < 0 = inactive
    slot, output row exactly zero). With ``k_scale``/``v_scale``
    ((P,page,K) fp32) the pools are int8 and the quantized kernel
    dequantizes in-tile."""
    from repro.kernels import ops as kops
    if k_scale is not None:
        if backend == "pallas":
            return kops.paged_decode_quant(q, k_pages, v_pages, k_scale,
                                           v_scale, tables, pos,
                                           interpret=interpret)
        return kops.paged_decode_quant(q, k_pages, v_pages, k_scale,
                                       v_scale, tables, pos, backend="ref")
    if backend == "pallas":
        return kops.paged_decode(q, k_pages, v_pages, tables, pos,
                                 interpret=interpret)
    return kops.paged_decode(q, k_pages, v_pages, tables, pos,
                             backend="ref")
