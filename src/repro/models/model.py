"""Model orchestration: block dispatch, period-scan over the layer stack,
train/prefill/decode entry points, loss, and ShapeDtypeStruct specs for the
dry-run. One code path serves all 10 assigned architectures; family
differences are entirely data-driven from ModelConfig.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN, MAMBA, MLSTM, SLSTM, ModelConfig,
                                RunConfig, ShapeConfig)
from repro.models import params as P
from repro.models.attention import (attention, decode_attention,
                                    paged_decode_attention)
from repro.models.layers import apply_rope, embed_lookup, rms_norm, swiglu
from repro.models.moe import moe_ffn
from repro.models.ssm import mamba_block
from repro.models.xlstm import mlstm_block, slstm_block
from repro.runtime.partitioning import constrain

_BLOCK_FNS = {MAMBA: mamba_block, MLSTM: mlstm_block, SLSTM: slstm_block}


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ===========================================================================
# attention mixer
# ===========================================================================
def _attn_mixer(cfg: ModelConfig, p: dict, x, cdt, mode, cache, positions,
                pos, backend, interpret, causal=True, tables=None,
                active=None):
    B, S, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = rms_norm(x, p["ln1"], cfg.norm_eps).astype(cdt)
    if mode != "decode":
        h = constrain(h, "hidden_full")   # SP: gather seq for TP qkv
    q = (h @ p["wq"].astype(cdt)).reshape(B, S, H, hd)
    k = (h @ p["wk"].astype(cdt)).reshape(B, S, K, hd)
    v = (h @ p["wv"].astype(cdt)).reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if mode != "decode":
        q = constrain(q, "attn_q")
        k = constrain(k, "attn_kv")
        v = constrain(v, "attn_kv")

    new_cache = None
    if mode == "decode" and tables is not None:
        # paged KV: the cache leaf is the shared page pool (P, page, K, hd);
        # slot b's new token lands in page tables[b, pos//page] at offset
        # pos%page. Inactive slots (active[b] False) are redirected to the
        # reserved garbage page 0, so an idle slot's pages stay untouched
        # and its attention (pos[b] = -1 -> zero valid tokens) reads none.
        posa = jnp.asarray(pos)
        page = cache["k"].shape[1]
        posw = jnp.maximum(posa, 0)
        rows = jnp.arange(B)
        pids = tables[rows, posw // page]
        offs = posw % page
        if active is not None:
            pids = jnp.where(active, pids, 0)
        if "k_scale" in cache:
            # int8 pool (kv_dtype='int8'): quantize the new token's row
            # on write — per-(slot,head) symmetric scale over hd — and
            # land scale + int8 payload at the same (page, offset)
            from repro.serve.paged import kv_quantize
            kq, ks = kv_quantize(k[:, 0])
            vq, vs = kv_quantize(v[:, 0])
            kc = cache["k"].at[pids, offs].set(kq)
            vc = cache["v"].at[pids, offs].set(vq)
            ksc = cache["k_scale"].at[pids, offs].set(ks)
            vsc = cache["v_scale"].at[pids, offs].set(vs)
            o = paged_decode_attention(q, kc, vc, tables, posa,
                                       k_scale=ksc, v_scale=vsc,
                                       backend=backend, interpret=interpret)
            new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
        else:
            kc = cache["k"].at[pids, offs].set(
                k[:, 0].astype(cache["k"].dtype))
            vc = cache["v"].at[pids, offs].set(
                v[:, 0].astype(cache["v"].dtype))
            o = paged_decode_attention(q, kc, vc, tables, posa,
                                       backend=backend, interpret=interpret)
            new_cache = {"k": kc, "v": vc}
    elif mode == "decode":
        posa = jnp.asarray(pos)
        if posa.ndim == 0:       # uniform position: dynamic_update_slice
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        else:                    # per-slot positions (continuous batching)
            rows = jnp.arange(B)
            posw = jnp.maximum(posa, 0)
            knew = k[:, 0].astype(cache["k"].dtype)
            vnew = v[:, 0].astype(cache["v"].dtype)
            if active is not None:
                # masked scatter: an inactive slot writes back the bytes it
                # already holds, so its cache rows are bit-untouched (and
                # nothing lands at position 0 for an empty slot)
                knew = jnp.where(active[:, None, None],
                                 knew, cache["k"][rows, posw])
                vnew = jnp.where(active[:, None, None],
                                 vnew, cache["v"][rows, posw])
            kc = cache["k"].at[rows, posw].set(knew)
            vc = cache["v"].at[rows, posw].set(vnew)
        kc = constrain(kc, "kv_cache")
        vc = constrain(vc, "kv_cache")
        o = decode_attention(q, kc, vc, pos, backend=backend,
                             interpret=interpret)
        new_cache = {"k": kc, "v": vc}
    elif mode == "prefill_chunk":
        # chunked-prefill continuation: append this chunk's KV at offset
        # ``pos`` and attend causally against everything cached so far
        # (kv_len masks the not-yet-written tail, incl. any chunk padding)
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        o = attention(q, kc, vc, causal=True, q_offset=pos,
                      backend=backend, interpret=interpret)
        new_cache = {"k": kc, "v": vc}
    else:
        o = attention(q, k, v, causal=causal, backend=backend,
                      interpret=interpret)
        if mode == "prefill":
            new_cache = {"k": constrain(k, "kv_cache"),
                         "v": constrain(v, "kv_cache")}
        # reshard the (bf16) attention output explicitly — otherwise GSPMD
        # may place the seq->replicated gather inside downstream fp32 norm
        # internals, doubling the bytes (§Perf HC2)
        o = constrain(o, "attn_q")
    out = o.reshape(B, S, H * hd).astype(cdt) @ p["wo"].astype(cdt)
    if mode != "decode":
        out = constrain(out, "hidden")
    return out, new_cache


def _cross_mixer(cfg: ModelConfig, p: dict, x, cdt, mode, cache, memory,
                 backend, interpret):
    """Encoder-decoder cross attention. memory: (B, Te, D) or None if the
    projected memory (xk/xv) is already in the cache (decode)."""
    B, S, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = rms_norm(x, p["ln_x"], cfg.norm_eps).astype(cdt)
    q = (h @ p["xq"].astype(cdt)).reshape(B, S, H, hd)
    if mode == "decode":
        xk, xv = cache["xk"], cache["xv"]
    else:
        m = memory.astype(cdt)
        Te = m.shape[1]
        xk = (m @ p["xk"].astype(cdt)).reshape(B, Te, K, hd)
        xv = (m @ p["xv"].astype(cdt)).reshape(B, Te, K, hd)
    o = attention(q, xk, xv, causal=False, backend=backend,
                  interpret=interpret)
    out = o.reshape(B, S, H * hd).astype(cdt) @ p["xo"].astype(cdt)
    new_cache = {"xk": xk, "xv": xv} if mode in ("prefill", "decode") else None
    return out, new_cache


def _apply_ffn(cfg: ModelConfig, p: dict, x, cdt):
    aux = {}
    if "ln2" not in p:
        return x, aux
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    y = jnp.zeros_like(x)
    if "ffn" in p:
        # SP mode: gather the sequence (bf16) exactly here for the TP
        # matmuls; the reduce-scatter back happens at the block-boundary
        # "hidden" constraint (Megatron-SP placement, §Perf HC2 it.3)
        hf = constrain(h, "hidden_full")
        y = y + swiglu(hf, p["ffn"]["wi"], p["ffn"]["wg"], p["ffn"]["wo"],
                       cdt)
    if "moe" in p:
        ym, aux = moe_ffn(h, p["moe"], cfg.moe, cdt)
        y = y + ym
    return x + y.astype(x.dtype), aux


def _apply_block(cfg, run: RunConfig, kind: str, p, x, mode, cache_j,
                 positions, pos, memory, causal=True, cross=False,
                 tables=None, active=None):
    cdt = _dt(run.precision.compute)
    backend = run.kernel_backend
    interpret = backend == "pallas" and jax.default_backend() != "tpu"
    new_cache = {}
    if kind == ATTN:
        out, nc = _attn_mixer(cfg, p, x, cdt, mode, cache_j, positions, pos,
                              backend, interpret, causal=causal,
                              tables=tables, active=active)
        x = x + out
        if nc:
            new_cache.update(nc)
        if cross:
            out, ncx = _cross_mixer(cfg, p, x, cdt, mode, cache_j, memory,
                                    backend, interpret)
            x = x + out
            if ncx:
                new_cache.update(ncx)
    else:
        out, nc = _BLOCK_FNS[kind](cfg, p, x, cdt, mode=mode, cache=cache_j,
                                   backend=backend, interpret=interpret)
        if nc and active is not None and mode == "decode":
            # recurrent per-slot state: an inactive slot's cells must stay
            # bit-untouched (its row would otherwise integrate garbage)
            nc = jax.tree.map(
                lambda n, o: jnp.where(
                    active.reshape(active.shape + (1,) * (n.ndim - 1)),
                    n, o),
                nc, {k_: cache_j[k_] for k_ in nc})
        x = x + out
        if nc:
            new_cache.update(nc)
    x, aux = _apply_ffn(cfg, p, x, cdt)
    x = constrain(x, "hidden")
    return x, (new_cache or None), aux


# ===========================================================================
# layer-stack scan
# ===========================================================================
ZERO_AUX = {"load_balance": 0.0, "router_z": 0.0}


def run_stack(cfg: ModelConfig, run: RunConfig, layers: dict, x, mode,
              cache=None, positions=None, pos=None, memory=None,
              is_encoder=False, tables=None, active=None):
    """Scan the (period-stacked) layer stack.

    layers: {"block{j}": tree stacked over periods}
    cache: same structure (or None); returned updated for prefill/decode.
    tables/active: paged-KV block tables + active-slot mask (decode only;
    see ``Model.decode_step``) — layer-invariant, so threaded by closure.
    """
    pattern = (ATTN,) if is_encoder else cfg.block_pattern
    plen = len(pattern)
    nper = (cfg.num_encoder_layers if is_encoder else cfg.num_layers) // plen
    causal = not is_encoder
    cross = cfg.is_encoder_decoder and not is_encoder
    with_cache = (mode in ("prefill", "prefill_chunk", "decode")
                  and not is_encoder)

    def period_fn(x, aux_in, period_params, period_cache):
        aux_acc = dict(aux_in)
        new_caches = {}
        for j in range(plen):
            cj = period_cache.get(f"block{j}") if period_cache else None
            x, nc, aux = _apply_block(
                cfg, run, pattern[j], period_params[f"block{j}"], x, mode,
                cj, positions, pos, memory, causal=causal, cross=cross,
                tables=tables, active=active)
            if nc is not None:
                new_caches[f"block{j}"] = nc
            for k_, v_ in aux.items():
                aux_acc[k_] = aux_acc[k_] + v_
        return x, aux_acc, (new_caches if with_cache else None)

    remat = run.sharding.remat
    if remat != "none" and mode == "train":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat == "dots" else
                  jax.checkpoint_policies.nothing_saveable)
        period_fn = jax.checkpoint(period_fn, policy=policy,
                                   static_argnums=())

    if run.sharding.scan_layers and nper > 1:
        def body(carry, xs):
            x, aux = carry
            pp, pc = xs
            x, aux, ncache = period_fn(x, aux, pp, pc)
            return (x, aux), ncache
        # None is an empty pytree, so (layers, None) is a valid xs when no
        # cache flows through the stack.
        (x, aux), ncache = jax.lax.scan(body, (x, dict(ZERO_AUX)),
                                        (layers, cache))
    else:
        aux = dict(ZERO_AUX)
        ncache = {} if with_cache else None
        for i in range(nper):
            pp = jax.tree.map(lambda l: l[i], layers)
            pc = jax.tree.map(lambda l: l[i], cache) if cache else None
            x, aux, nc = period_fn(x, aux, pp, pc)
            if with_cache:
                ncache[i] = nc
        if with_cache:
            ncache = jax.tree.map(lambda *ls: jnp.stack(ls),
                                  *[ncache[i] for i in range(nper)])
    return x, aux, ncache


# ===========================================================================
# the Model
# ===========================================================================
class Model:
    """Functional model bound to a RunConfig (mesh-agnostic; sharding comes
    from the active ``sharding_scope``)."""

    def __init__(self, run: RunConfig):
        self.run = run
        self.cfg = run.model

    # -- params -------------------------------------------------------------
    def init(self, rng: jax.Array) -> dict:
        return P.init_params(self.cfg, rng, _dt(self.run.precision.params))

    def param_shapes(self) -> dict:
        return P.param_shapes(self.cfg, _dt(self.run.precision.params))

    # -- embedding / head ----------------------------------------------------
    def _embed(self, params, tokens, cdt):
        return embed_lookup(params["embed"]["tok"], tokens, cdt)

    def _logits(self, params, x):
        ldt = _dt(self.run.precision.logits)
        if self.cfg.tie_embeddings:
            w = params["embed"]["tok"]
            out = jnp.einsum("bsd,vd->bsv", x.astype(ldt), w.astype(ldt))
        else:
            out = x.astype(ldt) @ params["lm_head"].astype(ldt)
        return constrain(out, "logits")

    def _encode(self, params, frames, cdt):
        x = frames.astype(cdt)
        x = constrain(x, "hidden")
        pos = jnp.arange(x.shape[1])
        x, _, _ = run_stack(self.cfg, self.run, params["encoder"]["layers"],
                            x, "train", positions=pos, is_encoder=True)
        return rms_norm(x, params["encoder"]["final_norm"], self.cfg.norm_eps)

    # -- forward (train / prefill) -------------------------------------------
    def forward(self, params, batch, mode="train"):
        cfg, run = self.cfg, self.run
        cdt = _dt(run.precision.compute)
        x = self._embed(params, batch["tokens"], cdt)
        memory = None
        if cfg.frontend.kind == "vision":
            x = jnp.concatenate([batch["patches"].astype(cdt), x], axis=1)
        if cfg.is_encoder_decoder:
            memory = self._encode(params, batch["frames"], cdt)
        x = constrain(x, "hidden")
        positions = jnp.arange(x.shape[1])
        x, aux, cache = run_stack(cfg, run, params["decoder"]["layers"], x,
                                  mode, positions=positions, memory=memory)
        x = rms_norm(x, params["decoder"]["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits, aux, cache

    # -- loss -----------------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        logits, aux, _ = self.forward(params, batch, mode="train")
        if cfg.frontend.kind == "vision":          # text positions only
            logits = logits[:, cfg.frontend.num_patches:]
        labels = batch["labels"]
        Vp = logits.shape[-1]
        # mask the padded vocab tail
        vmask = (jnp.arange(Vp) < cfg.vocab_size)
        logits = jnp.where(vmask, logits, -1e30)
        valid = labels >= 0
        safe = jnp.clip(labels, 0, cfg.vocab_size - 1)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        # gold logit via one-hot contraction, NOT take_along_axis: a gather
        # over the vocab(-TP-sharded) dim makes SPMD all-gather the full
        # fp32 logits; the masked reduction keeps everything local and the
        # partitioner emits only a tiny (B,S) all-reduce.  §Perf iteration 1.
        onehot = (jnp.arange(Vp)[None, None, :] == safe[..., None])
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        ce = jnp.where(valid, lse - gold, 0.0)
        ntok = jnp.maximum(jnp.sum(valid), 1)
        ce_mean = jnp.sum(ce) / ntok
        aux_total = sum(aux.values())
        loss = ce_mean + aux_total
        metrics = {"loss": loss, "ce": ce_mean, "ntok": ntok, **aux}
        return loss, metrics

    # -- serving ---------------------------------------------------------------
    def prefill(self, params, batch):
        """Returns (cache, last_logits)."""
        logits, _, cache = self.forward(params, batch, mode="prefill")
        return cache, logits[:, -1]

    def decode_step(self, params, cache, tokens, pos, *, tables=None,
                    active=None):
        """tokens: (B,1) int32; pos: scalar int32 (uniform) or (B,) int32
        (per-slot, continuous batching) — the slot the new token occupies
        (attends to <= pos). Returns (logits (B,V), new_cache).

        active: optional (B,) bool — False rows are masked OUT of the
        decode: their cache bytes (KV rows / recurrent state) stay
        bit-untouched and their attention reads zero tokens (pos[b] must
        be < 0 for them). Their logits are garbage and must be discarded.

        tables: optional (B,NP) int32 paged-KV block tables. When given,
        attention-cache leaves are page pools (nper, P, page, K, hd) —
        see ``repro.serve.paged`` — and ``pos`` is per-slot logical
        position; page 0 is reserved as the garbage page."""
        cfg, run = self.cfg, self.run
        cdt = _dt(run.precision.compute)
        x = self._embed(params, tokens, cdt)
        x = constrain(x, "hidden")
        posa = jnp.asarray(pos)
        if posa.ndim == 0:
            positions = jnp.reshape(pos, (1,))
        else:
            # rope positions must be in-range even for inactive (-1) slots
            positions = jnp.maximum(posa, 0)[:, None]
        x, _, cache = run_stack(cfg, run, params["decoder"]["layers"], x,
                                "decode", cache=cache, positions=positions,
                                pos=pos, tables=tables, active=active)
        x = rms_norm(x, params["decoder"]["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits[:, 0], cache

    def prefill_chunk(self, params, cache, tokens, offset):
        """One chunk of a chunked prefill: process ``tokens`` (B,C) at
        absolute positions [offset, offset+C), appending KV into the dense
        staging ``cache`` and attending causally against every earlier
        chunk. Returns (cache, logits (B,C,V)) — the caller picks the
        logits row of the last REAL token (trailing chunk padding yields
        garbage rows that are never used, and the padded KV tail is
        overwritten by decode before it can ever be attended).

        Only attention-pattern stacks support this (recurrent blocks would
        need their chunk-boundary state threaded); callers gate on
        ``cfg.attention_free`` / ``block_pattern``."""
        cfg, run = self.cfg, self.run
        cdt = _dt(run.precision.compute)
        x = self._embed(params, tokens, cdt)
        x = constrain(x, "hidden")
        positions = offset + jnp.arange(x.shape[1])
        x, _, cache = run_stack(cfg, run, params["decoder"]["layers"], x,
                                "prefill_chunk", cache=cache,
                                positions=positions, pos=offset)
        x = rms_norm(x, params["decoder"]["final_norm"], cfg.norm_eps)
        return cache, self._logits(params, x)

    # =========================================================================
    # specs (dry-run: ShapeDtypeStructs, no allocation)
    # =========================================================================
    def input_specs(self, shape: Optional[ShapeConfig] = None) -> dict:
        cfg = self.cfg
        shape = shape or self.run.shape
        B, S = shape.global_batch, shape.seq_len
        cdt = _dt(self.run.precision.compute)
        i32 = jnp.int32

        def sd(shp, dt):
            return jax.ShapeDtypeStruct(shp, dt)

        if shape.kind == "train":
            specs = {"tokens": sd((B, S), i32), "labels": sd((B, S), i32)}
        elif shape.kind == "prefill":
            specs = {"tokens": sd((B, S), i32)}
        else:  # decode: one new token against a cache of length S
            return {"tokens": sd((B, 1), i32), "pos": sd((), i32)}
        if cfg.frontend.kind == "vision":
            specs["patches"] = sd((B, cfg.frontend.num_patches, cfg.d_model),
                                  cdt)
        if cfg.is_encoder_decoder:
            Te = S // cfg.frontend.frame_ratio
            specs["frames"] = sd((B, Te, cfg.d_model), cdt)
        return specs

    def cache_specs(self, shape: Optional[ShapeConfig] = None) -> dict:
        """Decode-cache ShapeDtypeStructs: (periods, B, ...) per block."""
        cfg = self.cfg
        shape = shape or self.run.shape
        B, S = shape.global_batch, shape.seq_len
        cdt = _dt(self.run.precision.compute)
        plen = len(cfg.block_pattern)
        nper = cfg.num_layers // plen
        K, hd = cfg.num_kv_heads, cfg.head_dim
        D = cfg.d_model

        def sd(shp, dt=cdt):
            return jax.ShapeDtypeStruct((nper,) + shp, dt)

        tree = {}
        for j, kind in enumerate(cfg.block_pattern):
            if kind == ATTN:
                c = {"k": sd((B, S, K, hd)), "v": sd((B, S, K, hd))}
                if cfg.is_encoder_decoder:
                    Te = S // cfg.frontend.frame_ratio
                    c["xk"] = sd((B, Te, K, hd))
                    c["xv"] = sd((B, Te, K, hd))
            elif kind == MAMBA:
                di, nh, _, ch = P.mamba_dims(cfg)
                c = {"conv": sd((B, cfg.ssm.conv_dim - 1, ch)),
                     "ssm": sd((B, nh, cfg.ssm.head_dim, cfg.ssm.d_state),
                               jnp.float32)}
            elif kind == MLSTM:
                di, nh = P.mlstm_dims(cfg)
                hdm = cfg.xlstm.head_dim
                c = {"mlstm": {"C": sd((B, nh, hdm, hdm), jnp.float32),
                               "n": sd((B, nh, hdm), jnp.float32),
                               "m": sd((B, nh), jnp.float32)}}
            elif kind == SLSTM:
                c = {"slstm": {k_: sd((B, D), jnp.float32)
                               for k_ in ("h", "c", "n", "m")}}
            tree[f"block{j}"] = c
        return tree

    def init_cache(self, shape: Optional[ShapeConfig] = None) -> dict:
        def one(path, s):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name == "m":       # exp-gate stabilizers start at -inf-ish
                return jnp.full(s.shape, -1e30, s.dtype)
            return jnp.zeros(s.shape, s.dtype)
        return jax.tree_util.tree_map_with_path(one, self.cache_specs(shape))


def build_model(run: RunConfig) -> Model:
    return Model(run)
