"""Single source of truth for parameter trees.

``param_shapes(cfg)`` builds the full parameter tree as ShapeDtypeStructs;
``init_params`` materializes it; ``count_params_config`` folds it. Sharding
rules (runtime/shardings.py) and the dry-run consume the same tree, so the
three can never disagree.

Layer stacks are *period-stacked*: the repeating block pattern (len divides
num_layers) is scanned over ``num_periods``, so every leaf belonging to block
position ``j`` of the pattern carries a leading ``(num_periods,)`` dim. This
keeps the HLO O(pattern) instead of O(layers) (95-layer deepseek compiles as
one scanned block) while supporting heterogeneous stacks (jamba, xlstm).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN, MAMBA, MLSTM, SLSTM, ModelConfig)

VOCAB_PAD = 128  # pad vocab so TP over the model axis always divides


def padded_vocab(cfg: ModelConfig) -> int:
    return ((cfg.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


# ---------------------------------------------------------------------------
# derived dims
# ---------------------------------------------------------------------------
def mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm.head_dim
    # in_proj emits [x (d_inner), z (d_inner), B (d_state), C (d_state),
    #                dt (n_heads)]
    d_in_proj = 2 * d_inner + 2 * cfg.ssm.d_state + n_heads
    d_conv_ch = d_inner + 2 * cfg.ssm.d_state   # conv over x, B, C
    return d_inner, n_heads, d_in_proj, d_conv_ch


def mlstm_dims(cfg: ModelConfig):
    di = int(cfg.xlstm.proj_factor * cfg.d_model)
    di = (di // cfg.xlstm.head_dim) * cfg.xlstm.head_dim
    n_heads = di // cfg.xlstm.head_dim
    return di, n_heads


def slstm_dims(cfg: ModelConfig):
    # simplified sLSTM: recurrence at d_model width, per-head block-diagonal
    # recurrent weights, post-recurrence GLU at slstm_proj_factor.
    heads = cfg.num_heads
    dh = cfg.d_model // heads
    d_up = int(cfg.xlstm.slstm_proj_factor * cfg.d_model)
    d_up = (d_up // 8) * 8
    return heads, dh, d_up


# ---------------------------------------------------------------------------
# per-block shapes (logical, un-stacked)
# ---------------------------------------------------------------------------
def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def ffn_shapes(cfg: ModelConfig, layer_idx: int, dtype) -> dict:
    """FFN half of a block — orthogonal to the mixer kind (jamba has an
    MLP/MoE after *every* mixer, attention or mamba alike)."""
    D = cfg.d_model
    has_moe = cfg.layer_has_moe(layer_idx)
    dense = (cfg.d_ff > 0) and (not has_moe or
                                (cfg.moe and cfg.moe.dense_residual))
    p = {}
    if dense or has_moe:
        p["ln2"] = _sd((D,), dtype)
    if dense:
        F = cfg.d_ff
        p["ffn"] = {"wi": _sd((D, F), dtype), "wg": _sd((D, F), dtype),
                    "wo": _sd((F, D), dtype)}
    if has_moe:
        E, Fe = cfg.moe.num_experts, cfg.moe.d_ff
        p["moe"] = {
            "router": _sd((D, E), jnp.float32),   # router in fp32 (stability)
            "wi": _sd((E, D, Fe), dtype),
            "wg": _sd((E, D, Fe), dtype),
            "wo": _sd((E, Fe, D), dtype),
        }
    return p


def attn_block_shapes(cfg: ModelConfig, layer_idx: int, dtype,
                      cross: bool = False) -> dict:
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "ln1": _sd((D,), dtype),
        "wq": _sd((D, H * hd), dtype),
        "wk": _sd((D, K * hd), dtype),
        "wv": _sd((D, K * hd), dtype),
        "wo": _sd((H * hd, D), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = _sd((hd,), dtype)
        p["k_norm"] = _sd((hd,), dtype)
    if cross:
        p["ln_x"] = _sd((D,), dtype)
        p["xq"] = _sd((D, H * hd), dtype)
        p["xk"] = _sd((D, K * hd), dtype)
        p["xv"] = _sd((D, K * hd), dtype)
        p["xo"] = _sd((H * hd, D), dtype)
    p.update(ffn_shapes(cfg, layer_idx, dtype))
    return p


def mamba_block_shapes(cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    d_inner, n_heads, d_in_proj, d_conv_ch = mamba_dims(cfg)
    return {
        "ln": _sd((D,), dtype),
        "in_proj": _sd((D, d_in_proj), dtype),
        "conv_w": _sd((cfg.ssm.conv_dim, d_conv_ch), dtype),
        "conv_b": _sd((d_conv_ch,), dtype),
        "A_log": _sd((n_heads,), jnp.float32),
        "D": _sd((n_heads,), jnp.float32),
        "dt_bias": _sd((n_heads,), jnp.float32),
        "norm": _sd((d_inner,), dtype),
        "out_proj": _sd((d_inner, D), dtype),
    }


def mlstm_block_shapes(cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    di, n_heads = mlstm_dims(cfg)
    return {
        "ln": _sd((D,), dtype),
        "w_up": _sd((D, 2 * di), dtype),       # x and gate branches
        "wq": _sd((di, di), dtype),
        "wk": _sd((di, di), dtype),
        "wv": _sd((di, di), dtype),
        "w_i": _sd((di, n_heads), jnp.float32),  # exp-gate projections
        "w_f": _sd((di, n_heads), jnp.float32),
        "b_i": _sd((n_heads,), jnp.float32),
        "b_f": _sd((n_heads,), jnp.float32),
        "norm": _sd((di,), dtype),
        "w_out": _sd((di, D), dtype),
    }


def slstm_block_shapes(cfg: ModelConfig, dtype) -> dict:
    D = cfg.d_model
    heads, dh, d_up = slstm_dims(cfg)
    gates = {}
    for g in ("z", "i", "f", "o"):
        gates[f"w_{g}"] = _sd((D, D), dtype)
        gates[f"r_{g}"] = _sd((heads, dh, dh), dtype)   # block-diag recurrent
        gates[f"b_{g}"] = _sd((D,), jnp.float32)
    return {
        "ln": _sd((D,), dtype),
        **gates,
        "norm": _sd((D,), dtype),
        "up_wi": _sd((D, d_up), dtype),
        "up_wg": _sd((D, d_up), dtype),
        "up_wo": _sd((d_up, D), dtype),
    }


def block_shapes(cfg: ModelConfig, layer_idx: int, dtype,
                 cross: bool = False) -> dict:
    kind = cfg.layer_kind(layer_idx)
    if kind == ATTN:
        return attn_block_shapes(cfg, layer_idx, dtype, cross=cross)
    if kind == MAMBA:
        p = mamba_block_shapes(cfg, dtype)
    elif kind == MLSTM:
        p = mlstm_block_shapes(cfg, dtype)
    elif kind == SLSTM:
        p = slstm_block_shapes(cfg, dtype)
    else:
        raise ValueError(kind)
    p.update(ffn_shapes(cfg, layer_idx, dtype))
    return p


# ---------------------------------------------------------------------------
# full tree
# ---------------------------------------------------------------------------
def _stack(tree: dict, n: int) -> dict:
    return jax.tree.map(lambda s: _sd((n,) + s.shape, s.dtype), tree)


def stack_param_shapes(cfg: ModelConfig, dtype, num_layers: int,
                       cross: bool = False) -> dict:
    """Period-stacked shapes for a stack of ``num_layers`` blocks."""
    plen = len(cfg.block_pattern)
    assert num_layers % plen == 0
    periods = num_layers // plen
    out = {}
    for j in range(plen):
        out[f"block{j}"] = _stack(block_shapes(cfg, j, dtype, cross=cross),
                                  periods)
    return out


def param_shapes(cfg: ModelConfig, param_dtype=jnp.float32) -> dict:
    dt = param_dtype
    D, Vp = cfg.d_model, padded_vocab(cfg)
    tree = {
        "embed": {"tok": _sd((Vp, D), dt)},
        "decoder": {
            "layers": stack_param_shapes(cfg, dt, cfg.num_layers,
                                         cross=cfg.is_encoder_decoder),
            "final_norm": _sd((D,), dt),
        },
    }
    if cfg.is_encoder_decoder:
        # encoder blocks are plain attention blocks (bidirectional at apply
        # time); the audio frontend itself is a STUB (precomputed frames).
        enc_cfg = cfg  # same dims
        tree["encoder"] = {
            "layers": stack_param_shapes(enc_cfg, dt, cfg.num_encoder_layers),
            "final_norm": _sd((D,), dt),
        }
    if not cfg.tie_embeddings:
        tree["lm_head"] = _sd((D, Vp), dt)
    return tree


def count_params_config(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = param_shapes(cfg)
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    if active_only and cfg.moe is not None:
        # subtract the inactive expert fraction of MoE weights
        moe_leaves = []

        def _collect(path, leaf):
            if any(getattr(p, "key", None) == "moe" for p in path):
                name = path[-1].key if hasattr(path[-1], "key") else ""
                if name != "router":
                    moe_leaves.append(int(np.prod(leaf.shape)))

        jax.tree_util.tree_map_with_path(_collect, shapes)
        moe_total = sum(moe_leaves)
        frac = cfg.moe.num_experts_per_token / cfg.moe.num_experts
        total = total - int(moe_total * (1.0 - frac))
    return total


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, rng: jax.Array,
                param_dtype=jnp.float32) -> dict:
    """Fan-in scaled truncated-normal init over the shape tree."""
    shapes = param_shapes(cfg, param_dtype)
    leaves, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(rng, len(leaves))

    def one(key, sd: jax.ShapeDtypeStruct):
        shp = sd.shape
        if len(shp) >= 2:
            fan_in = int(np.prod(shp[:-1]))  # period/expert dims count as fan
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            x = scale * jax.random.truncated_normal(
                key, -2.0, 2.0, shp, jnp.float32)
        else:
            x = jnp.ones(shp, jnp.float32)   # norms / biases -> 1 (gates fix below)
        return x.astype(sd.dtype)

    inited = jax.tree.unflatten(treedef, [one(k, s)
                                          for k, s in zip(keys, leaves)])

    # Targeted overrides where ones/noise are wrong:
    def fix(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        if not names:
            return leaf
        last = names[-1]
        if last in ("b_i", "b_f", "b_z", "b_o"):
            return jnp.zeros_like(leaf)
        if last == "A_log":      # mamba: A in [-eps, -~8] -> A_log ~ log range
            n = leaf.shape[-1]
            return jnp.log(jnp.linspace(1.0, 8.0, n)).astype(leaf.dtype)
        if last == "dt_bias":    # softplus^-1 of dt in [1e-3, 1e-1]
            n = leaf.shape[-1]
            dt = jnp.exp(jnp.linspace(math.log(1e-3), math.log(1e-1), n))
            return jnp.log(jnp.expm1(dt)).astype(leaf.dtype)
        if last == "D":
            return jnp.ones_like(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, inited)
