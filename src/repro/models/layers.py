"""Shared primitive layers (pure functions, explicit params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, split-half (llama) convention.

    x: (B, S, H, hd); positions: (S,) or (B, S) int.
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv      # (..., S, hd/2)
    if ang.ndim == 2:                                          # (S, hd/2)
        ang = ang[None]                                        # (1, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]                          # (B|1,S,1,hd/2)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array,
           cdt) -> jax.Array:
    x = x.astype(cdt)
    h = jax.nn.silu(x @ wg.astype(cdt)) * (x @ wi.astype(cdt))
    return h @ wo.astype(cdt)


def embed_lookup(table: jax.Array, tokens: jax.Array, cdt) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(cdt)


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: jax.Array | None = None):
    """Depthwise causal conv over time. x: (B, S, C); w: (W, C); b: (C,).

    If ``state`` is given — (B, W-1, C), the tail of the previous segment —
    it is prepended (decode / chunked prefill), and the new tail returned.
    """
    B, S, C = x.shape
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+W-1, C)
    out = jnp.zeros((B, S, C), jnp.float32)
    for i in range(W):                                        # W is tiny (4)
        out = out + xp[:, i:i + S, :].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_state = xp[:, S:, :] if W > 1 else state
    return out.astype(x.dtype), new_state
