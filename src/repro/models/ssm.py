"""Mamba block — Mamba-2 (SSD) scalar-per-head-decay formulation.

Hardware adaptation (DESIGN.md §2): Mamba-1's per-(channel,state) selective
scan is a gather/scan pattern that is VPU-bound on TPU; the SSD dual form
turns the recurrence into chunked matmuls (MXU-friendly):

  H_t = a_t * H_{t-1} + (dt_t x_t) ⊗ B_t        a_t = exp(dt_t * A_h) <= 1
  y_t = H_t · C_t + D_h x_t

Within a chunk of Q tokens the output is an attention-like einsum with the
decay mask M_ts = exp(cum_t - cum_s); across chunks an associative scan
carries the (decayed) state. All exponents are <= 0, so everything is
numerically tame without stabilizers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import causal_conv1d, rms_norm
from repro.models.params import mamba_dims


def _split_proj(cfg: ModelConfig, proj):
    d_inner, n_heads, _, _ = mamba_dims(cfg)
    N = cfg.ssm.d_state
    idx = [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N]
    x = proj[..., :idx[0]]
    z = proj[..., idx[0]:idx[1]]
    Bv = proj[..., idx[1]:idx[2]]
    Cv = proj[..., idx[2]:idx[3]]
    dt = proj[..., idx[3]:]
    return x, z, Bv, Cv, dt


def ssd_chunked(xh, Bv, Cv, log_a, h0=None, chunk: int = 128):
    """Chunkwise SSD scan.

    xh:   (B, S, H, hd)   — dt-scaled inputs (dt_t * x_t)
    Bv:   (B, S, N)       — input maps (shared across heads, ngroups=1)
    Cv:   (B, S, N)       — output maps
    log_a:(B, S, H)       — per-head log decay (<= 0), fp32
    h0:   (B, H, hd, N)   — optional initial state
    Returns y (B,S,H,hd) fp32 and final state (B,H,hd,N) fp32.
    """
    B, S, H, hd = xh.shape
    N = Bv.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    cdt = xh.dtype

    xq = xh.reshape(B, nc, Q, H, hd)
    Bq = Bv.reshape(B, nc, Q, N)
    Cq = Cv.reshape(B, nc, Q, N)
    la = log_a.astype(jnp.float32).reshape(B, nc, Q, H)
    cum = jnp.cumsum(la, axis=2)                               # (B,nc,Q,H)

    # ---- intra-chunk (dual / attention-like form) -------------------------
    Lt = jnp.transpose(cum, (0, 1, 3, 2))                      # (B,nc,H,Q)
    M = Lt[..., :, None] - Lt[..., None, :]                    # t - s
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(mask, jnp.exp(M), 0.0)                       # (B,nc,H,Q,Q)
    GB = jnp.einsum("bcqn,bcsn->bcqs", Cq.astype(jnp.float32),
                    Bq.astype(jnp.float32))
    W = (M * GB[:, :, None]).astype(cdt)                       # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bchqs,bcshd->bcqhd", W, xq)

    # ---- chunk-boundary states --------------------------------------------
    wlast = jnp.exp(cum[:, :, -1:, :] - cum)                   # (B,nc,Q,H)
    S_c = jnp.einsum("bcqh,bcqhd,bcqn->bchdn",
                     wlast.astype(cdt), xq, Bq.astype(cdt)
                     ).astype(jnp.float32)                     # (B,nc,H,hd,N)
    d_c = jnp.exp(cum[:, :, -1, :])                            # (B,nc,H)

    if h0 is None:
        h0 = jnp.zeros((B, H, hd, N), jnp.float32)
    h0 = h0.astype(jnp.float32)

    def combine(ea, eb):
        (da, sa), (db, sb) = ea, eb
        return da * db, db[..., None, None] * sa + sb

    ds, ss = jax.lax.associative_scan(combine, (d_c, S_c), axis=1)
    # state after chunk c including h0: H_c = ds_c * h0 + ss_c
    H_after = ds[..., None, None] * h0[:, None] + ss           # (B,nc,H,hd,N)
    H_prev = jnp.concatenate([h0[:, None], H_after[:, :-1]], axis=1)

    # ---- inter-chunk contribution ------------------------------------------
    y_inter = jnp.einsum("bcqn,bchdn->bcqhd", Cq.astype(jnp.float32),
                         H_prev) * jnp.exp(cum)[..., None]
    y = y_intra.astype(jnp.float32).reshape(B, S, H, hd) + \
        y_inter.reshape(B, S, H, hd)
    return y, H_after[:, -1]


def mamba_block(cfg: ModelConfig, p: dict, x, cdt, mode: str = "train",
                cache: dict | None = None, backend: str = "reference",
                interpret: bool = False):
    """Full mamba mixer. x: (B,S,D). Returns (y, new_cache)."""
    d_inner, n_heads, _, d_conv_ch = mamba_dims(cfg)
    N = cfg.ssm.d_state
    B_, S, D = x.shape

    h = rms_norm(x, p["ln"], cfg.norm_eps).astype(cdt)
    proj = h @ p["in_proj"].astype(cdt)                        # (B,S,dproj)
    xs, z, Bv, Cv, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xs, Bv, Cv], axis=-1)           # (B,S,ch)
    conv_state = cache.get("conv") if cache else None
    conv_out, new_conv = causal_conv1d(conv_in, p["conv_w"], p["conv_b"],
                                       conv_state)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(cdt)
    xs = conv_out[..., :d_inner]
    Bv = conv_out[..., d_inner:d_inner + N]
    Cv = conv_out[..., d_inner + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))     # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (H,) < 0
    log_a = dt * A                                             # (B,S,H)
    xh = xs.reshape(B_, S, n_heads, -1)
    xdt = (xh.astype(jnp.float32) * dt[..., None]).astype(cdt)

    if mode == "decode":
        # single-token state update
        h0 = cache["ssm"].astype(jnp.float32)                  # (B,H,hd,N)
        a = jnp.exp(log_a[:, 0])                               # (B,H)
        upd = jnp.einsum("bhd,bn->bhdn", xdt[:, 0].astype(jnp.float32),
                         Bv[:, 0].astype(jnp.float32))
        h_new = a[..., None, None] * h0 + upd
        y = jnp.einsum("bhdn,bn->bhd", h_new,
                       Cv[:, 0].astype(jnp.float32))[:, None]  # (B,1,H,hd)
        new_state = h_new
    else:
        if backend == "pallas":
            from repro.kernels import ops as kops
            y, new_state = kops.ssm_scan(xdt, Bv, Cv, log_a,
                                         chunk=cfg.ssm.chunk,
                                         interpret=interpret)
        else:
            y, new_state = ssd_chunked(xdt, Bv, Cv, log_a,
                                       chunk=cfg.ssm.chunk)

    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.reshape(B_, S, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))                 # gate
    y = rms_norm(y.astype(cdt), p["norm"], cfg.norm_eps)
    out = y.astype(cdt) @ p["out_proj"].astype(cdt)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"conv": new_conv.astype(cdt),
                     "ssm": new_state.astype(jnp.float32)}
    return out, new_cache
