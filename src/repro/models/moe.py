"""Mixture-of-Experts FFN — GShard-style top-k routing with capacity.

SPMD mapping (see DESIGN.md): tokens are grouped into fixed-size groups so
the dispatch/combine one-hots stay small — groups shard over the data axes,
the expert dim shards over the model axis (EP). All communication is left
to the XLA SPMD partitioner (all-to-all between the token layout and the
expert layout, all-gather for FSDP expert weights).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.runtime.partitioning import constrain

GROUP_SIZE = 512  # tokens per routing group (capacity is per-group)


def moe_ffn(x: jax.Array, p: dict, moe: MoEConfig, cdt):
    """x: (B, S, D) -> (y, aux) where aux = {load_balance, router_z} losses.

    Routing/capacity semantics: top-k per token, per-group capacity
    C = ceil(Sg * k / E * capacity_factor); overflow tokens drop (their
    combine weight is zero) — standard GShard "dropping" behaviour.
    """
    B, S, D = x.shape
    E, K = moe.num_experts, moe.num_experts_per_token
    sg = min(GROUP_SIZE, S)
    # under sequence-parallel activations, groups must not straddle the
    # sequence shards (routing then stays local; EP comm is the small
    # token-sized all-to-all XLA inserts at the expert einsums)
    from repro.runtime.partitioning import current_rules
    rules = current_rules()
    if rules is not None and rules.run.sharding.seq_shard_acts:
        m = rules.axis_size.get("model", 1)
        if m > 1 and S % m == 0:
            sg = min(sg, S // m)
    assert (B * S) % sg == 0
    G = (B * S) // sg
    xg = x.reshape(G, sg, D)

    # ---- router: bf16 matmul (keeps the bwd cotangent of the hidden
    # stream in bf16 — an fp32 router input promotes the entire residual
    # cotangent to f32, doubling every reshard; §Perf HC2), fp32 softmax.
    logits = (xg.astype(cdt) @ p["router"].astype(cdt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (G, sg, E)
    gate_vals, idx = jax.lax.top_k(probs, K)                   # (G, sg, K)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    cap = int(max(1, round(sg * K / E * moe.capacity_factor)))

    # ---- capacity assignment (sequential over the K choices) --------------
    # dispatch/combine are built in the COMPUTE dtype: their (G,sg,E,C)
    # one-hots are the largest tensors in the layer and fp32 versions drag
    # f32 cotangents through both dispatch einsums (§Perf HC2).
    # NOTE (§Perf HC2 it.4, REFUTED): constraining dispatch/combine with E
    # sharded over the model axis ("moe_dispatch" kind) was hypothesized to
    # kill the bwd dispatch-cotangent gather; measured instead +71% flops
    # and 2.5x temp memory (XLA materializes full-E one-hots before the
    # forced reshard). Left unconstrained: GSPMD's own placement wins.
    counts = jnp.zeros((G, E), jnp.float32)
    dispatch = jnp.zeros((G, sg, E, cap), cdt)
    combine = jnp.zeros((G, sg, E, cap), cdt)
    for i in range(K):
        m = jax.nn.one_hot(idx[:, :, i], E, dtype=jnp.float32)  # (G,sg,E)
        pos = counts[:, None, :] + jnp.cumsum(m, axis=1) - m    # slot index
        keep = (pos < cap).astype(jnp.float32) * m
        counts = counts + jnp.sum(keep, axis=1)
        slot = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                              dtype=jnp.float32)                # (G,sg,E,C)
        d_i = keep[..., None] * slot
        dispatch = dispatch + d_i.astype(cdt)
        combine = combine + (gate_vals[:, :, i][..., None, None] *
                             d_i).astype(cdt)

    # ---- expert computation ------------------------------------------------
    ein = dispatch
    expert_in = jnp.einsum("gsec,gsd->egcd", ein, xg.astype(cdt))
    expert_in = constrain(expert_in, "expert")                  # (E,G,C,D)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in,
                               p["wg"].astype(cdt)))
    h = h * jnp.einsum("egcd,edf->egcf", expert_in, p["wi"].astype(cdt))
    out = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(cdt))
    out = constrain(out, "expert")
    y = jnp.einsum("gsec,egcd->gsd", combine, out)

    # ---- aux losses --------------------------------------------------------
    # load balance: E * sum_e mean_prob_e * mean_dispatch_frac_e
    frac = jnp.mean(jnp.sum(dispatch.astype(jnp.float32), axis=-1),
                    axis=(0, 1))                                # (E,)
    mean_p = jnp.mean(probs, axis=(0, 1))
    lb = E * jnp.sum(frac * mean_p) / K
    z = jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, axis=-1)))
    aux = {"load_balance": moe.load_balance_loss * lb,
           "router_z": moe.router_z_loss * z}
    return y.reshape(B, S, D), aux
