"""Model substrate: one code path for all 10 assigned architectures."""
