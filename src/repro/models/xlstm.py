"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, genuinely sequential recurrence).

mLSTM semantics (per head, stabilized — xLSTM paper eq. 19-27):
  C_t = f_t C_{t-1} + i_t k_t v_t^T      n_t = f_t n_{t-1} + i_t k_t
  h_t = (q_t·C_t) / max(|q_t·n_t|, exp(-m_t))
with exponential gates stabilized by the running max m_t. The chunkwise
form below processes Q-token chunks with intra-chunk pairwise decays and a
sequential (max-coupled, non-associative) carry across chunks.

sLSTM keeps a per-channel scalar memory with block-diagonal (per-head)
recurrent weights — it cannot be parallelized over time (hidden state feeds
the gates), so it runs as a lax.scan over timesteps; this is faithful to
the paper and its cost is visible in the roofline.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.params import mlstm_dims, slstm_dims

NEG = -1e30


# ===========================================================================
# mLSTM
# ===========================================================================
def mlstm_chunked(q, k, v, log_f, log_i, state=None, chunk: int = 128):
    """q,k,v: (B,S,H,hd); log_f (<=0-ish), log_i: (B,S,H) fp32.

    Returns h (B,S,H,hd) fp32 and final state dict(C,n,m).
    """
    B, S, H, hd = q.shape
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    q = q.astype(jnp.float32) / math.sqrt(hd)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    lf = log_f.astype(jnp.float32).reshape(B, nc, Q, H)
    li = log_i.astype(jnp.float32).reshape(B, nc, Q, H)
    qc = q.reshape(B, nc, Q, H, hd)
    kc = k.reshape(B, nc, Q, H, hd)
    vc = v.reshape(B, nc, Q, H, hd)

    F = jnp.cumsum(lf, axis=2)                                 # (B,nc,Q,H)
    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), NEG, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    mask = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(carry, xs):
        Cp, np_, mp = carry
        Fq, liq, qq, kk, vv = xs                # (B,Q,H), ..., (B,Q,H,hd)
        Ft = jnp.transpose(Fq, (0, 2, 1))       # (B,H,Q)
        lit = jnp.transpose(liq, (0, 2, 1))
        # pairwise D_ts = F_t - F_s + log_i_s  (s<=t)
        Dm = Ft[..., :, None] - Ft[..., None, :] + lit[..., None, :]
        Dm = jnp.where(mask, Dm, NEG)           # (B,H,Q,Q)
        m_intra = jnp.max(Dm, axis=-1)          # (B,H,Q)
        m_inter = Ft + mp[..., None]            # (B,H,Q)
        m_t = jnp.maximum(m_intra, m_inter)
        Sm = jnp.exp(Dm - m_t[..., None])       # (B,H,Q,Q)
        c_t = jnp.exp(m_inter - m_t)            # (B,H,Q)
        qkT = jnp.einsum("bqhd,bshd->bhqs", qq, kk)
        A = qkT * Sm
        num = jnp.einsum("bhqs,bshd->bqhd", A, vv) + \
            c_t.transpose(0, 2, 1)[..., None] * \
            jnp.einsum("bqhd,bhde->bqhe", qq, Cp)
        den = jnp.sum(A, axis=-1).transpose(0, 2, 1) + \
            c_t.transpose(0, 2, 1) * jnp.einsum("bqhd,bhd->bqh", qq, np_)
        h = num / jnp.maximum(jnp.abs(den),
                              jnp.exp(-m_t).transpose(0, 2, 1))[..., None]
        # ---- end-of-chunk state -----------------------------------------
        Fl = Ft[..., -1]                        # (B,H)
        w = Fl[..., None] - Ft + lit            # (B,H,Q) decay to chunk end
        m_state = jnp.maximum(Fl + mp, jnp.max(w, axis=-1))
        wS = jnp.exp(w - m_state[..., None])    # (B,H,Q)
        Cn = jnp.exp(Fl + mp - m_state)[..., None, None] * Cp + \
            jnp.einsum("bhq,bqhd,bqhe->bhde", wS, kk, vv)
        nn = jnp.exp(Fl + mp - m_state)[..., None] * np_ + \
            jnp.einsum("bhq,bqhd->bhd", wS, kk)
        return (Cn, nn, m_state), h

    xs = (F.transpose(1, 0, 2, 3), li.transpose(1, 0, 2, 3),
          qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4))
    (Cn, nn, mn), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return h, {"C": Cn, "n": nn, "m": mn}


def mlstm_step(q, k, v, log_f, log_i, state):
    """Single decode step. q,k,v: (B,H,hd); log_f/log_i: (B,H)."""
    hd = q.shape[-1]
    q = q.astype(jnp.float32) / math.sqrt(hd)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    Cp, np_, mp = state["C"], state["n"], state["m"]
    m_t = jnp.maximum(log_f + mp, log_i)
    f = jnp.exp(log_f + mp - m_t)
    i = jnp.exp(log_i - m_t)
    Cn = f[..., None, None] * Cp + i[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    nn = f[..., None] * np_ + i[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, Cn)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, nn)),
                      jnp.exp(-m_t))
    h = num / den[..., None]
    return h, {"C": Cn, "n": nn, "m": m_t}


def mlstm_block(cfg: ModelConfig, p: dict, x, cdt, mode: str = "train",
                cache: dict | None = None, backend: str = "reference",
                interpret: bool = False):
    di, H = mlstm_dims(cfg)
    hd = cfg.xlstm.head_dim
    B, S, D = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps).astype(cdt)
    up = h @ p["w_up"].astype(cdt)
    xm, z = up[..., :di], up[..., di:]
    q = (xm @ p["wq"].astype(cdt)).reshape(B, S, H, hd)
    k = (xm @ p["wk"].astype(cdt)).reshape(B, S, H, hd)
    v = (xm @ p["wv"].astype(cdt)).reshape(B, S, H, hd)
    xf = xm.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(xf @ p["w_f"] + p["b_f"])       # (B,S,H)
    log_i = xf @ p["w_i"] + p["b_i"]

    state = cache.get("mlstm") if cache else None
    if mode == "decode":
        y, new_state = mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                  log_f[:, 0], log_i[:, 0], state)
        y = y[:, None]
    else:
        y, new_state = mlstm_chunked(q, k, v, log_f, log_i, state,
                                     chunk=cfg.xlstm.chunk)
    y = y.reshape(B, S, di)
    y = rms_norm(y.astype(cdt), p["norm"], cfg.norm_eps)
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(cdt) @ p["w_out"].astype(cdt)
    new_cache = {"mlstm": new_state} if mode in ("prefill", "decode") else None
    return out, new_cache


# ===========================================================================
# sLSTM
# ===========================================================================
def _rmul(h, r):
    """Block-diagonal recurrent matmul. h: (B,D); r: (heads,dh,dh)."""
    B, D = h.shape
    heads, dh, _ = r.shape
    hh = h.reshape(B, heads, dh)
    return jnp.einsum("bhd,hde->bhe", hh, r).reshape(B, D)


def slstm_block(cfg: ModelConfig, p: dict, x, cdt, mode: str = "train",
                cache: dict | None = None, **_):
    heads, dh, d_up = slstm_dims(cfg)
    B, S, D = x.shape
    xin = rms_norm(x, p["ln"], cfg.norm_eps).astype(cdt)
    # input projections for all timesteps up-front (parallel part)
    zx = (xin @ p["w_z"].astype(cdt)).astype(jnp.float32)
    ix = (xin @ p["w_i"].astype(cdt)).astype(jnp.float32)
    fx = (xin @ p["w_f"].astype(cdt)).astype(jnp.float32)
    ox = (xin @ p["w_o"].astype(cdt)).astype(jnp.float32)

    if cache and "slstm" in cache:
        st = cache["slstm"]
        carry0 = (st["h"], st["c"], st["n"], st["m"])
    else:
        zero = jnp.zeros((B, D), jnp.float32)
        carry0 = (zero, zero, zero, jnp.full((B, D), -30.0, jnp.float32))

    rz, ri, rf, ro = (p["r_z"].astype(jnp.float32),
                      p["r_i"].astype(jnp.float32),
                      p["r_f"].astype(jnp.float32),
                      p["r_o"].astype(jnp.float32))
    bz, bi, bf, bo = p["b_z"], p["b_i"], p["b_f"], p["b_o"]

    def step(carry, xs):
        hp, cp, npr, mp = carry
        zt, it, ft, ot = xs
        z = jnp.tanh(zt + _rmul(hp, rz) + bz)
        li = it + _rmul(hp, ri) + bi
        lf = jax.nn.log_sigmoid(ft + _rmul(hp, rf) + bf)
        m = jnp.maximum(lf + mp, li)
        i = jnp.exp(li - m)
        f = jnp.exp(lf + mp - m)
        c = f * cp + i * z
        n = f * npr + i
        o = jax.nn.sigmoid(ot + _rmul(hp, ro) + bo)
        hn = o * c / jnp.maximum(n, 1e-6)
        return (hn, c, n, m), hn

    xs = (zx.transpose(1, 0, 2), ix.transpose(1, 0, 2),
          fx.transpose(1, 0, 2), ox.transpose(1, 0, 2))
    (hf, cf, nf, mf), hs = jax.lax.scan(step, carry0, xs)
    y = hs.transpose(1, 0, 2)                                  # (B,S,D)
    y = rms_norm(y.astype(cdt), p["norm"], cfg.norm_eps).astype(cdt)
    # GLU up/down
    g = jax.nn.silu((y @ p["up_wg"].astype(cdt)).astype(jnp.float32))
    u = (y @ p["up_wi"].astype(cdt)).astype(jnp.float32)
    out = (g * u).astype(cdt) @ p["up_wo"].astype(cdt)
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"slstm": {"h": hf, "c": cf, "n": nf, "m": mf}}
    return out, new_cache
