"""Fused temperature/top-k Gumbel sampling — the decode hot path's last
host round-trip, moved on-device.

Before this kernel a decode step was: Pallas ``paged_decode`` -> (B, V)
logits D2H -> per-request numpy sampling on the host. The logits transfer
and the per-token host work scale with batch x vocab and sit squarely on
the serve plane's critical path. Here the whole sampler — vocab-tail mask,
temperature scale, top-k filter, Gumbel-max draw, argmax — runs where the
logits already live, and only the sampled token ids (B,) int32 ever leave
the device.

Bit-identity contract (invariant I10). ``ServeEngine._sample`` is the
HOST-side oracle: a request's token t must be the same whether it was
sampled on the host or in-kernel, before or after any pause / migrate /
CoW. That forces every arithmetic op here to be *portably exact* between
numpy (host) and XLA/Pallas (device):

  noise      a counter-seeded integer hash (uint32 avalanche mixing of
             (seed, rid, token_counter, vocab_index)) — wrapping uint32
             arithmetic is bit-exact everywhere
  u32 -> u   ``(h >> 8) + 0.5) * 2^-24`` — every step exactly
             representable in float32, u in (0, 1) strictly
  gumbel     ``-log(-log(u))`` with ``log`` implemented HERE from
             exponent extraction + an atanh polynomial using only
             IEEE-correctly-rounded float32 +,-,*,/ — numpy and XLA agree
             on those bit-for-bit, which libm/XLA's transcendental
             ``log`` does not guarantee
  argmax     first-max-index semantics in both numpy and jnp

The same generic implementation (parameterized over the array namespace)
is instantiated for numpy (``host_gumbel`` — what ``ServeEngine._sample``
draws) and jnp (the ref oracle and the Pallas kernel), so the two paths
cannot drift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# uint32 avalanche constants (splitmix/murmur-style finalizer)
_M1 = 0x7FEB352D
_M2 = 0x846CA68B
_GOLD = 0x9E3779B9
_SALT = 0x5E12C0DE     # the serve plane's sampling-stream domain tag

# portable-log constants (float32 exact values)
_LN2 = np.float32(0.6931471805599453)
_SQRT2 = np.float32(1.4142135623730951)
_C3 = np.float32(1.0 / 3.0)
_C5 = np.float32(1.0 / 5.0)
_C7 = np.float32(1.0 / 7.0)
_C9 = np.float32(1.0 / 9.0)
_HALF = np.float32(0.5)
_ONE = np.float32(1.0)
_TWO = np.float32(2.0)
_U24 = np.float32(2.0 ** -24)


def _mix(h, xp):
    """Finalizing avalanche mix over uint32 (wrapping arithmetic)."""
    h = h ^ (h >> 16)
    h = h * xp.uint32(_M1)
    h = h ^ (h >> 15)
    h = h * xp.uint32(_M2)
    h = h ^ (h >> 16)
    return h


def _log32(x, xp, to_i32, to_f32):
    """Portable float32 natural log for x > 0 (normal range).

    Exponent/mantissa split via bitcast, then ln(m) from the atanh series
    2s(1 + s^2/3 + s^4/5 + ...) with s = (m-1)/(m+1), |s| < 0.1716 after
    centering m into [sqrt(2)/2, sqrt(2)). Only +,-,*,/ on float32 — all
    correctly rounded, so numpy and XLA produce identical bits.
    """
    bits = to_i32(x)
    e = ((bits >> 23) & 0xFF) - 127
    m = to_f32((bits & 0x007FFFFF) | 0x3F800000)          # [1, 2)
    big = m > _SQRT2
    m = xp.where(big, m * _HALF, m)
    e = xp.where(big, e + 1, e)
    s = (m - _ONE) / (m + _ONE)
    t = s * s
    poly = _ONE + t * (_C3 + t * (_C5 + t * (_C7 + t * _C9)))
    return e.astype(xp.float32) * _LN2 + (_TWO * s) * poly


def _gumbel(base_u32, idx_u32, xp, to_i32, to_f32):
    """Gumbel(0,1) noise for each vocab index, from the mixed base key.
    base_u32: uint32 scalar/array broadcastable against idx_u32 (uint32
    vocab indices). Returns float32 of idx's shape."""
    h = _mix(base_u32 ^ idx_u32, xp)
    u = (((h >> 8)).astype(xp.float32) + _HALF) * _U24    # (0,1) exclusive
    return -_log32(-_log32(u, xp, to_i32, to_f32), xp, to_i32, to_f32)


def _base_key(seed, rid, counter, xp):
    """Counter-seeded stream key: token ``counter`` of request
    (seed, rid) always derives the same key — sampling stays a pure
    function of the request, which is what makes pause/migrate/replay
    token-identical (I10)."""
    h = _mix(xp.uint32(_SALT) ^ (seed.astype(xp.uint32) * xp.uint32(_GOLD)),
             xp)
    h = _mix(h ^ rid.astype(xp.uint32), xp)
    h = _mix(h ^ counter.astype(xp.uint32), xp)
    return h


# ---------------------------------------------------------------------------
# numpy instantiation (the host oracle's noise source)
# ---------------------------------------------------------------------------
def _np_to_i32(x):
    return np.ascontiguousarray(x).view(np.int32)


def _np_to_f32(x):
    return np.ascontiguousarray(x).astype(np.uint32).view(np.float32) \
        if x.dtype != np.int32 else np.ascontiguousarray(x).view(np.float32)


def host_gumbel(seed: int, rid: int, counter: int, n: int) -> np.ndarray:
    """(n,) float32 Gumbel noise for token ``counter`` of request
    (seed, rid) — numpy twin of the in-kernel draw, bit-identical."""
    base = _base_key(np.uint32(np.asarray([seed], np.int64) & 0xFFFFFFFF),
                     np.uint32(np.asarray([rid], np.int64) & 0xFFFFFFFF),
                     np.uint32(np.asarray([counter],
                                          np.int64) & 0xFFFFFFFF), np)
    idx = np.arange(n, dtype=np.uint32)
    return _gumbel(base, idx, np, _np_to_i32, _np_to_f32)


# ---------------------------------------------------------------------------
# jnp instantiation (ref oracle + inside the Pallas kernel)
# ---------------------------------------------------------------------------
def _jnp_to_i32(x):
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def _jnp_to_f32(x):
    return jax.lax.bitcast_convert_type(x.astype(jnp.int32), jnp.float32) \
        if x.dtype != jnp.int32 else jax.lax.bitcast_convert_type(
            x, jnp.float32)


def jnp_gumbel(keys, idx):
    """keys: (..., 3) int32 (seed, rid, counter); idx: uint32 indices
    broadcastable against keys[..., 0]. Returns float32 noise."""
    base = _base_key(keys[..., 0], keys[..., 1], keys[..., 2], jnp)
    return _gumbel(base, idx, jnp, _jnp_to_i32, _jnp_to_f32)


def prepare_rows(logits, temp, top_k, *, vocab_size: int):
    """Shared sampler front half (runs as plain XLA either way): cast to
    float32, mask the padded vocab tail, temperature-scale, top-k filter.
    Greedy rows (temp <= 0) pass through unscaled so the argmax equals
    the host's greedy ``argmax(logits)``. Returns (B, V) float32 rows
    ready for noise + argmax, plus the (B,) bool noisy-row mask."""
    B, Vp = logits.shape
    lg = logits.astype(jnp.float32)
    vmask = jnp.arange(Vp) < vocab_size
    lg = jnp.where(vmask[None, :], lg, -jnp.inf)
    temp = jnp.asarray(temp, jnp.float32)
    noisy = temp > 0
    z = lg / jnp.where(noisy, temp, _ONE)[:, None]
    # per-row k-th largest of the SCALED row (matches the host's
    # np.partition threshold); k outside (0, V) disables the filter
    top_k = jnp.asarray(top_k, jnp.int32)
    use_k = noisy & (top_k > 0) & (top_k < vocab_size)
    srt = -jnp.sort(-z, axis=-1)                    # descending
    kidx = jnp.clip(top_k - 1, 0, Vp - 1)
    kth = jnp.take_along_axis(srt, kidx[:, None], axis=-1)[:, 0]
    thr = jnp.where(use_k, kth, -jnp.inf)
    z = jnp.where(z >= thr[:, None], z, -jnp.inf)
    return z, noisy


# ---------------------------------------------------------------------------
# the Pallas kernel: tiled noise + online first-index argmax
# ---------------------------------------------------------------------------
def _kernel(keys_ref, z_ref, o_ref, val_scr, idx_scr, *, vtile: int):
    b = pl.program_id(0)
    ti = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(ti == 0)
    def _init():
        val_scr[0] = NEG_INF
        idx_scr[0] = 0

    seed = keys_ref[b, 0]
    rid = keys_ref[b, 1]
    ctr = keys_ref[b, 2]
    noisy = keys_ref[b, 3]
    base = _base_key(seed.reshape(1, 1), rid.reshape(1, 1),
                     ctr.reshape(1, 1), jnp)
    col = ti * vtile + jax.lax.broadcasted_iota(jnp.int32, (1, vtile), 1)
    g = _gumbel(base, col.astype(jnp.uint32), jnp, _jnp_to_i32, _jnp_to_f32)
    z = z_ref[0, :].reshape(1, vtile)
    y = jnp.where(noisy != 0, z + g, z)
    # -inf rows (vocab padding / top-k filtered) can never win: noise is
    # finite, so -inf + g stays -inf < any finite running best
    tmax = jnp.max(y)
    targ = jnp.argmax(y[0, :]).astype(jnp.int32) + ti * vtile
    better = tmax > val_scr[0]
    val_scr[0] = jnp.where(better, tmax, val_scr[0])
    idx_scr[0] = jnp.where(better, targ, idx_scr[0])

    @pl.when(ti == nt - 1)
    def _finish():
        o_ref[0] = idx_scr[0]


def fused_sample(logits, temp, top_k, keys, *, vocab_size: int,
                 interpret: bool = False):
    """logits: (B, Vp); temp: (B,) float32; top_k: (B,) int32; keys:
    (B, 3) int32 (seed, rid, token_counter). Returns (B,) int32 sampled
    token ids, bit-identical to ``ServeEngine._sample`` row by row."""
    B, Vp = logits.shape
    z, noisy = prepare_rows(logits, temp, top_k, vocab_size=vocab_size)
    vtile = min(512, 1 << max(0, (Vp - 1).bit_length()))
    pad = (-Vp) % vtile
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    nt = (Vp + pad) // vtile
    keys4 = jnp.concatenate(
        [jnp.asarray(keys, jnp.int32),
         noisy.astype(jnp.int32)[:, None]], axis=1)
    # replace -inf with a finite floor: the kernel adds noise to every
    # lane and -inf + finite is -inf (fine), but NEG_INF keeps the
    # scratch compare total-ordered even if a row is entirely masked
    z = jnp.maximum(z, NEG_INF)
    return pl.pallas_call(
        functools.partial(_kernel, vtile=vtile),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, nt),
            in_specs=[
                pl.BlockSpec((1, vtile),
                             lambda b, ti, keys_ref: (b, ti)),
            ],
            out_specs=pl.BlockSpec(
                (1,), lambda b, ti, keys_ref: (b,),
                memory_space=pltpu.SMEM),
            scratch_shapes=[
                pltpu.SMEM((1,), jnp.float32),
                pltpu.SMEM((1,), jnp.int32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(keys4, z)
