"""Blocked flash attention (forward) for TPU — pl.pallas_call + BlockSpec.

Tiling: grid (B, H, nq, nk); the kv axis is innermost so the online-softmax
running state (m, l, acc) lives in VMEM scratch and persists across the kv
iteration (TPU grids execute sequentially over the trailing axis). Q/K
tiles are MXU-aligned (default 128x128, head_dim loaded whole). GQA is
handled in the k/v index_map (q head h reads kv head h // group).

Causal handling: logits inside a block are masked with position iotas;
fully-masked blocks are skipped via pl.when.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, scale: float, block_q: int, block_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)          # (bq,1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip blocks entirely above the diagonal
        pl.when(k_start <= q_start + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B,S,H,hd); k,v: (B,T,K,hd); H % K == 0. Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    grid = (B, H, S // block_q, T // block_k)

    kern = functools.partial(_kernel, causal=causal,
                             scale=1.0 / math.sqrt(hd),
                             block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
