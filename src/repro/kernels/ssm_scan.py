"""Chunked SSD scan kernel (Mamba-2 style scalar-per-head decay).

Grid (B, H, nc): the chunk axis is innermost, so the recurrent state
H (hd x N) lives in VMEM scratch and is carried chunk-to-chunk — the
HBM<->VMEM traffic per chunk is just the chunk inputs/outputs, and the
intra-chunk work is two MXU matmuls (C·Bᵀ and the masked-weight @ x).

Per chunk (all fp32 in-kernel):
  cum   = cumsum(log_a)                              (Q,)
  y     = ((exp(cum_t - cum_s) ⊙ tril) ⊙ (C Bᵀ)) @ xdt  +  exp(cum) ⊙ (C H_prevᵀ)
  H_new = exp(cum_Q) H_prev + ((exp(cum_Q - cum) ⊙ xdt)ᵀ B)

Inputs  xdt (B,S,H,hd), Bv (B,S,N), Cv (B,S,N), log_a (B,S,H).
Outputs y (B,S,H,hd) and the final state (B,H,hd,N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, b_ref, c_ref, la_ref, y_ref, hout_ref, h_scr, *,
            chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, hd)
    Bv = b_ref[0, :, :].astype(jnp.float32)            # (Q, N)
    Cv = c_ref[0, :, :].astype(jnp.float32)            # (Q, N)
    la = la_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    cum = jnp.cumsum(la)                               # (Q,)

    # intra-chunk: masked decay-weighted attention-like matmul
    M = cum[:, None] - cum[None, :]                    # t - s
    tril = jax.lax.broadcasted_iota(jnp.int32, M.shape, 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, M.shape, 1)
    M = jnp.where(tril, jnp.exp(M), 0.0)               # (Q,Q)
    GB = jax.lax.dot_general(Cv, Bv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    y = jax.lax.dot(M * GB, x, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    h_prev = h_scr[...]                                # (hd, N)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cv, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (Q, hd)

    # state update
    w = jnp.exp(cum[-1] - cum)                         # (Q,)
    h_new = jnp.exp(cum[-1]) * h_prev + jax.lax.dot_general(
        w[:, None] * x, Bv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (hd, N)
    h_scr[...] = h_new
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0, 0, :, :] = h_new.astype(hout_ref.dtype)


def ssm_scan(xdt, Bv, Cv, log_a, *, chunk: int = 128,
             interpret: bool = False):
    """See module docstring. Returns (y fp32 (B,S,H,hd), state (B,H,hd,N))."""
    B, S, H, hd = xdt.shape
    N = Bv.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    grid = (B, H, S // Q)
    kern = functools.partial(_kernel, chunk=Q)
    y, hfinal = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, hd), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, ci: (b, ci, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, hd), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(xdt, Bv, Cv, log_a)
    return y, hfinal
