"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These are the ground truth for the per-kernel sweep tests and the lowering
path used on non-TPU backends / in the dry-run (so cost_analysis counts
real FLOPs rather than opaque custom calls).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
def flash_attention_ref(q, k, v, causal: bool = True):
    """q: (B,S,H,hd); k,v: (B,T,K,hd) with H % K == 0. fp32 softmax."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------
def flash_decode_ref(q, k, v, pos):
    """q: (B,1,H,hd); k,v: (B,T,K,hd); attend to indices <= pos."""
    B, _, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    logits = jnp.einsum("bkgh,btkh->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    valid = (jnp.arange(T) <= pos)[None, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged_decode (flash_decode over a block-table-indirected paged KV pool)
# ---------------------------------------------------------------------------
def paged_decode_ref(q, k_pages, v_pages, tables, pos):
    """q: (B,1,H,hd); k_pages/v_pages: (P,page,K,hd) — the shared page pool;
    tables: (B,NP) int32 page ids forming each sequence's logical
    (NP*page)-token view; pos: (B,) int32 — last valid logical index per
    sequence (attend to <= pos; pos < 0 means no valid tokens and the
    output row is exactly zero, matching the Pallas kernel's zero-init
    accumulator when every tile is skipped)."""
    B, _, H, hd = q.shape
    page, K = k_pages.shape[1], k_pages.shape[2]
    T = tables.shape[1] * page
    G = H // K
    k = k_pages[tables].reshape(B, T, K, hd)
    v = v_pages[tables].reshape(B, T, K, hd)
    qg = q.reshape(B, K, G, hd)
    logits = jnp.einsum("bkgh,btkh->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    valid = (jnp.arange(T)[None, :] <= pos[:, None])[:, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.where(valid, jnp.exp(logits - m), 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgt,btkh->bkgh", p / denom, v.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 paged KV (per-row/head symmetric scales — see serve/paged.py)
# ---------------------------------------------------------------------------
def kv_quant_ref(x):
    """Symmetric int8 quantization of a KV tensor over its last (hd) axis.
    x: (..., hd) float -> (q int8 same shape, scale fp32 shape[:-1]).
    scale = max|x| / 127 per (page-row, head), so a later dequant-requant
    round-trip is exact (q_max lands on 127 by construction)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def kv_dequant_ref(q, scale, dtype=jnp.float32):
    """Inverse of kv_quant_ref: (..., hd) int8 x (...) fp32 -> float."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def paged_decode_quant_ref(q, k_pages, v_pages, k_scale, v_scale,
                           tables, pos):
    """paged_decode_ref over an int8 page pool: k/v_pages (P,page,K,hd)
    int8 with per-(row,head) scales (P,page,K) fp32, dequantized before
    the fp32 attention math."""
    k = kv_dequant_ref(k_pages, k_scale)
    v = kv_dequant_ref(v_pages, v_scale)
    return paged_decode_ref(q, k, v, tables, pos)


# ---------------------------------------------------------------------------
# fused_sample (in-kernel temperature/top-k Gumbel sampling)
# ---------------------------------------------------------------------------
def fused_sample_ref(logits, temp, top_k, keys, *, vocab_size: int):
    """jnp oracle for kernels/sampling.fused_sample: same prepare_rows
    front half, same portable counter-hash Gumbel noise, plain argmax.
    Bit-identical to both the Pallas kernel and ServeEngine._sample."""
    from repro.kernels.sampling import jnp_gumbel, prepare_rows
    z, noisy = prepare_rows(logits, temp, top_k, vocab_size=vocab_size)
    idx = jnp.arange(z.shape[1], dtype=jnp.uint32)
    g = jnp_gumbel(jnp.asarray(keys, jnp.int32)[:, None, :], idx[None, :])
    y = jnp.where(noisy[:, None], z + g, z)
    return jnp.argmax(y, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# ssm_scan (chunked scalar-decay linear recurrence — see models/ssm.py)
# ---------------------------------------------------------------------------
def ssm_scan_ref(xdt, Bv, Cv, log_a, chunk: int = 128):
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(xdt, Bv, Cv, log_a, h0=None, chunk=chunk)


def ssm_scan_sequential_ref(xdt, Bv, Cv, log_a):
    """O(S) sequential oracle (slow, exact)."""
    B, S, H, hd = xdt.shape

    def step(h, t):
        a = jnp.exp(log_a[:, t].astype(jnp.float32))
        h = a[..., None, None] * h + jnp.einsum(
            "bhd,bn->bhdn", xdt[:, t].astype(jnp.float32),
            Bv[:, t].astype(jnp.float32))
        y = jnp.einsum("bhdn,bn->bhd", h, Cv[:, t].astype(jnp.float32))
        return h, y

    h0 = jnp.zeros((B, H, hd, Bv.shape[-1]), jnp.float32)
    hf, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return jnp.swapaxes(ys, 0, 1), hf


# ---------------------------------------------------------------------------
# qdma_pack / qdma_unpack
# ---------------------------------------------------------------------------
def qdma_pack_ref(x, block: int = 256):
    """Blockwise symmetric int8 quantization over the last dim.
    Returns (q int8 same shape, scale fp32 shape[:-1]+(L/block,))."""
    L = x.shape[-1]
    assert L % block == 0
    xb = x.astype(jnp.float32).reshape(x.shape[:-1] + (L // block, block))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale


def qdma_unpack_ref(q, scale, dtype="float32"):
    block = q.shape[-1] // scale.shape[-1]
    qb = q.reshape(q.shape[:-1] + (scale.shape[-1], block))
    x = qb.astype(jnp.float32) * scale[..., None]
    return x.reshape(q.shape).astype(dtype)


def qdma_pack_rows_ref(x, lo, rows: int, block: int = 256):
    """Pack rows [lo, lo+rows) of the 2-D row view of x (one descriptor)."""
    x2 = x.reshape(-1, x.shape[-1]) if x.ndim else x.reshape(1, 1)
    chunk = jax.lax.dynamic_slice_in_dim(x2, lo, rows, axis=0)
    return qdma_pack_ref(chunk, block=block)


def qdma_digest_ref(x):
    """Position-weighted 2x32-bit content fingerprint of x's raw bytes.
    Bit-equal arrays (same dtype) digest equal; differing bytes at any
    position flip the weighted sums with overwhelming probability."""
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.uint8)
    u8 = jax.lax.bitcast_convert_type(x, jnp.uint8).reshape(-1)
    v = u8.astype(jnp.uint32)
    idx = jnp.arange(v.shape[0], dtype=jnp.uint32)
    w1 = idx * jnp.uint32(2654435761) + jnp.uint32(0x9E3779B1)
    w2 = idx * jnp.uint32(0x85EBCA6B) + jnp.uint32(0xC2B2AE35)
    return jnp.stack([jnp.sum(v * w1, dtype=jnp.uint32),
                      jnp.sum(v * w2, dtype=jnp.uint32)])
