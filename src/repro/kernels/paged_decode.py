"""Paged flash-decode: single-token attention over a block-table-indirected
KV page pool (the serve plane's paged-KV cache, see ``repro.serve.paged``).

Extends ``flash_decode``'s split-K online-softmax scheme with one level of
indirection: the cache is a shared pool of fixed-size pages (P, page, K, hd)
and each sequence names its pages through a prefetched block table
(B, NP) — the k/v BlockSpec index_map reads ``table[b, pi]`` so the DMA
engine fetches exactly the pages a sequence owns, in logical order. The
per-sequence valid length is a second prefetched scalar vector: tiles past
``pos[b]`` are skipped with ``pl.when``, so decode cost is proportional to
the tokens a sequence has actually written — not to the pool size and not
to a dense per-slot ring allocation. ``pos[b] < 0`` (an inactive batch
slot) skips every tile and yields an exactly-zero output row.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, *, scale: float, page: int):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    npg = pl.num_programs(2)
    pos = pos_ref[b]
    start = pi * page

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(start <= pos)
    def compute():
        q = q_ref[0, 0, 0, :].astype(jnp.float32) * scale    # (hd,)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (page, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (page, hd)
        s = jax.lax.dot_general(q[None], k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(kpos <= pos, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == npg - 1)
    def _finish():
        o_ref[0, 0, 0, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        )[0].astype(o_ref.dtype)


def _kernel_quant(tbl_ref, pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, scale: float, page: int):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    npg = pl.num_programs(2)
    pos = pos_ref[b]
    start = pi * page

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(start <= pos)
    def compute():
        q = q_ref[0, 0, 0, :].astype(jnp.float32) * scale    # (hd,)
        # int8 page tile + its per-row scales, dequantized in-register:
        # the HBM traffic this kernel pays is the int8 bytes, not fp32
        ks = ks_ref[0, :, 0].astype(jnp.float32)             # (page,)
        vs = vs_ref[0, :, 0].astype(jnp.float32)             # (page,)
        k = k_ref[0, :, 0, :].astype(jnp.float32) * ks[:, None]
        v = v_ref[0, :, 0, :].astype(jnp.float32) * vs[:, None]
        s = jax.lax.dot_general(q[None], k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(kpos <= pos, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == npg - 1)
    def _finish():
        o_ref[0, 0, 0, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        )[0].astype(o_ref.dtype)


def paged_decode_quant(q, k_pages, v_pages, k_scale, v_scale, tables, pos, *,
                       interpret: bool = False):
    """paged_decode over an int8 page pool. k_pages/v_pages:
    (P,page,K,hd) int8; k_scale/v_scale: (P,page,K) fp32 per-(row,head)
    symmetric scales; everything else as paged_decode. Pages are fetched
    at int8 width and dequantized in-tile, halving the kernel's HBM
    bytes per token."""
    B, _, H, hd = q.shape
    page, K = k_pages.shape[1], k_pages.shape[2]
    NP = tables.shape[1]
    G = H // K
    grid = (B, H, NP)
    kern = functools.partial(_kernel_quant, scale=1.0 / math.sqrt(hd),
                             page=page)
    tbl = jnp.asarray(tables, jnp.int32)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape((B,))
    kv_spec = pl.BlockSpec((1, page, 1, hd),
                           lambda b, h, pi, tbl_ref, pos_ref:
                           (tbl_ref[b, pi], 0, h // G, 0))
    sc_spec = pl.BlockSpec((1, page, 1),
                           lambda b, h, pi, tbl_ref, pos_ref:
                           (tbl_ref[b, pi], 0, h // G))
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, 1, hd),
                             lambda b, h, pi, tbl_ref, pos_ref: (b, 0, h, 0)),
                kv_spec,
                kv_spec,
                sc_spec,
                sc_spec,
            ],
            out_specs=pl.BlockSpec((1, 1, 1, hd),
                                   lambda b, h, pi, tbl_ref, pos_ref:
                                   (b, 0, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, 1, H, hd), q.dtype),
        interpret=interpret,
    )(tbl, pos_arr, q, k_pages, v_pages, k_scale, v_scale)


def paged_decode(q, k_pages, v_pages, tables, pos, *,
                 interpret: bool = False):
    """q: (B,1,H,hd); k_pages,v_pages: (P,page,K,hd); tables: (B,NP) int32;
    pos: (B,) int32 — attend to logical indices <= pos[b] (< 0: none)."""
    B, _, H, hd = q.shape
    page, K = k_pages.shape[1], k_pages.shape[2]
    NP = tables.shape[1]
    G = H // K
    grid = (B, H, NP)
    kern = functools.partial(_kernel, scale=1.0 / math.sqrt(hd), page=page)
    tbl = jnp.asarray(tables, jnp.int32)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape((B,))
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, 1, hd),
                             lambda b, h, pi, tbl_ref, pos_ref: (b, 0, h, 0)),
                pl.BlockSpec((1, page, 1, hd),
                             lambda b, h, pi, tbl_ref, pos_ref:
                             (tbl_ref[b, pi], 0, h // G, 0)),
                pl.BlockSpec((1, page, 1, hd),
                             lambda b, h, pi, tbl_ref, pos_ref:
                             (tbl_ref[b, pi], 0, h // G, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, 1, hd),
                                   lambda b, h, pi, tbl_ref, pos_ref:
                                   (b, 0, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, 1, H, hd), q.dtype),
        interpret=interpret,
    )(tbl, pos_arr, q, k_pages, v_pages)
