"""Flash-decode: single-token attention against a long KV cache.

Split-K tiling: grid (B, H, ns) walks the cache in block_k tiles with the
online-softmax state in VMEM scratch; the valid-length position is a
prefetched scalar (pltpu.PrefetchScalarGridSpec) so tiles past ``pos`` are
skipped with pl.when — for a ring cache where pos << T this makes decode
cost proportional to the *filled* cache, not the allocation.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_k: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    pos = pos_ref[0]
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(k_start <= pos)
    def compute():
        q = q_ref[0, 0, 0, :].astype(jnp.float32) * scale    # (hd,)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (bk, hd)
        s = jax.lax.dot_general(q[None], k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (1,bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0, 0, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        )[0].astype(o_ref.dtype)


def flash_decode(q, k, v, pos, *, block_k: int = 256,
                 interpret: bool = False):
    """q: (B,1,H,hd); k,v: (B,T,K,hd); pos: scalar int32 (attend <= pos)."""
    B, _, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    block_k = min(block_k, T)
    assert T % block_k == 0
    grid = (B, H, T // block_k)
    kern = functools.partial(_kernel, scale=1.0 / math.sqrt(hd),
                             block_k=block_k)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape((1,))
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, 1, hd),
                             lambda b, h, ki, pos_ref: (b, 0, h, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, ki, pos_ref: (b, ki, h // G, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, ki, pos_ref: (b, ki, h // G, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, 1, hd),
                                   lambda b, h, ki, pos_ref: (b, 0, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, 1, H, hd), q.dtype),
        interpret=interpret,
    )(pos_arr, q, k, v)
