"""qdma_pack / qdma_unpack — the QDMA descriptor-queue analogue.

Blockwise symmetric int8 quantization used by the StagingEngine to shrink
pause-snapshot payloads (and, beyond-paper, gradient payloads) before they
cross the slow host link. Grid-chunked so arbitrary-size state tensors
stream through a fixed VMEM footprint — exactly the descriptor-queue shape
of the QDMA hardware (paper §IV-A), with the (rows, block) tile playing the
role of one descriptor.

pack:   x (M, L) -> q int8 (M, L), scale fp32 (M, L/block)
unpack: inverse (dequantize).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(x_ref, q_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)                # (rows, tile)
    rows, tile = x.shape
    nb = tile // block
    xb = x.reshape(rows, nb, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0     # (rows, nb)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    q_ref[...] = q.reshape(rows, tile).astype(jnp.int8)
    s_ref[...] = scale


def _unpack_kernel(q_ref, s_ref, x_ref, *, block: int):
    q = q_ref[...].astype(jnp.float32)
    rows, tile = q.shape
    nb = tile // block
    x = q.reshape(rows, nb, block) * s_ref[...][..., None]
    x_ref[...] = x.reshape(rows, tile).astype(x_ref.dtype)


def _as2d(x):
    L = x.shape[-1]
    return x.reshape(-1, L)


def qdma_pack(x, *, block: int = 256, rows_per_tile: int = 256,
              interpret: bool = False):
    """x: any shape with shape[-1] % block == 0. Returns (q, scale) shaped
    like ref.qdma_pack_ref."""
    shape = x.shape
    x2 = _as2d(x)
    M, L = x2.shape
    rows = min(rows_per_tile, M)
    while M % rows:
        rows -= 1
    grid = (M // rows,)
    kern = functools.partial(_pack_kernel, block=block)
    q, scale = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, L), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, L), lambda i: (i, 0)),
                   pl.BlockSpec((rows, L // block), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, L), jnp.int8),
                   jax.ShapeDtypeStruct((M, L // block), jnp.float32)],
        interpret=interpret,
    )(x2)
    return (q.reshape(shape),
            scale.reshape(shape[:-1] + (L // block,)))


def qdma_unpack(q, scale, *, dtype="float32", rows_per_tile: int = 256,
                interpret: bool = False):
    shape = q.shape
    block = q.shape[-1] // scale.shape[-1]
    q2 = _as2d(q)
    s2 = _as2d(scale)
    M, L = q2.shape
    rows = min(rows_per_tile, M)
    while M % rows:
        rows -= 1
    grid = (M // rows,)
    kern = functools.partial(_unpack_kernel, block=block)
    x = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, L), lambda i: (i, 0)),
                  pl.BlockSpec((rows, L // block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, L), jnp.dtype(dtype)),
        interpret=interpret,
    )(q2, s2)
    return x.reshape(shape)
