"""qdma_pack / qdma_unpack — the QDMA descriptor-queue analogue.

Blockwise symmetric int8 quantization used by the StagingEngine to shrink
pause-snapshot payloads (and, beyond-paper, gradient payloads) before they
cross the slow host link. Grid-chunked so arbitrary-size state tensors
stream through a fixed VMEM footprint — exactly the descriptor-queue shape
of the QDMA hardware (paper §IV-A), with the (rows, block) tile playing the
role of one descriptor.

pack:   x (M, L) -> q int8 (M, L), scale fp32 (M, L/block)
unpack: inverse (dequantize).
rows:   chunk-granular entry points (`qdma_pack_rows`) that pack ONE
        descriptor — a row range of the 2-D view — so the staging engine
        can overlap pack of descriptor i+1 with D2H of descriptor i.
digest: `qdma_digest` — a position-weighted 2x32-bit content fingerprint
        of the raw bytes, computed on device, used by the staging engine's
        dirty tracking to skip mutated-but-equal leaves without a D2H.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(x_ref, q_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)                # (rows, tile)
    rows, tile = x.shape
    nb = tile // block
    xb = x.reshape(rows, nb, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0     # (rows, nb)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    q_ref[...] = q.reshape(rows, tile).astype(jnp.int8)
    s_ref[...] = scale


def _unpack_kernel(q_ref, s_ref, x_ref, *, block: int):
    q = q_ref[...].astype(jnp.float32)
    rows, tile = q.shape
    nb = tile // block
    x = q.reshape(rows, nb, block) * s_ref[...][..., None]
    x_ref[...] = x.reshape(rows, tile).astype(x_ref.dtype)


def _as2d(x):
    L = x.shape[-1]
    return x.reshape(-1, L)


def qdma_pack(x, *, block: int = 256, rows_per_tile: int = 256,
              interpret: bool = False):
    """x: any shape with shape[-1] % block == 0. Returns (q, scale) shaped
    like ref.qdma_pack_ref."""
    shape = x.shape
    x2 = _as2d(x)
    M, L = x2.shape
    rows = min(rows_per_tile, M)
    while M % rows:
        rows -= 1
    grid = (M // rows,)
    kern = functools.partial(_pack_kernel, block=block)
    q, scale = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, L), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, L), lambda i: (i, 0)),
                   pl.BlockSpec((rows, L // block), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, L), jnp.int8),
                   jax.ShapeDtypeStruct((M, L // block), jnp.float32)],
        interpret=interpret,
    )(x2)
    return (q.reshape(shape),
            scale.reshape(shape[:-1] + (L // block,)))


def qdma_pack_rows(x, lo, *, rows: int, block: int = 256,
                   rows_per_tile: int = 256, interpret: bool = False):
    """Pack ONE descriptor: rows [lo, lo+rows) of the 2-D row view of x.

    ``lo`` is a traced scalar (chunks of equal ``rows`` share one compiled
    executable); ``rows`` is static. Returns (q (rows, L) int8,
    scale (rows, L/block) fp32)."""
    x2 = _as2d(x)
    chunk = jax.lax.dynamic_slice_in_dim(x2, lo, rows, axis=0)
    return qdma_pack(chunk, block=block, rows_per_tile=rows_per_tile,
                     interpret=interpret)


def _digest_kernel(v_ref, out_ref, *, lanes: int):
    i = pl.program_id(0)
    v = v_ref[...].astype(jnp.uint32)                 # (rows, lanes)
    rows = v.shape[0]
    # global flat index of each element (uint32 wrap-around is fine: the
    # digest only needs determinism, not order)
    base = (i * rows * lanes)
    idx = (jax.lax.broadcasted_iota(jnp.uint32, v.shape, 0) *
           jnp.uint32(lanes) +
           jax.lax.broadcasted_iota(jnp.uint32, v.shape, 1) +
           jnp.uint32(base))
    w1 = idx * jnp.uint32(2654435761) + jnp.uint32(0x9E3779B1)
    w2 = idx * jnp.uint32(0x85EBCA6B) + jnp.uint32(0xC2B2AE35)
    out_ref[0, 0] = jnp.sum(v * w1)
    out_ref[0, 1] = jnp.sum(v * w2)


def _bytes_view(x):
    """Raw little-endian byte view of x as a flat uint8 vector."""
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.uint8)
    u8 = jax.lax.bitcast_convert_type(x, jnp.uint8)
    return u8.reshape(-1)


def qdma_digest(x, *, rows_per_tile: int = 512, lanes: int = 128,
                interpret: bool = False):
    """Content fingerprint of x's raw bytes: (2,) uint32. Equal bytes ->
    equal digest; position-weighted so permutations don't collide. Zero
    padding is digest-neutral (padded elements contribute 0)."""
    u8 = _bytes_view(x)
    n = int(u8.shape[0])
    per = rows_per_tile * lanes
    npad = (-n) % per
    if npad:
        u8 = jnp.pad(u8, (0, npad))
    v = u8.reshape(-1, lanes)
    grid = (v.shape[0] // rows_per_tile,)
    kern = functools.partial(_digest_kernel, lanes=lanes)
    parts = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((rows_per_tile, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 2), jnp.uint32),
        interpret=interpret,
    )(v)
    return jnp.sum(parts, axis=0, dtype=jnp.uint32)


def qdma_unpack(q, scale, *, dtype="float32", rows_per_tile: int = 256,
                interpret: bool = False):
    shape = q.shape
    block = q.shape[-1] // scale.shape[-1]
    q2 = _as2d(q)
    s2 = _as2d(scale)
    M, L = q2.shape
    rows = min(rows_per_tile, M)
    while M % rows:
        rows -= 1
    grid = (M // rows,)
    kern = functools.partial(_unpack_kernel, block=block)
    x = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, L), lambda i: (i, 0)),
                  pl.BlockSpec((rows, L // block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, L), jnp.dtype(dtype)),
        interpret=interpret,
    )(q2, s2)
    return x.reshape(shape)
