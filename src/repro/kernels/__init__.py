# Pallas TPU kernels for the substrate's compute hot-spots + the QDMA-
# analogue pack kernel. Each <name>.py is a pl.pallas_call with explicit
# BlockSpec VMEM tiling; ops.py holds the jit'd wrappers; ref.py the
# pure-jnp oracles (also the dry-run lowering path).
