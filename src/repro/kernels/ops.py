"""jit'd dispatch wrappers for the Pallas kernels.

On TPU the real kernels run; elsewhere (this CPU container, the dry-run)
they execute in interpret mode or fall back to the jnp oracle — callers
never branch on backend themselves. ``backend='ref'`` forces the oracle
(used by the dry-run so cost_analysis sees real FLOPs, not opaque calls).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import paged_decode as _pd
from repro.kernels import qdma_pack as _qp
from repro.kernels import sampling as _sp
from repro.kernels import ssm_scan as _ss


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "interpret",
                                             "backend"))
def flash_attention(q, k, v, *, causal: bool = True, interpret: bool = False,
                    backend: str = "auto"):
    if backend == "ref" or (backend == "auto" and not _on_tpu()
                             and not interpret):
        return _ref.flash_attention_ref(q, k, v, causal=causal)
    return _fa.flash_attention(q, k, v, causal=causal,
                               interpret=interpret or not _on_tpu())


@functools.partial(jax.jit, static_argnames=("interpret", "backend"))
def flash_decode(q, k, v, pos, *, interpret: bool = False,
                 backend: str = "auto"):
    if backend == "ref" or (backend == "auto" and not _on_tpu()
                             and not interpret):
        return _ref.flash_decode_ref(q, k, v, pos)
    return _fd.flash_decode(q, k, v, pos,
                            interpret=interpret or not _on_tpu())


@functools.partial(jax.jit, static_argnames=("interpret", "backend"))
def paged_decode(q, k_pages, v_pages, tables, pos, *,
                 interpret: bool = False, backend: str = "auto"):
    """Block-table-indirected decode over the paged KV pool (serve plane)."""
    if backend == "ref" or (backend == "auto" and not _on_tpu()
                             and not interpret):
        return _ref.paged_decode_ref(q, k_pages, v_pages, tables, pos)
    return _pd.paged_decode(q, k_pages, v_pages, tables, pos,
                            interpret=interpret or not _on_tpu())


@functools.partial(jax.jit, static_argnames=("interpret", "backend"))
def paged_decode_quant(q, k_pages, v_pages, k_scale, v_scale, tables, pos, *,
                       interpret: bool = False, backend: str = "auto"):
    """paged_decode over an int8 page pool with per-(row,head) scales —
    half the HBM bytes per decoded token, dequantized in-tile."""
    if backend == "ref" or (backend == "auto" and not _on_tpu()
                             and not interpret):
        return _ref.paged_decode_quant_ref(q, k_pages, v_pages,
                                           k_scale, v_scale, tables, pos)
    return _pd.paged_decode_quant(q, k_pages, v_pages, k_scale, v_scale,
                                  tables, pos,
                                  interpret=interpret or not _on_tpu())


@functools.partial(jax.jit, static_argnames=("vocab_size", "interpret",
                                             "backend"))
def fused_sample(logits, temp, top_k, keys, *, vocab_size: int,
                 interpret: bool = False, backend: str = "auto"):
    """In-kernel temperature/top-k Gumbel sampling: (B, Vp) logits ->
    (B,) int32 token ids, bit-identical to ServeEngine._sample (the
    host oracle) row by row. keys: (B, 3) int32 (seed, rid, counter)."""
    if backend == "ref" or (backend == "auto" and not _on_tpu()
                             and not interpret):
        return _ref.fused_sample_ref(logits, temp, top_k, keys,
                                     vocab_size=vocab_size)
    return _sp.fused_sample(logits, temp, top_k, keys,
                            vocab_size=vocab_size,
                            interpret=interpret or not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "backend"))
def ssm_scan(xdt, Bv, Cv, log_a, *, chunk: int = 128,
             interpret: bool = False, backend: str = "auto"):
    if backend == "ref" or (backend == "auto" and not _on_tpu()
                             and not interpret):
        return _ref.ssm_scan_ref(xdt, Bv, Cv, log_a, chunk=chunk)
    return _ss.ssm_scan(xdt, Bv, Cv, log_a, chunk=chunk,
                        interpret=interpret or not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block", "interpret", "backend"))
def qdma_pack(x, *, block: int = 256, interpret: bool = False,
              backend: str = "auto"):
    if backend == "ref" or (backend == "auto" and not _on_tpu()
                             and not interpret):
        return _ref.qdma_pack_ref(x, block=block)
    return _qp.qdma_pack(x, block=block,
                         interpret=interpret or not _on_tpu())


@functools.partial(jax.jit, static_argnames=("dtype", "interpret", "backend"))
def qdma_unpack(q, scale, *, dtype: str = "float32",
                interpret: bool = False, backend: str = "auto"):
    if backend == "ref" or (backend == "auto" and not _on_tpu()
                             and not interpret):
        return _ref.qdma_unpack_ref(q, scale, dtype=dtype)
    return _qp.qdma_unpack(q, scale, dtype=dtype,
                           interpret=interpret or not _on_tpu())


@functools.partial(jax.jit, static_argnames=("rows", "block", "interpret",
                                             "backend"))
def qdma_pack_rows(x, lo, *, rows: int, block: int = 256,
                   interpret: bool = False, backend: str = "auto"):
    """Chunk-granular pack: one descriptor = rows [lo, lo+rows) of the 2-D
    row view. ``lo`` is traced, so equal-size chunks share an executable."""
    if backend == "ref" or (backend == "auto" and not _on_tpu()
                             and not interpret):
        return _ref.qdma_pack_rows_ref(x, lo, rows, block=block)
    return _qp.qdma_pack_rows(x, lo, rows=rows, block=block,
                              interpret=interpret or not _on_tpu())


@functools.partial(jax.jit, static_argnames=("interpret", "backend"))
def qdma_digest(x, *, interpret: bool = False, backend: str = "auto"):
    """On-device content fingerprint, (2,) uint32 — the staging engine's
    dirty-tracking primitive (skip mutated-but-equal leaves)."""
    if backend == "ref" or (backend == "auto" and not _on_tpu()
                             and not interpret):
        return _ref.qdma_digest_ref(x)
    return _qp.qdma_digest(x, interpret=interpret or not _on_tpu())
