"""Version-compatibility shims for the installed jax.

The repo targets the modern jax API surface (``jax.shard_map``,
``jax.tree_util.keystr(..., simple=True, separator=...)``); older releases
(0.4.x) spell both differently. Import from here instead of jax directly:

    from repro.compat import keystr, shard_map
"""
from __future__ import annotations

import jax

__all__ = ["axis_size", "keystr", "shard_map"]


def axis_size(axis: str) -> int:
    """``jax.lax.axis_size`` (jax >= 0.5); psum-of-1 constant-folds to the
    static axis size on older releases."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def _simple_key(k) -> str:
    # DictKey(.key) / SequenceKey(.idx) / GetAttrKey(.name) / FlattenedIndexKey
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def keystr(path, *, simple: bool = True, separator: str = "/") -> str:
    """``jax.tree_util.keystr(path, simple=..., separator=...)`` everywhere.

    jax < 0.5 only accepts the bare ``keystr(keys)`` form; reproduce the
    simple/separator behaviour by hand there.
    """
    try:
        return jax.tree_util.keystr(path, simple=simple, separator=separator)
    except TypeError:
        pass
    if not simple:
        return jax.tree_util.keystr(path)
    return separator.join(_simple_key(k) for k in path)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict: jax < 0.5 returns a
    one-element list of per-device dicts, newer jax returns the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


try:
    from jax import shard_map  # jax >= 0.5 (check_vma spelling)
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        """Adapter: old experimental shard_map spells ``check_vma`` as
        ``check_rep`` and is positional-friendly."""
        return _shard_map_exp(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)
