"""Serving driver: batched requests through the continuous-batching engine.

Default mode serves synthetic requests and reports latency/throughput.
``--fleet N`` serves through a ``ServeFleet`` (N engines as tenants under
the real SVFFManager); adding ``--autoscale`` turns on the elastic
control plane — one ``autoscale_step`` per drive-loop tick plans and
executes scale-out / scale-in / rebalance from live telemetry, with
``--spares`` warm parked standby engines for pause-free scale-out.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import SHAPES, list_archs, make_run_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--shape", default="decode_32k", choices=list(SHAPES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--paged", action="store_true",
                    help="block-granular paged KV cache")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill (attention stacks; 0 = whole)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--fleet", type=int, default=0,
                    help="serve through a ServeFleet of N engine tenants"
                         " under the SVFFManager (0 = bare engine)")
    ap.add_argument("--autoscale", action="store_true",
                    help="fleet mode: enable the elastic control plane")
    ap.add_argument("--spares", type=int, default=1,
                    help="fleet mode: warm parked standby engines")
    ap.add_argument("--slo-max-load", type=int, default=64)
    args = ap.parse_args(argv)

    run = make_run_config(args.arch, args.shape, smoke=args.smoke)
    model = build_model(run)
    params = model.init(jax.random.key(run.seed))
    if args.fleet > 0:
        return _serve_fleet(run, params, args)
    eng = ServeEngine(run, params, slots=args.slots, max_len=args.max_len,
                      paged=args.paged, page_size=args.page_size,
                      prefill_chunk=args.prefill_chunk)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, run.model.vocab_size, plen),
            max_new_tokens=args.new_tokens,
            temperature=args.temperature, top_k=args.top_k))
        eng.submit(reqs[-1])

    t0 = time.perf_counter()
    steps = 0
    while (eng.step() or eng.queue or eng._jobs) and steps < 10_000:
        steps += 1
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    out = {"requests": len(reqs), "completed": sum(r.done for r in reqs),
           "decode_steps": steps, "generated_tokens": toks,
           "wall_s": wall, "tokens_per_s": toks / wall}
    print(json.dumps(out))
    return 0 if out["completed"] == len(reqs) else 1


def _serve_fleet(run, params, args) -> int:
    import tempfile
    from repro.core.autoscaler import AutoscaleConfig
    from repro.serve import RequestRejected, ServeFleet

    autoscale = None
    if args.autoscale:
        autoscale = AutoscaleConfig(
            hysteresis=1, cooldown=2,
            max_engines=args.fleet + args.spares, pinned=("serve0",))
    fleet = ServeFleet(
        run, params, num_engines=args.fleet,
        num_devices=max(2 * (args.fleet + args.spares), 4),
        num_vfs=args.fleet + (args.spares if args.autoscale else 0),
        slots=args.slots, max_len=args.max_len, paged=args.paged,
        page_size=args.page_size, prefill_chunk=args.prefill_chunk,
        slo_max_load=args.slo_max_load, autoscale=autoscale,
        spare_engines=args.spares if args.autoscale else 0,
        workdir=tempfile.mkdtemp(prefix="svff_serve_"))

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, run.model.vocab_size, plen),
            max_new_tokens=args.new_tokens,
            temperature=args.temperature, top_k=args.top_k))

    t0 = time.perf_counter()
    pending = list(reqs)
    steps = 0
    actions = []
    while (pending or any(tn.load for tn in fleet.tenants.values())) \
            and steps < 10_000:
        retry = []
        for r in pending:
            try:
                fleet.submit(r)
            except RequestRejected:
                retry.append(r)        # side-effect-free: resubmit later
        pending = retry
        if autoscale is not None:
            act = fleet.autoscale_step()
            if act is not None:
                actions.append({"step": steps, "kind": act.kind,
                                "reason": act.reason})
        fleet.step()
        steps += 1
    res = fleet.drain()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    out = {"mode": "fleet", "engines_initial": args.fleet,
           "engines_final": sum(1 for tn in fleet.tenants.values()
                                if tn.status == "running"),
           "requests": len(reqs), "completed": sum(r.done for r in reqs),
           "drained": res.drained, "fleet_steps": steps,
           "generated_tokens": toks, "wall_s": wall,
           "tokens_per_s": toks / wall,
           "rejected_submissions": fleet.rejected_total,
           "autoscale_actions": actions,
           "journal_pending": fleet.mgr.query()["journal_pending"]}
    print(json.dumps(out))
    return 0 if out["completed"] == len(reqs) else 1


if __name__ == "__main__":
    sys.exit(main())
