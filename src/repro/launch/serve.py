"""Serving driver: batched requests through the continuous-batching engine.

Default mode serves synthetic requests and reports latency/throughput;
--svff wraps the engine in a Tenant under the SVFFManager so serving
survives pool reconfigurations (requests queue while paused).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import SHAPES, list_archs, make_run_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--shape", default="decode_32k", choices=list(SHAPES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--paged", action="store_true",
                    help="block-granular paged KV cache")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill (attention stacks; 0 = whole)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    args = ap.parse_args(argv)

    run = make_run_config(args.arch, args.shape, smoke=args.smoke)
    model = build_model(run)
    params = model.init(jax.random.key(run.seed))
    eng = ServeEngine(run, params, slots=args.slots, max_len=args.max_len,
                      paged=args.paged, page_size=args.page_size,
                      prefill_chunk=args.prefill_chunk)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, run.model.vocab_size, plen),
            max_new_tokens=args.new_tokens,
            temperature=args.temperature, top_k=args.top_k))
        eng.submit(reqs[-1])

    t0 = time.perf_counter()
    steps = 0
    while (eng.step() or eng.queue or eng._jobs) and steps < 10_000:
        steps += 1
    wall = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    out = {"requests": len(reqs), "completed": sum(r.done for r in reqs),
           "decode_steps": steps, "generated_tokens": toks,
           "wall_s": wall, "tokens_per_s": toks / wall}
    print(json.dumps(out))
    return 0 if out["completed"] == len(reqs) else 1


if __name__ == "__main__":
    sys.exit(main())
