"""End-to-end training driver with checkpoint/restart fault tolerance.

Runs on whatever devices the host has (CPU tests use the unit mesh; a TPU
pod picks up the full mesh). With --svff the job runs as a Tenant under the
SVFFManager — pause/reconf-able mid-run via the QMP socket (the paper's
deployment shape); without it, a plain standalone loop.

Restart semantics: --resume finds the newest valid checkpoint (manifest is
written last, so a crash mid-save is invisible) and continues with
bit-identical data order (batches are a pure function of step).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.store import CheckpointStore
from repro.configs import (OptimizerConfig, SHAPES, list_archs,
                           make_run_config)
from repro.data.pipeline import Prefetcher, SyntheticSource
from repro.launch.mesh import local_mesh_config, make_mesh_from_config
from repro.runtime.partitioning import ShardingRules
from repro.train.step import init_train_state, make_train_step


def build(args):
    mesh_cfg = local_mesh_config()
    overrides = {}
    if args.lr:
        overrides["optimizer"] = OptimizerConfig(lr=args.lr,
                                                 warmup=args.warmup)
    run = make_run_config(args.arch, args.shape, mesh=mesh_cfg,
                          smoke=args.smoke, microbatch=args.microbatch,
                          **overrides)
    if args.batch or args.seq:
        shape = dataclasses.replace(
            run.shape,
            global_batch=args.batch or run.shape.global_batch,
            seq_len=args.seq or run.shape.seq_len)
        run = dataclasses.replace(run, shape=shape)
    mesh = (make_mesh_from_config(mesh_cfg)
            if mesh_cfg.num_devices > 1 else None)
    rules = ShardingRules(mesh_cfg, run, mesh) if mesh else None
    return run, rules


def train(args) -> dict:
    run, rules = build(args)
    store = CheckpointStore(os.path.join(args.workdir, "ckpt"),
                            keep=args.keep)
    step_fn = jax.jit(make_train_step(run, rules,
                                      total_steps=args.steps))
    state = init_train_state(run, jax.random.key(run.seed))
    start = 0
    if args.resume and store.latest() is not None:
        state = store.restore(store.latest(), state)
        state = jax.tree.map(jnp.asarray, state)
        start = int(state["step"])
        print(f"[train] resumed from step {start}", flush=True)

    src = SyntheticSource(run, batch_override=run.shape.global_batch,
                          seq_override=run.shape.seq_len)
    pf = Prefetcher(src, depth=2, start_step=start)
    log_path = os.path.join(args.workdir, "metrics.jsonl")
    os.makedirs(args.workdir, exist_ok=True)
    tokens_per_step = run.shape.global_batch * run.shape.seq_len
    t_start = time.perf_counter()
    last = {}
    try:
        for i in range(start, args.steps):
            step_idx, batch = pf.next()
            assert step_idx == i
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            last = {k: float(v) for k, v in metrics.items()}
            last.update(step=i + 1, step_s=dt,
                        tokens_per_s=tokens_per_step / dt)
            with open(log_path, "a") as f:
                f.write(json.dumps(last) + "\n")
            if args.log_every and (i + 1) % args.log_every == 0:
                print(f"[train] step {i+1} loss {last['loss']:.4f} "
                      f"({last['tokens_per_s']:.0f} tok/s)", flush=True)
            if args.checkpoint_every and (i + 1) % args.checkpoint_every == 0:
                store.save_async(i + 1, state)
            if args.crash_at and (i + 1) == args.crash_at:
                print("[train] simulated crash", flush=True)
                store.wait()
                os._exit(17)        # hard kill: restart path must recover
    finally:
        pf.stop()
    store.wait()
    store.save(args.steps, state)
    last["wall_s"] = time.perf_counter() - t_start
    return last


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.0)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--crash-at", type=int, default=0,
                    help="simulate a hard crash after N steps (testing)")
    args = ap.parse_args(argv)
    last = train(args)
    print(json.dumps(last))
    return 0


if __name__ == "__main__":
    sys.exit(main())
