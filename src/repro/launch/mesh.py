"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first
device query, and tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax

from repro.configs.base import (MULTI_POD_MESH, SINGLE_POD_MESH, MeshConfig,
                                UNIT_MESH)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH


def make_mesh_from_config(cfg: MeshConfig):
    return jax.make_mesh(tuple(cfg.shape), tuple(cfg.axes))


def local_mesh_config() -> MeshConfig:
    """Whatever this host actually has (CPU tests / examples)."""
    n = len(jax.devices())
    return MeshConfig((n, 1), ("data", "model")) if n > 1 else UNIT_MESH
