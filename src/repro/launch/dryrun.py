"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, lower + compile the real step
function (train_step / prefill / serve_step) against ShapeDtypeStruct
inputs on the production mesh — 16x16 single-pod and 2x16x16 multi-pod —
and extract memory_analysis / cost_analysis / collective bytes for the
roofline (EXPERIMENTS.md §Dry-run, §Roofline).

No arrays are ever allocated at production shapes; the 512 placeholder
devices exist only inside this process.
"""
# The VERY FIRST lines — before ANY other import — jax locks the device
# count on first init. Do NOT set this globally (tests see 1 device).
import os
if "--real-devices" not in __import__("sys").argv:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512")

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import (SHAPES, get_model_config, list_archs,
                           make_run_config, shape_applicable)
from repro.launch.mesh import make_production_mesh, mesh_config
from repro.models.model import build_model
from repro.runtime.hlo import collective_stats, scan_op_counts
from repro.runtime.partitioning import ShardingRules, sharding_scope
from repro.runtime.roofline import Roofline, model_flops_estimate
from repro.train.step import (batch_specs, make_train_step,
                              train_state_shapes, train_state_specs)

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../results/dryrun")

ASSIGNED_ARCHS = [
    "arctic-480b", "olmoe-1b-7b", "qwen3-0.6b", "llama3-8b", "deepseek-67b",
    "phi3-mini-3.8b", "seamless-m4t-medium", "xlstm-350m",
    "jamba-1.5-large-398b", "internvl2-1b",
]


def _cell_path(arch, shape, mesh_name, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None, model_override=None):
    """Build and lower one cell; returns (lowered, run, rules, meta)."""
    import dataclasses as _dc
    mcfg = mesh_config(multi_pod=multi_pod)
    run = make_run_config(arch, shape_name, mesh=mcfg,
                          kernel_backend="reference",
                          **(overrides or {}))
    if model_override is not None:
        run = _dc.replace(run, model=model_override)
    model = build_model(run)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(mcfg, run, mesh)
    shape_cfg = SHAPES[shape_name]
    kind = shape_cfg.kind

    with mesh:
        with sharding_scope(rules):
            if kind == "train":
                step = make_train_step(run, rules)
                sshapes = train_state_shapes(run)
                sspecs = rules.named(train_state_specs(run, rules))
                bspecs = rules.named(batch_specs(run, rules))
                bshapes = model.input_specs()
                lowered = jax.jit(
                    step, in_shardings=(sspecs, bspecs),
                    donate_argnums=(0,)).lower(sshapes, bshapes)
            elif kind == "prefill":
                pshapes = model.param_shapes()
                pspecs = rules.named(rules.param_specs(pshapes))
                bspecs = rules.named(batch_specs(run, rules))
                bshapes = model.input_specs()

                def prefill(params, batch):
                    with sharding_scope(rules):
                        return model.prefill(params, batch)
                lowered = jax.jit(
                    prefill, in_shardings=(pspecs, bspecs)).lower(
                        pshapes, bshapes)
            else:  # decode
                pshapes = model.param_shapes()
                pspecs = rules.named(rules.param_specs(pshapes))
                cshapes = model.cache_specs()
                cspecs = rules.named(cache_partition_specs(rules, cshapes))
                ishapes = model.input_specs()
                from jax.sharding import PartitionSpec as P
                tok_spec = rules.named(P(
                    rules._fit(ishapes["tokens"].shape[0], rules.dp_axes),
                    None))
                pos_spec = rules.named(P())

                def serve_step(params, cache, tokens, pos):
                    with sharding_scope(rules):
                        return model.decode_step(params, cache, tokens, pos)
                lowered = jax.jit(
                    serve_step,
                    in_shardings=(pspecs, cspecs, tok_spec, pos_spec),
                    donate_argnums=(1,)).lower(
                        pshapes, cshapes, ishapes["tokens"], ishapes["pos"])
    return lowered, run, rules


def cache_partition_specs(rules: ShardingRules, cache_shapes):
    """Decode-cache specs: kv leaves get the kv_cache rule (seq sharding),
    recurrent states shard on batch. Leading dim is the period stack."""
    from jax.sharding import PartitionSpec as P

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        shape = leaf.shape
        if name in ("k", "v", "xk", "xv"):
            inner = rules.spec("kv_cache", shape[1:])
            return P(None, *inner)
        inner = rules.spec("state", shape[1:])
        return P(None, *inner)
    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# ---------------------------------------------------------------------------
# True-cost extraction.
#
# XLA's cost_analysis counts a while-loop body ONCE, not x trip-count, so a
# scanned layer stack under-reports flops/bytes/collectives by ~num_periods.
# Fix: compile two UNROLLED variants of the same cell at full width with
# P=1 and P=2 pattern-periods; every metric is linear in P
# (metric = a + b*P), so   b = m2 - m1,  a = m1 - b,  total = a + nper*b.
# The full scanned compile still provides memory_analysis (true buffer
# allocation) and proves the production mesh compiles.
#
# xLSTM blocks contain *inner* time scans (mLSTM chunk loop, sLSTM step
# loop) that stay while-loops even in the unrolled variants; their missing
# trips are added analytically (first-order formulas below).
# ---------------------------------------------------------------------------
def _inner_scan_correction(model_cfg, shape_cfg, kind: str) -> dict:
    """Analytic add-on flops/bytes for inner time scans (xlstm only)."""
    from repro.configs.base import MLSTM, SLSTM
    from repro.models.params import mlstm_dims, slstm_dims
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    if kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    mult = 4.0 if kind == "train" else 1.0      # fwd + remat replay + 2x bwd
    plen = len(model_cfg.block_pattern)
    nper = model_cfg.num_layers // plen
    flops = 0.0
    for j, bk in enumerate(model_cfg.block_pattern):
        if bk == MLSTM:
            di, H = mlstm_dims(model_cfg)
            hd = model_cfg.xlstm.head_dim
            Q = min(model_cfg.xlstm.chunk, S)
            nc = S // Q
            body = B * H * (4 * Q * Q * hd + 8 * Q * hd * hd)
            flops += (nc - 1) * body * mult * nper
        elif bk == SLSTM:
            heads, dh, d_up = slstm_dims(model_cfg)
            D = model_cfg.d_model
            body = B * (8 * D * D + 8 * D * dh + 20 * D)
            flops += (S - 1) * body * mult * nper
    return {"flops": flops, "bytes": flops / 16.0}  # ~AI of these blocks


def _cost_of(lowered) -> dict:
    compiled = lowered.compile()
    cost = compat.cost_analysis(compiled)
    coll = collective_stats(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll.total_bytes),
            "coll_by_op": dict(coll.bytes_by_op)}


def true_costs(arch: str, shape_name: str, multi_pod: bool, run,
               overrides: dict | None = None) -> dict:
    """Extrapolated per-device costs for the full layer count."""
    import dataclasses as _dc
    base = run.model
    plen = len(base.block_pattern)
    nper = base.num_layers // plen
    var_overrides = dict(overrides or {})
    var_overrides.setdefault("sharding", run.sharding)
    var_overrides["sharding"] = _dc.replace(var_overrides["sharding"],
                                            scan_layers=False,
                                            unroll_microbatch=True)
    var_overrides["precision"] = run.precision
    var_overrides["optimizer"] = run.optimizer
    ms = []
    for P in (1, 2):
        mc = _dc.replace(
            base, num_layers=plen * P,
            num_encoder_layers=(plen * P if base.num_encoder_layers else 0))
        lowered, _, _ = lower_cell(arch, shape_name, multi_pod,
                                   overrides=var_overrides,
                                   model_override=mc)
        ms.append(_cost_of(lowered))
    out = {}
    for key in ("flops", "bytes", "coll_bytes"):
        b = ms[1][key] - ms[0][key]
        a = ms[0][key] - b
        out[key] = max(a + nper * b, 0.0)
    by_op = {}
    for op in set(ms[0]["coll_by_op"]) | set(ms[1]["coll_by_op"]):
        b = ms[1]["coll_by_op"].get(op, 0) - ms[0]["coll_by_op"].get(op, 0)
        a = ms[0]["coll_by_op"].get(op, 0) - b
        v = a + nper * b
        if v > 0:
            by_op[op] = v
    out["coll_by_op"] = by_op
    corr = _inner_scan_correction(base, run.shape, run.shape.kind)
    out["flops"] += corr["flops"] / (512 if multi_pod else 256)
    out["bytes"] += corr["bytes"] / (512 if multi_pod else 256)
    out["inner_scan_corr_flops"] = corr["flops"]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR, force: bool = False,
             tag: str = "", overrides: dict | None = None) -> dict:
    mesh_name = ("multi" if multi_pod else "single") + (f"-{tag}" if tag
                                                        else "")
    path = _cell_path(arch, shape_name, mesh_name, out_dir)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    model_cfg = get_model_config(arch)
    ok, why = shape_applicable(model_cfg, SHAPES[shape_name])
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "chips": 512 if multi_pod else 256}
    if not ok:
        result.update({"status": "skipped", "reason": why})
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        return result

    try:
        from repro.configs import ShardingConfig
        overrides = dict(overrides or {})
        overrides.setdefault("sharding", ShardingConfig(remat="full"))

        t0 = time.perf_counter()
        lowered, run, rules = lower_cell(arch, shape_name, multi_pod,
                                         overrides)
        t_lower = time.perf_counter() - t0

        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        cost = compat.cost_analysis(compiled)
        hlo = compiled.as_text()
        coll = collective_stats(hlo)

        # true per-device costs via unrolled 1/2-period extrapolation
        tc = true_costs(arch, shape_name, multi_pod, run, overrides)

        chips = result["chips"]
        rf = Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=tc["flops"],
            hlo_bytes=tc["bytes"],
            collective_bytes=tc["coll_bytes"],
            collective_detail={"bytes_by_op": tc["coll_by_op"]},
            model_flops=model_flops_estimate(run.model, run.shape))

        result.update({
            "status": "ok",
            "lower_s": t_lower, "compile_s": t_compile,
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
            },
            "cost_scanned_raw": {k: float(v) for k, v in cost.items()
                                 if isinstance(v, (int, float))
                                 and "{" not in k},
            "cost_extrapolated": {k: v for k, v in tc.items()
                                  if k != "coll_by_op"},
            "collectives_scanned_raw": coll.describe(),
            "collectives": {"bytes_by_op": tc["coll_by_op"],
                            "total_bytes": sum(tc["coll_by_op"].values())},
            "hlo_ops": scan_op_counts(hlo),
            "roofline": rf.row(),
        })
    except Exception as e:                                    # noqa: BLE001
        result.update({"status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]})
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES),
                    help="one shape (default: all four)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--real-devices", action="store_true",
                    help="skip the 512-device override (debug)")
    args = ap.parse_args(argv)

    if args.list:
        for a in ASSIGNED_ARCHS:
            print(a)
        return 0

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                r = run_cell(arch, shape, mp, out_dir=args.out,
                             force=args.force)
                status = r["status"]
                line = f"{arch:24s} {shape:12s} {r['mesh']:7s} {status}"
                if status == "ok":
                    rf = r["roofline"]
                    line += (f"  bound={rf['bound']:10s}"
                             f" step={rf['step_s']*1e3:8.2f}ms"
                             f" compile={r['compile_s']:6.1f}s")
                    mb = (r['memory']['argument_bytes'] +
                          r['memory']['temp_bytes']) / 2**30
                    line += f" mem/dev={mb:7.2f}GiB"
                elif status == "error":
                    failures += 1
                    line += f"  {r['error'][:80]}"
                print(line, flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
