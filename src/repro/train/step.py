"""Train-step builder: value_and_grad + clip + optimizer, with optional
microbatch gradient accumulation, under the active sharding scope.

The returned step function is pure (state, batch) -> (state, metrics) and is
what Tenants execute and the dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.models.model import Model, build_model
from repro.runtime.partitioning import ShardingRules, sharding_scope
from repro.train.optim import (build_optimizer, clip_by_global_norm,
                               lr_schedule)


def init_train_state(run: RunConfig, rng: jax.Array) -> dict:
    model = build_model(run)
    params = model.init(rng)
    opt = build_optimizer(run.optimizer)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def train_state_shapes(run: RunConfig) -> dict:
    """ShapeDtypeStructs of the full train state (dry-run: no allocation)."""
    model = build_model(run)
    opt = build_optimizer(run.optimizer)
    pshapes = model.param_shapes()
    oshapes = jax.eval_shape(opt.init, pshapes)
    return {"params": pshapes, "opt": oshapes,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_state_specs(run: RunConfig, rules: ShardingRules) -> dict:
    """PartitionSpec tree matching train_state_shapes."""
    from jax.sharding import PartitionSpec as P
    model = build_model(run)
    pshapes = model.param_shapes()
    pspecs = rules.param_specs(pshapes)
    opt = build_optimizer(run.optimizer)
    ospecs = opt.state_specs(rules, pspecs, pshapes)
    return {"params": pspecs, "opt": ospecs, "step": P()}


def batch_specs(run: RunConfig, rules: ShardingRules) -> dict:
    from jax.sharding import PartitionSpec as P
    model = build_model(run)
    specs = model.input_specs()
    out = {}
    for k, v in specs.items():
        if v.shape == ():
            out[k] = P()
        else:
            out[k] = P(rules._fit(v.shape[0], rules.dp_axes),
                       *([None] * (len(v.shape) - 1)))
    return out


def make_train_step(run: RunConfig, rules: Optional[ShardingRules] = None,
                    total_steps: int = 10000):
    model = build_model(run)
    opt = build_optimizer(run.optimizer)
    sched = lr_schedule(run.optimizer, total_steps)

    def loss_fn(params, batch):
        with sharding_scope(rules):
            return model.loss(params, batch)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def train_step(state, batch):
        params = state["params"]
        if run.microbatch > 1:
            mb = run.microbatch

            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
            mbatch = jax.tree.map(split, batch)

            def body(acc, b):
                g, m = grads_of(params, b)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, m
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(
                body, zero, mbatch,
                unroll=mb if run.sharding.unroll_microbatch else 1)
            grads = jax.tree.map(lambda g: (g / mb).astype(jnp.float32),
                                 grads)
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        else:
            grads, metrics = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, run.optimizer.grad_clip)
        lr = sched(state["step"])
        with sharding_scope(rules):
            new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return new_state, metrics

    return train_step


def make_eval_step(run: RunConfig, rules: Optional[ShardingRules] = None):
    model = build_model(run)

    def eval_step(params, batch):
        with sharding_scope(rules):
            loss, metrics = model.loss(params, batch)
        return metrics

    return eval_step


def make_serve_steps(run: RunConfig, rules: Optional[ShardingRules] = None):
    """Returns (prefill_fn, decode_fn) under the sharding scope."""
    model = build_model(run)

    def prefill(params, batch):
        with sharding_scope(rules):
            return model.prefill(params, batch)

    def decode(params, cache, tokens, pos):
        with sharding_scope(rules):
            return model.decode_step(params, cache, tokens, pos)

    return prefill, decode


def make_decode_step(run: RunConfig,
                     rules: Optional[ShardingRules] = None, *,
                     paged: bool = False, fused: bool = False):
    """Continuous-batching decode step with an active-slot mask; with
    ``paged`` the cache is the paged-KV page pool and a block table rides
    along (see ``Model.decode_step``).

    With ``fused`` the step also takes per-slot sampling params
    (temp (B,) f32, top_k (B,) i32, keys (B,3) i32 = (seed, rid,
    token_counter)) and returns SAMPLED TOKEN IDS (B,) i32 instead of
    logits — temperature/top-k Gumbel sampling runs on-device
    (``kernels/sampling``), bit-identical to ``ServeEngine._sample``, and
    the (B, V) logits never leave the device."""
    model = build_model(run)

    def _sample_on_device(logits, temp, topk, keys):
        from repro.kernels import ops as kops
        backend = run.kernel_backend
        interpret = (backend == "pallas"
                     and jax.default_backend() != "tpu")
        return kops.fused_sample(
            logits, temp, topk, keys, vocab_size=run.model.vocab_size,
            interpret=interpret,
            backend="auto" if backend == "pallas" else "ref")

    if paged and fused:
        def decode(params, cache, tokens, pos, tables, active,
                   temp, topk, keys):
            with sharding_scope(rules):
                logits, cache = model.decode_step(params, cache, tokens,
                                                  pos, tables=tables,
                                                  active=active)
                return _sample_on_device(logits, temp, topk, keys), cache
    elif paged:
        def decode(params, cache, tokens, pos, tables, active):
            with sharding_scope(rules):
                return model.decode_step(params, cache, tokens, pos,
                                         tables=tables, active=active)
    elif fused:
        def decode(params, cache, tokens, pos, active, temp, topk, keys):
            with sharding_scope(rules):
                logits, cache = model.decode_step(params, cache, tokens,
                                                  pos, active=active)
                return _sample_on_device(logits, temp, topk, keys), cache
    else:
        def decode(params, cache, tokens, pos, active):
            with sharding_scope(rules):
                return model.decode_step(params, cache, tokens, pos,
                                         active=active)
    return decode


def make_prefill_chunk(run: RunConfig,
                       rules: Optional[ShardingRules] = None):
    """Chunked-prefill step (attention-pattern stacks only)."""
    model = build_model(run)

    def chunk(params, cache, tokens, offset):
        with sharding_scope(rules):
            return model.prefill_chunk(params, cache, tokens, offset)

    return chunk
