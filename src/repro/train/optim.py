"""Optimizers (self-contained, optax-free): AdamW and Adafactor.

Both expose:
  init(params)                      -> opt_state (pytree)
  update(grads, state, params, lr)  -> (new_params, new_state)
  state_specs(rules, param_specs, param_shapes) -> PartitionSpec tree
    (optimizer state shards exactly like the params it mirrors — ZeRO-3
     falls out of FSDP param sharding; Adafactor's factored moments drop
     the corresponding spec dims).

Adafactor (factored second moments, no first moment) is what the 400B-class
archs (arctic, jamba) use so optimizer state fits v5e HBM — see DESIGN.md.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as Pspec

from repro.configs.base import OptimizerConfig


def lr_schedule(cfg: OptimizerConfig, total_steps: int = 10000):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup, 1))
        t = jnp.clip((step - cfg.warmup) / max(total_steps - cfg.warmup, 1),
                     0.0, 1.0)
        cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * t))
        return cfg.lr * warm * cos
    return lr


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


@dataclass
class Optimizer:
    cfg: OptimizerConfig
    init: Callable
    update: Callable                 # (grads, state, params, lr) -> (p, s)
    state_specs: Callable            # (rules, param_specs, shapes) -> specs


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def make_adamw(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        b1, b2 = cfg.beta1, cfg.beta2
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mh, vh = m / bc1, v / bc2
            step = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:                      # decoupled wd on matrices
                step = step + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x:
                             isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x:
                             isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x:
                             isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "count": c}

    def state_specs(rules, param_specs, shapes):
        return {"m": param_specs, "v": param_specs, "count": Pspec()}

    return Optimizer(cfg, init, update, state_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored v for ndim>=2 over the last two dims)
# ---------------------------------------------------------------------------
def make_adafactor(cfg: OptimizerConfig) -> Optimizer:
    def factored(p):
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        def one(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(one, params,
                                  is_leaf=lambda x: isinstance(x, jax.Array)),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        beta2 = 1.0 - (c.astype(jnp.float32) ** -0.8)
        eps = 1e-30

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if factored(p):
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                step = g32 * rfac[..., None] * cfac[..., None, :]
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                step = g32 * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            # update clipping (RMS <= 1) — adafactor's stability trick
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + eps)
            step = step / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                step = step + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), ns

        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state["f"])
        flat_p = jax.tree.leaves(params)
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_s = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return new_p, {"f": new_s, "count": c}

    def state_specs(rules, param_specs, shapes):
        def one(spec, shape):
            dims = shape.shape if hasattr(shape, "shape") else shape
            sp = tuple(spec) + (None,) * (len(dims) - len(tuple(spec)))
            if len(dims) >= 2 and dims[-1] > 1 and dims[-2] > 1:
                return {"vr": Pspec(*sp[:-1]),
                        "vc": Pspec(*(sp[:-2] + sp[-1:]))}
            return {"v": Pspec(*sp)}
        f = jax.tree.map(one, param_specs, shapes,
                         is_leaf=lambda x: isinstance(x, Pspec))
        return {"f": f, "count": Pspec()}

    return Optimizer(cfg, init, update, state_specs)


def make_sgd(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) -
                          lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_p, {"count": state["count"] + 1}

    def state_specs(rules, param_specs, shapes):
        return {"count": Pspec()}

    return Optimizer(cfg, init, update, state_specs)


def build_optimizer(cfg: OptimizerConfig) -> Optimizer:
    return {"adamw": make_adamw, "adafactor": make_adafactor,
            "sgd": make_sgd}[cfg.name](cfg)
