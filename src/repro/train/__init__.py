"""Training substrate: optimizers + train-step builder."""
