"""Crash-consistent sharded checkpoint store."""
