"""Sharded, crash-consistent checkpoint store.

Layout per checkpoint:
  <dir>/step_<N>/
    leaf_00000.npy ...      one file per pytree leaf (host-gathered)
    MANIFEST.json           written LAST via atomic rename — a checkpoint
                            without a valid manifest is ignored (crash mid-
                            write never corrupts restore).

Each manifest records the treedef, per-leaf shape/dtype/crc32, and user
metadata (step, config fingerprint). This store backs both periodic
fault-tolerance checkpoints and the SVFF pause snapshots' persistent tier.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), leaf) for p, leaf in flat]


def tree_fingerprint(tree) -> str:
    """Structural fingerprint: paths + shapes + dtypes (not values)."""
    desc = [(p, tuple(np.shape(l)), str(np.asarray(l).dtype if not
             isinstance(l, jax.Array) else l.dtype))
            for p, l in _flatten_with_paths(tree)]
    return f"{zlib.crc32(json.dumps(desc).encode()):08x}"


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None,
             verify: bool = True) -> str:
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        leaves_meta = []
        for i, (path, leaf) in enumerate(flat):
            arr = np.asarray(jax.device_get(leaf))
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            leaves_meta.append({
                "path": jax.tree_util.keystr(path), "file": fn,
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": (int(zlib.crc32(np.ascontiguousarray(arr).tobytes()))
                          if verify else None),
            })
        manifest = {"step": step, "leaves": leaves_meta,
                    "fingerprint": tree_fingerprint(tree),
                    "metadata": metadata or {}}
        mpath = os.path.join(tmp, "MANIFEST.json.part")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        os.replace(mpath, os.path.join(tmp, "MANIFEST.json"))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                    # atomic publish
        self._gc()
        return final

    def save_async(self, step: int, tree: Any,
                   metadata: Optional[dict] = None) -> threading.Thread:
        """Non-blocking save: device->host copy happens here (cheap,
        snapshot-consistent), file I/O on a worker thread."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.wait()
        t = threading.Thread(target=self.save,
                             args=(step, host_tree, metadata), daemon=True)
        t.start()
        self._async_thread = t
        return t

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    # -- restore ---------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        if not os.path.isdir(self.dir):
            return out
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "MANIFEST.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Any, shardings: Any = None,
                verify: bool = True) -> Any:
        """Restore into the structure of ``like`` (values ignored).
        ``shardings``: optional matching tree of jax.sharding.Sharding —
        leaves are placed directly with the target sharding (resharding on
        restore = elastic restart onto a different mesh)."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        metas = manifest["leaves"]
        if len(metas) != len(flat_like):
            raise ValueError(
                f"checkpoint has {len(metas)} leaves, target structure "
                f"expects {len(flat_like)}")
        shard_flat = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "device_set"))
            if shardings is not None else [None] * len(metas))
        leaves = []
        for meta, shard in zip(metas, shard_flat):
            arr = np.load(os.path.join(d, meta["file"]))
            if str(arr.dtype) != meta["dtype"]:
                # np.save stores ml_dtypes (bfloat16, ...) as raw void —
                # view the bytes back through the manifest dtype
                import ml_dtypes  # noqa: F401
                arr = arr.view(np.dtype(meta["dtype"]))
            if verify and meta.get("crc32") is not None:
                crc = int(zlib.crc32(np.ascontiguousarray(arr).tobytes()))
                if crc != meta["crc32"]:
                    raise IOError(f"crc mismatch for {meta['path']}")
            leaves.append(jax.device_put(arr, shard) if shard is not None
                          else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def metadata(self, step: int) -> dict:
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            return json.load(f)["metadata"]

    def remove(self, step: int) -> bool:
        """Delete one checkpoint (idempotent). Crash recovery uses this to
        drop the orphan snapshot of a detach that was rolled back."""
        d = os.path.join(self.dir, f"step_{step}")
        if os.path.isdir(d):
            shutil.rmtree(d, ignore_errors=True)
            return True
        return False

    def sweep_tmp(self) -> int:
        """Remove ``.tmp_step_*`` staging dirs a crash mid-save left
        behind (they never had a manifest, so restores already ignore
        them — this just reclaims the space)."""
        n = 0
        if not os.path.isdir(self.dir):
            return n
        for d in os.listdir(self.dir):
            if d.startswith(".tmp_step_"):
                shutil.rmtree(os.path.join(self.dir, d),
                              ignore_errors=True)
                n += 1
        return n

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
