"""Synthetic-data pipeline: deterministic, host-sharded, prefetched.

Real deployments swap ``SyntheticSource`` for a tokenized corpus reader;
everything downstream (host sharding, prefetch thread, device placement) is
production-shaped. Determinism: batch content is a pure function of
(seed, step), so restarts resume bit-identically — required for the
checkpoint/restart fault-tolerance tests.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RunConfig


@dataclass
class HostShard:
    """This host's slice of the global batch (multi-host data loading)."""
    index: int = 0
    count: int = 1


class SyntheticSource:
    """Markov-chain-flavoured synthetic LM tokens (harder than uniform —
    loss actually decreases, which the examples/tests rely on)."""

    def __init__(self, run: RunConfig, shard: HostShard = HostShard(),
                 batch_override: Optional[int] = None,
                 seq_override: Optional[int] = None):
        self.run = run
        self.cfg = run.model
        self.shard = shard
        B = batch_override or run.shape.global_batch
        assert B % shard.count == 0
        self.local_batch = B // shard.count
        self.seq = seq_override or run.shape.seq_len

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (self.run.seed * 1_000_003 + step) * 97 + self.shard.index)
        B, S = self.local_batch, self.seq
        V = cfg.vocab_size
        # structured tokens: noisy arithmetic sequences -> learnable
        start = rng.integers(0, V, (B, 1))
        stride = rng.integers(1, 7, (B, 1))
        base = (start + stride * np.arange(S + 1)[None, :]) % V
        noise = rng.integers(0, V, (B, S + 1))
        mask = rng.random((B, S + 1)) < 0.1
        toks = np.where(mask, noise, base).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.frontend.kind == "vision":
            batch["patches"] = rng.standard_normal(
                (B, cfg.frontend.num_patches, cfg.d_model)).astype(np.float32)
        if cfg.is_encoder_decoder:
            Te = max(1, S // cfg.frontend.frame_ratio)
            batch["frames"] = rng.standard_normal(
                (B, Te, cfg.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (double buffering host-side) + optional
    device placement with the batch sharding."""

    def __init__(self, source: SyntheticSource, depth: int = 2,
                 shardings: Optional[dict] = None, start_step: int = 0):
        self.source = source
        self.shardings = shardings
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            try:
                self.q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self) -> tuple[int, dict]:
        step, batch = self.q.get()
        if self.shardings is not None:
            batch = {k: jax.device_put(v, self.shardings[k])
                     for k, v in batch.items()}
        return step, batch

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2)
