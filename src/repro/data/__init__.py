"""Deterministic host-sharded data pipeline."""
