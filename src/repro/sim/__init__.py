"""repro.sim — deterministic scenario simulation for the SVFF core.

The paper claims pause-based reconfiguration is transparent to live
tenants across ARBITRARY sequences of management operations (§IV, Tables
I/II). Hand-written tests cover a handful of sequences; this package
checks the claim property-style over thousands of randomized histories,
driving the REAL ``SVFFManager`` / ``DevicePool`` / scheduler / pause /
staging / records / checkpoint code with lightweight ``SimTenant``s and
simulated device tokens.

Pieces
------
  clock       ``VirtualClock`` — deterministic virtual time + event log
  scenario    the op DSL (``Op``) and the seeded generator
              (``generate_scenario``): same seed -> same op sequence
  tenant      ``SimTenant`` — numpy-state tenant whose state is a pure
              function of ``(seed, steps_done)``
  invariants  ``check_invariants`` (I1-I5, I8) + ``check_timings`` (I6)
              + ``check_pause_timings`` (I7) + ``check_federation``
              (I15), asserted after every op — see its docstring for
              the list
  chaos       crash-point catalogue (``CRASH_POINTS``), per-cell runner
              (``run_crash_case``) and the full ``crash_matrix``; I9
              (recovery idempotence) lives in ``recover_manager``
  harness     ``ScenarioRunner`` / ``run_scenario`` — executes a scenario,
              records per-op outcomes (ok / atomically rejected) and the
              Table-II timing dict of every reconf; ``crash`` ops kill
              the manager at a crash point and rebuild it via
              ``SVFFManager.recover``
  federation  the multi-host plane: ``FedScenarioConfig`` /
              ``run_fed_scenario`` over a lease-based
              ``FederationCoordinator``, the network-fault catalogue
              (``NETWORK_FAULTS``: armed one-shot partitions instead of
              crash points) with ``run_network_fault_case`` /
              ``network_fault_matrix``, and ``federation_fingerprint``
              (the I16 recovery-idempotence digest)

Reproducing a failure
---------------------
Every ``InvariantViolation`` message carries ``seed=<s> policy=<p>
op#<i>``. Replay it exactly with:

    from repro.sim import ScenarioConfig, ScenarioRunner
    ScenarioRunner(ScenarioConfig(seed=<s>, policy="<p>")).run()

``ScenarioResult.fingerprint()`` digests the whole outcome (per-op status
+ final tenant states); two runs of one seed always match, which the
tests assert. See also ``src/repro/sim/README.md``.
"""
from repro.sim.chaos import (CRASH_POINTS, CrashSpec, crash_matrix,
                             recover_manager, run_crash_case,
                             state_fingerprint)
from repro.sim.clock import VirtualClock
from repro.sim.federation import (FED_OP_KINDS, FedOp, FedRunner,
                                  FedScenarioConfig, NETWORK_FAULTS,
                                  NetFaultSpec, build_fed_cell,
                                  federation_fingerprint,
                                  generate_fed_scenario,
                                  network_fault_matrix, run_fed_scenario,
                                  run_network_fault_case)
from repro.sim.harness import (OpResult, ScenarioResult, ScenarioRunner,
                               run_scenario)
from repro.sim.invariants import (InvariantViolation, check_autoscale,
                                  check_federation, check_invariants,
                                  check_pause_timings, check_timings)
from repro.sim.scenario import (ARRIVAL_PATTERNS, Op, OP_KINDS,
                                ScenarioConfig, generate_scenario)
from repro.sim.tenant import ServeSimTenant, SimServeTenant, SimTenant

__all__ = [
    "ARRIVAL_PATTERNS", "CRASH_POINTS", "CrashSpec", "FED_OP_KINDS",
    "FedOp", "FedRunner", "FedScenarioConfig", "InvariantViolation",
    "NETWORK_FAULTS", "NetFaultSpec", "Op", "OP_KINDS", "OpResult",
    "ScenarioConfig", "ScenarioResult", "ScenarioRunner",
    "ServeSimTenant", "SimServeTenant", "SimTenant", "VirtualClock",
    "build_fed_cell", "check_autoscale", "check_federation",
    "check_invariants", "check_pause_timings", "check_timings",
    "crash_matrix", "federation_fingerprint", "generate_fed_scenario",
    "generate_scenario", "network_fault_matrix", "recover_manager",
    "run_crash_case", "run_fed_scenario", "run_network_fault_case",
    "run_scenario", "state_fingerprint",
]
