"""repro.sim — deterministic scenario simulation for the SVFF core.

The paper claims pause-based reconfiguration is transparent to live
tenants across ARBITRARY sequences of management operations (§IV, Tables
I/II). Hand-written tests cover a handful of sequences; this package
checks the claim property-style over thousands of randomized histories,
driving the REAL ``SVFFManager`` / ``DevicePool`` / scheduler / pause /
staging / records / checkpoint code with lightweight ``SimTenant``s and
simulated device tokens.

Pieces
------
  clock       ``VirtualClock`` — deterministic virtual time + event log
  scenario    the op DSL (``Op``) and the seeded generator
              (``generate_scenario``): same seed -> same op sequence
  tenant      ``SimTenant`` — numpy-state tenant whose state is a pure
              function of ``(seed, steps_done)``
  invariants  ``check_invariants`` (I1-I5) + ``check_timings`` (I6),
              asserted after every op — see its docstring for the list
  harness     ``ScenarioRunner`` / ``run_scenario`` — executes a scenario,
              records per-op outcomes (ok / atomically rejected) and the
              Table-II timing dict of every reconf

Reproducing a failure
---------------------
Every ``InvariantViolation`` message carries ``seed=<s> policy=<p>
op#<i>``. Replay it exactly with:

    from repro.sim import ScenarioConfig, ScenarioRunner
    ScenarioRunner(ScenarioConfig(seed=<s>, policy="<p>")).run()

``ScenarioResult.fingerprint()`` digests the whole outcome (per-op status
+ final tenant states); two runs of one seed always match, which the
tests assert. See also ``src/repro/sim/README.md``.
"""
from repro.sim.clock import VirtualClock
from repro.sim.harness import (OpResult, ScenarioResult, ScenarioRunner,
                               run_scenario)
from repro.sim.invariants import (InvariantViolation, check_invariants,
                                  check_pause_timings, check_timings)
from repro.sim.scenario import (Op, OP_KINDS, ScenarioConfig,
                                generate_scenario)
from repro.sim.tenant import ServeSimTenant, SimTenant

__all__ = [
    "InvariantViolation", "Op", "OP_KINDS", "OpResult", "ScenarioConfig",
    "ScenarioResult", "ScenarioRunner", "ServeSimTenant", "SimTenant",
    "VirtualClock",
    "check_invariants", "check_pause_timings", "check_timings",
    "generate_scenario", "run_scenario",
]
