"""Federation scenario plane — multi-host scenarios + network-fault chaos.

The single-host scenario machinery (``scenario``/``harness``/``chaos``)
proves that ONE manager survives arbitrary op histories and process
crashes. This module lifts that to a FEDERATION: several ``Host``s (each
a full manager + journal + pool + serving tenants) behind a
``FederationCoordinator``, with the network itself as the fault plane —
partitions instead of process crashes, lease lapses instead of device
failures, stale coordinators instead of stale snapshots.

Three public surfaces, mirroring the single-host trio:

  * ``FedScenarioConfig`` / ``generate_fed_scenario`` — seeded generator
    over federation ops (``FED_OP_KINDS``). Every fault knob defaults to
    0, so a pre-fault config draws a byte-identical op stream.
  * ``FedRunner`` / ``run_fed_scenario`` — executes a scenario against
    real hosts, asserting per-host invariants (I1-I14 via
    ``check_invariants``) AND the federation invariants (I15 via
    ``check_federation``) after every op; ``host_crash`` ops additionally
    assert I16 (double ``recover`` is a ``federation_fingerprint``
    no-op).
  * ``NETWORK_FAULTS`` / ``run_network_fault_case`` /
    ``network_fault_matrix`` — the network-fault analogue of
    ``chaos.CRASH_POINTS``: each catalogued window arms a one-shot
    partition at a named instant inside a coordinator path
    (``Fabric.arm``), and the per-cell runner asserts the catalogued
    outcome, I15/I16, and end-to-end token-oracle fidelity (I10) for
    every request the fault touched.

Op kinds (``FedOp.kind``):

  init        build the fleet: ``num_hosts`` hosts x 2 serving engines
              (3 VFs each: one spare stays detached so autoscale
              snapshots see real ``free_vfs``), coordinator heartbeats
  submit      admit ``n`` requests through coordinator routing
              (``choose_host`` over replicated snapshots); typed
              rejections (no live host, every engine full) are clean
  step        every host's running engines advance ``steps`` iterations
  beat        advance the virtual clock by ``dt`` and run one lease
              heartbeat round (renews reachable hosts, pulls snapshots)
  migrate     cross-host journaled request migration ``host -> dst``
              (picks the first migratable in-flight request; partitions
              mid-op DEFER the journal entry — resolved post-heal)
  partition   isolate ``host`` from the rest of the fabric (the
              coordinator stays with the majority side)
  heal        heal the fabric, heartbeat, and run federation recovery
              (resolves deferred cross-host entries, reconciles
              in-doubt admissions)
  host_crash  kill+rebuild ``host``'s manager from its journal (the
              single-host recovery path under federation wiring), then
              assert I16: a second recovery is fingerprint-identical
  handoff     coordinator failover: successor at epoch+1 fences every
              reachable host; the old coordinator's object stays live
              (split-brain fencing is ITS problem now)
  autoscale   one fleet-wide policy epoch over replicated telemetry;
              any planned action must be justified by its snapshot
              (I11), and a snapshot older than the staleness bound
              plans nothing
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import shutil
import tempfile
import zlib
from typing import Iterable, Optional, Sequence

from repro.core.autoscaler import Autoscaler, AutoscaleConfig
from repro.core.errors import (FederationError, HostUnreachableError,
                               LeaseExpiredError, SplitBrainError)
from repro.core.federation import Fabric, FederationCoordinator
from repro.core.host import Host
from repro.core.scheduler import AdmissionError
from repro.sim.chaos import state_fingerprint
from repro.sim.clock import VirtualClock
from repro.sim.invariants import (InvariantViolation, _serving_map,
                                  check_autoscale, check_federation,
                                  check_invariants)
from repro.sim.tenant import SimServeTenant

FED_OP_KINDS = ("init", "submit", "step", "beat", "migrate", "partition",
                "heal", "host_crash", "handoff", "autoscale")

#: lease/staleness parameters every fed cell runs with (virtual seconds)
LEASE_TTL = 3.0
MAX_STALENESS = 2.0


@dataclasses.dataclass(frozen=True)
class FedOp:
    kind: str
    host: Optional[str] = None      # acting host (partition victim, ...)
    dst: Optional[str] = None       # migrate only: destination host
    steps: int = 1                  # step only
    n: int = 1                      # submit only: batch size
    dt: float = 0.0                 # beat only: virtual seconds to advance

    def __post_init__(self):
        assert self.kind in FED_OP_KINDS, self.kind


@dataclasses.dataclass(frozen=True)
class FedScenarioConfig:
    seed: int = 0
    num_ops: int = 40
    num_hosts: int = 3
    policy: str = "first_fit"
    #: every fault knob defaults to 0 — a pre-fault config generates a
    #: byte-identical op stream (the sim plane's compatibility rule)
    partition_rate: float = 0.0
    crash_rate: float = 0.0
    handoff_rate: float = 0.0
    migrate_rate: float = 0.0
    autoscale_rate: float = 0.0


def generate_fed_scenario(cfg: FedScenarioConfig) -> tuple:
    """Seeded federation op stream; same config -> identical tuple. The
    validity model is one bit — partitioned or not — because every
    federation op is DEFINED to be clean under partition (typed
    rejection, deferred entry, or aged lease), which is the property
    under test."""
    rng = random.Random(0xFED ^ (cfg.seed * 2654435761 % 2**31))
    hid = lambda i: f"h{i}"                                  # noqa: E731
    ops: list[FedOp] = [FedOp("init")]
    partitioned = False
    while len(ops) < cfg.num_ops:
        if partitioned and rng.random() < 0.35:
            ops.append(FedOp("heal"))
            partitioned = False
            continue
        if (cfg.partition_rate and not partitioned
                and rng.random() < cfg.partition_rate):
            ops.append(FedOp("partition",
                             host=hid(rng.randrange(cfg.num_hosts))))
            partitioned = True
            continue
        if cfg.crash_rate and rng.random() < cfg.crash_rate:
            ops.append(FedOp("host_crash",
                             host=hid(rng.randrange(cfg.num_hosts))))
            continue
        if (cfg.handoff_rate and not partitioned
                and rng.random() < cfg.handoff_rate):
            ops.append(FedOp("handoff"))
            continue
        if cfg.migrate_rate and rng.random() < cfg.migrate_rate:
            s = rng.randrange(cfg.num_hosts)
            d = (s + 1 + rng.randrange(cfg.num_hosts - 1)) % cfg.num_hosts
            ops.append(FedOp("migrate", host=hid(s), dst=hid(d)))
            continue
        if cfg.autoscale_rate and rng.random() < cfg.autoscale_rate:
            ops.append(FedOp("autoscale"))
            continue
        r = rng.random()
        if r < 0.35:
            ops.append(FedOp("submit", n=rng.choice([1, 1, 2, 3])))
        elif r < 0.80:
            ops.append(FedOp("step", steps=rng.randint(1, 3)))
        else:
            ops.append(FedOp("beat", dt=round(rng.uniform(0.2, 1.2), 3)))
    if partitioned:
        ops.append(FedOp("heal"))        # scenarios end quiescent
    return tuple(ops)


def federation_fingerprint(hosts: Sequence[Host],
                           coordinator: Optional[FederationCoordinator]
                           = None) -> str:
    """Deterministic digest of everything federation recovery touches:
    per-host management-plane fingerprints (pool/tenants/records/journal
    resolutions), epoch fences, the serving/frozen request maps, and the
    coordinator's routing ledger. I16 asserts a double ``recover`` over
    any host subset leaves this unchanged."""
    per_host = []
    for h in sorted(hosts, key=lambda h: h.host_id):
        serving, frozen = _serving_map(h)
        per_host.append([h.host_id, state_fingerprint(h.mgr),
                         h.fence_epoch,
                         sorted(serving.items()),
                         sorted(frozen.items())])
    coord = None
    if coordinator is not None:
        coord = [coordinator.node_id, coordinator.epoch,
                 sorted(coordinator.residency.items()),
                 sorted(coordinator.in_doubt)]
    blob = json.dumps([per_host, coord], sort_keys=True, default=str)
    return f"{zlib.crc32(blob.encode()):08x}"


# ---------------------------------------------------------------------------
# cell builder (shared by the scenario runner and the fault matrix)
# ---------------------------------------------------------------------------
def build_fed_cell(seed: int, *, num_hosts: int = 3,
                   policy: str = "first_fit",
                   workdir: str) -> dict:
    """Deterministic small federation: ``num_hosts`` hosts x 2
    ``SimServeTenant`` engines each, over 3 VFs (the third stays detached
    with devices, so replicated snapshots carry a real ``free_vfs`` for
    the autoscale paths), one shared ``VirtualClock`` + ``Fabric``, a
    coordinator with every lease freshly granted."""
    clock = VirtualClock()
    fabric = Fabric()
    hosts = []
    for i in range(num_hosts):
        hid = f"h{i}"
        host = Host(hid, workdir=os.path.join(workdir, hid), clock=clock,
                    num_devices=8, max_vfs=4, policy=policy,
                    lease_ttl=LEASE_TTL, max_load_per_engine=6)
        svs = [SimServeTenant(f"{hid}.sv{j}", seed=seed * 31 + i * 7 + j,
                              clock=clock, placement=policy)
               for j in range(2)]
        host.mgr.init(num_vfs=3, tenants=svs, devices_per_vf=2)
        host.adopt({tn.tid: tn for tn in svs})
        hosts.append(host)
    coord = FederationCoordinator(hosts, clock=clock, fabric=fabric,
                                  policy=policy, lease_ttl=LEASE_TTL,
                                  max_staleness=MAX_STALENESS)
    coord.heartbeat_all()
    return {"clock": clock, "fabric": fabric, "hosts": hosts,
            "coordinator": coord}


# ---------------------------------------------------------------------------
# scenario runner
# ---------------------------------------------------------------------------
class FedRunner:
    """Execute one federation scenario, asserting I1-I15 after every op
    (and I16 on every host_crash). Mirrors ``harness.ScenarioRunner``:
    per-op outcome rows, violations tagged ``seed=<s> op#<i>``."""

    def __init__(self, cfg: FedScenarioConfig,
                 workdir: Optional[str] = None):
        self.cfg = cfg
        self.workdir = workdir
        self.rows: list[dict] = []

    def run(self) -> dict:
        cfg = self.cfg
        wd = self.workdir or tempfile.mkdtemp(prefix="svff_fed_")
        ops = generate_fed_scenario(cfg)
        try:
            cell = build_fed_cell(cfg.seed, num_hosts=cfg.num_hosts,
                                  policy=cfg.policy, workdir=wd)
            self.clock = cell["clock"]
            self.fabric = cell["fabric"]
            self.hosts = cell["hosts"]
            self.coordinator = cell["coordinator"]
            self.old_coordinators: list[FederationCoordinator] = []
            self.autoscaler = Autoscaler(AutoscaleConfig(
                hysteresis=1, cooldown=2,
                max_staleness_s=MAX_STALENESS))
            self.submitted = self.rejected = self.deferred = 0
            for i, op in enumerate(ops):
                try:
                    status = self._apply(op)
                    self._check()
                except InvariantViolation as e:
                    raise InvariantViolation(
                        f"fed scenario seed={cfg.seed} "
                        f"policy={cfg.policy} op#{i} {op.kind}: {e}"
                        ) from e
                self.rows.append({"i": i, "kind": op.kind,
                                  "status": status})
            return {"seed": cfg.seed, "ops": len(ops),
                    "submitted": self.submitted,
                    "rejected": self.rejected,
                    "deferred": self.deferred,
                    "epoch": self.coordinator.epoch,
                    "fingerprint": federation_fingerprint(
                        self.hosts, self.coordinator),
                    "rows": self.rows}
        finally:
            if self.workdir is None:
                shutil.rmtree(wd, ignore_errors=True)

    # ------------------------------------------------------------------
    def _apply(self, op: FedOp) -> str:
        co = self.coordinator
        if op.kind == "init":
            return "ok"
        if op.kind == "submit":
            ok = 0
            for _ in range(op.n):
                try:
                    co.submit(seed=self.cfg.seed * 17 + 5)
                    ok += 1
                    self.submitted += 1
                except (AdmissionError, LeaseExpiredError,
                        FederationError):
                    self.rejected += 1
            return f"admitted {ok}/{op.n}"
        if op.kind == "step":
            for host in self.hosts:
                for tn in host.serve_targets():
                    tn.run_steps(op.steps)
            return "ok"
        if op.kind == "beat":
            self.clock.advance(op.dt)
            beat = co.heartbeat_all()
            return f"renewed {len(beat['renewed'])}"
        if op.kind == "migrate":
            src = next(h for h in self.hosts if h.host_id == op.host)
            rid = None
            for tn in src.serve_targets():
                rid = tn.peek_migratable()
                if rid is not None:
                    break
            if rid is None:
                return "no-op (nothing migratable)"
            from repro.serve.paged import CacheExhausted
            try:
                co.migrate_request(op.host, op.dst, rid)
                return f"moved {rid}"
            except HostUnreachableError:
                self.deferred += 1
                return f"deferred {rid}"
            except (LeaseExpiredError, SplitBrainError, FederationError,
                    AdmissionError, CacheExhausted) as e:
                return f"clean reject ({type(e).__name__})"
        if op.kind == "partition":
            rest = [h.host_id for h in self.hosts
                    if h.host_id != op.host]
            coords = [co.node_id] + [c.node_id
                                     for c in self.old_coordinators]
            self.fabric.partition(coords + rest, [op.host])
            return f"isolated {op.host}"
        if op.kind == "heal":
            self.fabric.heal()
            co.heartbeat_all()
            rec = co.recover()          # resolve deferred + reconcile
            return f"healed (+{len(rec['confirmed'])} confirmed)"
        if op.kind == "host_crash":
            co.recover([op.host])
            fp1 = federation_fingerprint(self.hosts, co)
            co.recover([op.host])
            fp2 = federation_fingerprint(self.hosts, co)
            if fp1 != fp2:
                raise InvariantViolation(
                    f"I16 federation recovery of {op.host} not "
                    f"idempotent: {fp1} != {fp2}")
            return f"recovered {op.host}"
        if op.kind == "handoff":
            self.old_coordinators.append(co)
            self.coordinator = co.handoff()
            return f"epoch {self.coordinator.epoch}"
        if op.kind == "autoscale":
            action = co.plan_autoscale(self.autoscaler)
            if action is not None:
                check_autoscale(action, self.autoscaler.cfg)
                return f"planned {action.kind}"
            return "quiet"
        raise ValueError(f"unknown fed op {op.kind!r}")

    def _check(self) -> None:
        for host in self.hosts:
            check_invariants(host.mgr)
        check_federation(self.hosts,
                         [self.coordinator] + self.old_coordinators)


def run_fed_scenario(cfg: FedScenarioConfig,
                     workdir: Optional[str] = None) -> dict:
    return FedRunner(cfg, workdir=workdir).run()


# ---------------------------------------------------------------------------
# network-fault catalogue (the partition analogue of chaos.CRASH_POINTS)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NetFaultSpec:
    window: str
    outcome: str                    # catalogued recovery semantics
    doc: str


NETWORK_FAULTS: dict[str, NetFaultSpec] = {s.window: s for s in (
    NetFaultSpec("partition_leases", "route_around",
                 "a host is isolated until its lease lapses: routing "
                 "excludes it (LeaseExpiredError on direct ops), traffic "
                 "flows through the survivors, and a heal + heartbeat "
                 "restores it without losing a request"),
    NetFaultSpec("fed_submit_route", "reroute",
                 "partition strikes between host choice and delivery — "
                 "nothing was admitted, the coordinator re-routes the "
                 "SAME rid to the next candidate; exactly one host "
                 "serves it"),
    NetFaultSpec("fed_submit_after_admit", "in_doubt_confirm",
                 "partition eats the admit ACK — the rid is recorded "
                 "in-doubt against its host and never re-routed (I15); "
                 "post-heal reconciliation confirms the single owner"),
    NetFaultSpec("fed_migrate_mid_ship", "defer_rollback",
                 "partition mid-ship, before the remote admit: recovery "
                 "DEFERS the journaled entry (source slot frozen, served "
                 "by nobody); the first post-heal recover rolls it back "
                 "— the request resumes on the source, token-identical"),
    NetFaultSpec("fed_migrate_after_admit", "defer_forward",
                 "partition after the remote admit (in-doubt distributed "
                 "commit): the entry defers with the destination already "
                 "serving; post-heal recover finds the target owns the "
                 "rid and rolls FORWARD — source copy released exactly "
                 "once (the partition-during-migrate regression)"),
    NetFaultSpec("lease_handoff", "fence_stale",
                 "coordinator failover during a partition that isolates "
                 "the OLD coordinator: the successor (epoch+1) fences "
                 "every host; the stale coordinator's admissions are "
                 "rejected (SplitBrainError) even after the heal, and "
                 "epoch-salted rid spaces can never collide"),
    NetFaultSpec("stale_telemetry_autoscale", "suppress",
                 "partition ages every replicated snapshot past the "
                 "staleness bound: the autoscaler plans NOTHING from "
                 "stale evidence (and freezes its streaks); one fresh "
                 "post-heal heartbeat re-enables justified actions"),
)}


def _drain_all(hosts: Sequence[Host], rounds: int = 60) -> None:
    for _ in range(rounds):
        busy = 0
        for host in hosts:
            for tn in host.serve_targets():
                tn.run_steps(1)
                busy += (len(tn.queue)
                         + sum(r is not None for r in tn.active))
        if busy == 0:
            return


def _oracle_check(hosts: Sequence[Host]) -> int:
    """I10 across the federation: every request any engine has emitted
    tokens for matches its no-fault oracle. Returns requests checked."""
    n = 0
    for host in hosts:
        for tn in host.serve_targets():
            for req in getattr(tn, "requests", ()):
                want = SimServeTenant.expected_output(req.seed, req.rid)
                got = list(req.out)
                if req.done and got != want:
                    raise InvariantViolation(
                        f"I10 {host.host_id}/{tn.tid} rid={req.rid}: "
                        f"{got} != oracle {want}")
                if not req.done and got != want[:len(got)]:
                    raise InvariantViolation(
                        f"I10 {host.host_id}/{tn.tid} rid={req.rid}: "
                        f"in-flight prefix {got} diverged from "
                        f"{want[:len(got)]}")
                n += 1
    return n


def _recover_idempotent(cell: dict,
                        host_ids: Optional[Iterable[str]] = None) -> None:
    """Post-heal federation recovery + the I16 assertion: a second
    recover over the same subset is a fingerprint no-op."""
    co = cell["coordinator"]
    co.recover(host_ids)
    fp1 = federation_fingerprint(cell["hosts"], co)
    co.recover(host_ids)
    fp2 = federation_fingerprint(cell["hosts"], co)
    if fp1 != fp2:
        raise InvariantViolation(
            f"I16 federation recovery not idempotent: {fp1} != {fp2}")


def _fed_checks(cell: dict, extra_coords=()) -> None:
    for host in cell["hosts"]:
        check_invariants(host.mgr)
    check_federation(cell["hosts"],
                     [cell["coordinator"], *extra_coords])


def run_network_fault_case(window: str, seed: int,
                           policy: str = "first_fit",
                           workdir: Optional[str] = None) -> dict:
    """One cell of the network-fault matrix: build a 3-host federation,
    drive it into the catalogued window with a one-shot armed partition,
    and assert the catalogued outcome + I15 (during the fault) + I16
    (recovery idempotence after the heal) + I10 (every touched request
    finishes token-identical to its oracle)."""
    spec = NETWORK_FAULTS[window]
    wd = workdir or tempfile.mkdtemp(prefix="svff_netfault_")
    try:
        cell = build_fed_cell(seed, num_hosts=3, policy=policy,
                              workdir=wd)
        clock, fabric = cell["clock"], cell["fabric"]
        hosts, co = cell["hosts"], cell["coordinator"]
        by_id = {h.host_id: h for h in hosts}
        majority = [co.node_id, "h1", "h2"]
        extra_coords: list = []

        if window == "partition_leases":
            r0 = co.submit(seed=seed)
            fabric.partition(majority, ["h0"])
            clock.advance(LEASE_TTL + 0.1)
            co.heartbeat_all()
            if "h0" in co.live_hosts():
                raise InvariantViolation(
                    "isolated h0 still holds a valid lease after TTL")
            try:
                co.migrate_request("h0", "h1")
                raise InvariantViolation(
                    "direct op on lease-lapsed host not rejected")
            except LeaseExpiredError:
                pass
            routed = [co.submit(seed=seed) for _ in range(4)]
            if any(r["host"] == "h0" for r in routed):
                raise InvariantViolation(
                    "routing placed a request on a lease-lapsed host")
            _fed_checks(cell)
            fabric.heal()
            co.heartbeat_all()
            if "h0" not in co.live_hosts():
                raise InvariantViolation("healed h0 did not rejoin")
            if not any(c.host_id == "h0" for c in co._candidates()):
                raise InvariantViolation(
                    "healed h0 not back in the routing candidate set")
            if co.hosts["h0"].owner_engine(r0["rid"]) is None:
                raise InvariantViolation(
                    f"pre-partition request {r0['rid']} lost on h0")

        elif window == "fed_submit_route":
            # first_fit over equal loads picks h0 — cut exactly it at
            # the routing instant; delivery fails pre-admit, the SAME
            # rid re-routes to h1
            fabric.arm("fed_submit_route", majority, ["h0"])
            res = co.submit(seed=seed)
            if fabric.fired != ["fed_submit_route"]:
                raise InvariantViolation(
                    f"window never fired: {fabric.fired}")
            if res["host"] == "h0" or res["in_doubt"]:
                raise InvariantViolation(
                    f"re-route outcome wrong: {res}")
            owners = [h.host_id for h in hosts
                      if h.owner_engine(res["rid"]) is not None]
            if owners != [res["host"]]:
                raise InvariantViolation(
                    f"rid {res['rid']} owned by {owners}, "
                    f"routed to {res['host']}")
            _fed_checks(cell)
            fabric.heal()

        elif window == "fed_submit_after_admit":
            fabric.arm("fed_submit_after_admit", majority, ["h0"])
            res = co.submit(seed=seed)
            if not res["in_doubt"] or res["host"] != "h0":
                raise InvariantViolation(
                    f"ack-loss outcome wrong: {res}")
            owners = [h.host_id for h in hosts
                      if h.owner_engine(res["rid"]) is not None]
            if owners != ["h0"]:
                raise InvariantViolation(
                    f"in-doubt rid {res['rid']} owned by {owners}")
            try:
                co.submit(rid=res["rid"], seed=seed)
                raise InvariantViolation(
                    "in-doubt rid re-admitted (exactly-once broken)")
            except FederationError:
                pass
            _fed_checks(cell)
            fabric.heal()
            rec = co.reconcile()
            if rec["confirmed"] != [res["rid"]] or co.in_doubt:
                raise InvariantViolation(
                    f"reconcile outcome wrong: {rec}, "
                    f"in_doubt={co.in_doubt}")

        elif window in ("fed_migrate_mid_ship", "fed_migrate_after_admit"):
            # admit a small batch and pick the request with the longest
            # oracle (max_new >= 3 exists in any 3 consecutive rids), so
            # it is still mid-decode after one engine step — a request
            # that finishes at prefill is never migratable
            subs = [co.submit(seed=seed) for _ in range(3)]
            res = max(subs, key=lambda r: SimServeTenant.make_max_new(
                seed, r["rid"]))
            src = by_id[res["host"]]
            dst_id = "h1" if res["host"] != "h1" else "h2"
            for tn in src.serve_targets():
                tn.run_steps(1)
            eng = src.owner_engine(res["rid"])
            if eng is None or eng.peek_migratable(res["rid"]) is None:
                raise InvariantViolation(
                    f"setup: rid {res['rid']} not in a decoding slot on "
                    f"{src.host_id}")
            rest = [co.node_id] + [h.host_id for h in hosts
                                   if h.host_id != dst_id]
            fabric.arm(window, rest, [dst_id])
            try:
                co.migrate_request(src.host_id, dst_id, res["rid"])
                raise InvariantViolation(
                    f"window {window} never interrupted the migration")
            except HostUnreachableError:
                pass
            if fabric.fired != [window]:
                raise InvariantViolation(
                    f"window never fired: {fabric.fired}")
            deferred = [e for e in src.mgr.journal.pending()
                        if e["details"].get("deferred_cross_host")]
            if (len(deferred) != 1
                    or deferred[0]["details"].get("rid") != res["rid"]):
                raise InvariantViolation(
                    f"no deferred journal entry for rid {res['rid']}: "
                    f"{deferred}")
            if res["rid"] not in getattr(eng, "_migrating", {}):
                raise InvariantViolation(
                    "source slot not frozen under the deferred entry")
            dst_owns = by_id[dst_id].owner_engine(res["rid"]) is not None
            want_dst = window == "fed_migrate_after_admit"
            if dst_owns != want_dst:
                raise InvariantViolation(
                    f"{window}: destination owns={dst_owns}, "
                    f"catalogue says {want_dst}")
            _fed_checks(cell)               # I15 with the frozen slot
            fabric.heal()
            _recover_idempotent(cell, [src.host_id])
            owner = dst_id if want_dst else src.host_id
            owners = [h.host_id for h in hosts
                      if h.owner_engine(res["rid"]) is not None]
            if owners != [owner]:
                raise InvariantViolation(
                    f"post-heal owner {owners}, catalogue says "
                    f"[{owner}] ({spec.outcome})")
            if src.mgr.journal.pending():
                raise InvariantViolation(
                    "deferred entry survived the post-heal recover")
            if getattr(eng, "_migrating", None):
                raise InvariantViolation(
                    f"frozen slot survived recovery: {eng._migrating}")

        elif window == "lease_handoff":
            r_old = co.submit(seed=seed)
            # isolate the OLD coordinator; its successor lives with the
            # hosts (failover happens on the majority side)
            succ_id = f"fed{co.epoch + 1}"
            fabric.partition([co.node_id],
                             [succ_id] + [h.host_id for h in hosts])
            succ = co.handoff(succ_id)
            extra_coords.append(co)
            cell["coordinator"] = succ
            if any(h.fence_epoch != succ.epoch for h in hosts):
                raise InvariantViolation(
                    f"successor did not fence every host: "
                    f"{[(h.host_id, h.fence_epoch) for h in hosts]}")
            try:
                co.submit(seed=seed)
                raise InvariantViolation(
                    "isolated stale coordinator still admitted")
            except (AdmissionError, HostUnreachableError,
                    LeaseExpiredError):
                pass
            fabric.heal()
            clock.advance(0.1)
            try:
                co.submit(seed=seed)
                raise InvariantViolation(
                    "fenced stale coordinator admitted after heal")
            except (AdmissionError, SplitBrainError):
                pass
            r_new = succ.submit(seed=seed)
            if r_new["rid"] == r_old["rid"]:
                raise InvariantViolation(
                    "epoch-salted rid spaces collided across handoff")
            _fed_checks(cell, extra_coords)

        elif window == "stale_telemetry_autoscale":
            scaler = Autoscaler(AutoscaleConfig(
                hysteresis=1, cooldown=0,
                max_staleness_s=MAX_STALENESS))
            for _ in range(10):              # make h0's engines hot
                try:
                    co.submit(seed=seed)
                except AdmissionError:
                    break
            fabric.partition([co.node_id], [h.host_id for h in hosts])
            clock.advance(MAX_STALENESS + 0.5)
            stale = co.plan_autoscale(scaler)
            if stale is not None:
                raise InvariantViolation(
                    f"autoscale acted on stale telemetry: {stale}")
            snap = co.fleet_snapshot()
            if snap.age_s <= MAX_STALENESS:
                raise InvariantViolation(
                    f"stale snapshot age {snap.age_s} not past the "
                    f"bound {MAX_STALENESS}")
            fabric.heal()
            co.heartbeat_all()
            fresh = co.plan_autoscale(scaler)
            if fresh is not None:
                check_autoscale(fresh, scaler.cfg)   # I11 on fresh action
            if co.fleet_snapshot().age_s > MAX_STALENESS:
                raise InvariantViolation(
                    "post-heal snapshot still stale after heartbeat")

        else:
            raise ValueError(f"unknown network fault window {window!r}")

        # common epilogue: the federation quiesces clean — every touched
        # request completes token-identical to its oracle (I10), all
        # invariants green, recovery idempotent (I16)
        co = cell["coordinator"]
        co.heartbeat_all()
        _recover_idempotent(cell)
        _drain_all(hosts)
        checked = _oracle_check(hosts)
        _fed_checks(cell, extra_coords)
        return {"window": window, "seed": seed, "policy": policy,
                "outcome": spec.outcome, "oracle_checked": checked,
                "ok": True}
    except InvariantViolation as e:
        raise InvariantViolation(
            f"network fault window={window} seed={seed} "
            f"policy={policy}: {e}") from e
    finally:
        if workdir is None:
            shutil.rmtree(wd, ignore_errors=True)


def network_fault_matrix(windows: Optional[Iterable[str]] = None,
                         seeds: Sequence[int] = tuple(range(10)),
                         policies: Sequence[str] = ("first_fit",),
                         raise_on_fail: bool = True) -> dict:
    """The network-fault matrix: windows x seeds x policies (the
    partition analogue of ``chaos.crash_matrix``); the CI chaos job runs
    a subset and ``benchmarks/federation.py`` gates on the full sweep."""
    windows = list(windows) if windows is not None else \
        list(NETWORK_FAULTS)
    cases, failures = [], []
    for window in windows:
        for policy in policies:
            for seed in seeds:
                try:
                    cases.append(run_network_fault_case(window, seed,
                                                        policy))
                except Exception as e:
                    if raise_on_fail:
                        raise
                    failures.append({"window": window, "seed": seed,
                                     "policy": policy, "error": repr(e)})
    return {"cases": cases, "failures": failures,
            "summary": {"windows": len(windows),
                        "seeds": len(list(seeds)),
                        "policies": list(policies),
                        "num_cases": len(cases) + len(failures),
                        "num_failures": len(failures)}}


__all__ = ["FED_OP_KINDS", "FedOp", "FedRunner", "FedScenarioConfig",
           "NETWORK_FAULTS", "NetFaultSpec", "build_fed_cell",
           "federation_fingerprint", "generate_fed_scenario",
           "network_fault_matrix", "run_fed_scenario",
           "run_network_fault_case"]
