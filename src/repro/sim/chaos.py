"""Chaos harness — crash-inject the manager, recover it, prove invariants.

The paper's claim is that reconfiguration is transparent to guests; this
module sharpens it to *crash-transparent*: the management plane may die at
any of the named crash windows below and ``SVFFManager.recover`` must
rebuild an invariant-clean manager from what survives (journal + records
on disk, the device pool, the guests, the host-RAM snapshot table).

``CRASH_POINTS`` is the catalogue. Each spec names the ops that can reach
the window (``triggers``) and the recovery semantics the window commits
the stack to:

  outcome="none"      the op's destructive step had not run — recovery
                      rolls it BACK; guest state is as if the op was
                      never issued
  outcome="complete"  the destructive step ran (suspend / unbind / VF
                      re-attach) — recovery rolls it FORWARD; guest state
                      is as if the op fully succeeded

``run_crash_case(point, seed, policy)`` is the unit of the crash matrix:
build a deterministic small system, drive it to where the trigger op is
legal, arm the crash plane, catch the ``InjectedCrash``, recover — then
assert invariants I1-I8, recovery idempotence (I9: a second ``recover``
is a bit-identical no-op), the cataloged outcome, and post-recovery
liveness (the survivors still pause/unpause/step with bit-identical
state). ``crash_matrix`` sweeps points x seeds x policies; the CI chaos
job runs it and ``benchmarks/crash_matrix.py`` writes the JSON artifact.
"""
from __future__ import annotations

import dataclasses
import json
import shutil
import tempfile
import zlib
from typing import Iterable, Optional, Sequence

from repro.core.fault import InjectedCrash, crash_plane
from repro.core.journal import COMPLETED_STATUS as _COMPLETED_STATUS
from repro.core.manager import SVFFManager
from repro.core.pool import DevicePool
from repro.core.qmp import ControlPlane
from repro.core.staging import StagingEngine
from repro.sim.clock import VirtualClock
from repro.sim.invariants import InvariantViolation, check_invariants
from repro.sim.tenant import (SimPipelineTenant, SimServeTenant,
                              SimTenant)


@dataclasses.dataclass(frozen=True)
class CrashSpec:
    point: str
    triggers: tuple                 # op kinds that can reach this window
    outcome: str                    # "none" (rollback) | "complete"
    doc: str


CRASH_POINTS: dict[str, CrashSpec] = {s.point: s for s in (
    CrashSpec("mid_record_write", ("attach",), "complete",
              "record .part staged but not renamed; bind already done"),
    CrashSpec("after_record_write", ("attach",), "complete",
              "record durable, WAL commit lost"),
    CrashSpec("mid_pipeline_chunk", ("pause", "pause_live", "detach"),
              "none",
              "staging descriptors partly across the link; snapshot "
              "unpublished, memo untouched (transactional save)"),
    CrashSpec("mid_precopy_round", ("pause_live",), "none",
              "a pre-copy round landed in the memo; guest untouched"),
    CrashSpec("after_snapshot_register", ("pause", "pause_live"), "none",
              "snapshot in host RAM, guest not yet suspended"),
    CrashSpec("after_suspend", ("pause", "pause_live"), "complete",
              "guest suspended; snapshot is the only state copy"),
    CrashSpec("after_detach_snapshot", ("detach",), "none",
              "disk snapshot written, guest still bound"),
    CrashSpec("after_unbind", ("detach",), "complete",
              "guest unbound, attach record still on disk"),
    CrashSpec("before_unpause_restore", ("unpause",), "none",
              "devices re-allocated, nothing restored"),
    CrashSpec("after_unpause_restore", ("unpause",), "complete",
              "VF re-attached, guest not yet resumed"),
    CrashSpec("qmp_timeout", ("qmp",), "none",
              "command applied, monitor died before the response"),
    # -- request-granular live migration (PR 7). outcome names the
    # MIGRATION's fate: "none" == the request stays on the source (roll
    # back), "complete" == it resumes on the target (roll forward). The
    # source tenant's status is "running" either way (that is what
    # COMPLETED_STATUS["migrate_request"] encodes for I8).
    CrashSpec("migrate_mid_extract", ("migrate_request",), "none",
              "chain gathered host-side, source slot frozen; nothing "
              "destructive has run"),
    CrashSpec("migrate_mid_ship", ("migrate_request",), "none",
              "KV block descriptors mid-pipeline; target untouched"),
    CrashSpec("migrate_after_target_admit", ("migrate_request",),
              "complete",
              "target admitted the request (owns pages + slot); source "
              "still frozen — recovery releases the source copy"),
    CrashSpec("migrate_before_source_free", ("migrate_request",),
              "complete",
              "last instant before the only destructive step; same "
              "target-owns predicate rolls forward"),
    # -- elastic pipeline gangs (PR 9). outcome names the GANG OP's
    # fate. attach_group "none" is the one rollback whose victim does
    # NOT return to its pre-op status: the lead was attached (and its
    # state recorded) before the window, so rolling the gang back
    # detaches it — the lead ends "detached" with state parked on disk,
    # re-attachable as a whole gang. reshape outcomes are asserted on
    # ``stage_width`` (+ I14), since the lead stays "running" either way
    # (COMPLETED_STATUS["reshape"]).
    CrashSpec("gang_mid_member", ("attach_group",), "none",
              "lead attached and journaled, first shell mid-attach; "
              "recovery detaches the running members and parks the "
              "gang (lead ends detached, not created)"),
    CrashSpec("gang_before_commit", ("attach_group",), "complete",
              "every member running, gang WAL commit lost; recovery "
              "rolls the whole gang forward"),
    CrashSpec("reshape_mid_members", ("reshape",), "none",
              "reshape journaled, no member touched yet; recovery "
              "restores the old width exactly (grow and shrink alike)"),
    CrashSpec("reshape_before_commit", ("reshape",), "complete",
              "members attached/detached to the new width, commit "
              "lost; recovery re-applies the new template"),
)}


def state_fingerprint(mgr: SVFFManager) -> str:
    """Deterministic digest of everything recovery reconstructs: pool,
    tenants, snapshot table, records, journal entry resolutions. Two
    managers with equal fingerprints are management-plane-identical."""
    q = mgr.query()
    blob = json.dumps(
        [q["pool"], q["tenants"],
         sorted((k, v) for k, v in q["paused_snapshots"].items()),
         mgr.records.list(),
         [(e["seq"], e["op"], e["tenant"], e["status"])
          for e in mgr.journal.entries()]],
        sort_keys=True, default=str)
    return f"{zlib.crc32(blob.encode()):08x}"


def recover_manager(mgr: SVFFManager, tenants: dict, *,
                    policy: Optional[str] = None,
                    workdir: Optional[str] = None,
                    num_queues: int = 2,
                    check_idempotent: bool = True) -> SVFFManager:
    """Standard post-crash sequence: ``SVFFManager.recover`` from the dead
    manager's survivable pieces, then (I9) assert a second recovery is a
    bit-identical no-op."""
    kw = dict(tenants=tenants, workdir=workdir or mgr.workdir,
              scheduler=policy, pause_enabled=mgr.pause_enabled)
    new = SVFFManager.recover(mgr.journal, mgr.pool, mgr.records,
                              StagingEngine(num_queues=num_queues),
                              snapshots=mgr.snapshots, **kw)
    if check_idempotent:
        fp1 = state_fingerprint(new)
        again = SVFFManager.recover(new.journal, new.pool, new.records,
                                    StagingEngine(num_queues=num_queues),
                                    snapshots=new.snapshots, **kw)
        fp2 = state_fingerprint(again)
        if fp1 != fp2:
            raise InvariantViolation(
                f"I9 recovery not idempotent: {fp1} != {fp2}")
        new = again
    return new


def _fire(mgr: SVFFManager, trigger: str, point: str,
          victim: Optional[SimTenant]) -> int:
    """Arm ``point``, run ``trigger``, and require the injected crash.
    Returns how many live-pause background steps the victim took before
    the crash (they count toward its expected step total)."""
    stepped = [0]
    crash_plane.arm(point)
    try:
        if trigger == "attach":
            mgr.attach(victim)
        elif trigger == "pause":
            mgr.pause(victim)
        elif trigger == "pause_live":
            def _live_step():
                victim.run_steps(1)
                stepped[0] += 1
            mgr.pause_live(victim, rounds=2, step_fn=_live_step)
        elif trigger == "detach":
            mgr.detach(victim)
        elif trigger == "unpause":
            mgr.unpause(victim)
        elif trigger == "qmp":
            ControlPlane(mgr).execute({"execute": "query-status"})
        elif trigger == "migrate_request":
            dst = next(tn for tid, tn in sorted(mgr.tenants.items())
                       if tn is not victim
                       and getattr(tn, "status", None) == "running"
                       and hasattr(tn, "admit_migrated"))
            mgr.migrate_request(victim, dst)
        elif trigger == "attach_group":
            mgr.attach_group(victim)
        elif trigger == "reshape":
            # the target width is staged on the lead by run_crash_case
            mgr.reshape(victim, victim._crash_reshape_k)
        else:
            raise ValueError(f"unknown crash trigger {trigger!r}")
        raise InvariantViolation(
            f"crash point {point!r} never fired during {trigger!r}")
    except InjectedCrash:
        pass
    finally:
        crash_plane.disarm()
    return stepped[0]


def run_crash_case(point: str, seed: int, policy: str = "first_fit",
                   workdir: Optional[str] = None) -> dict:
    """One crash-matrix cell. Raises ``InvariantViolation`` (tagged with
    point/seed/policy) on any recovery failure; returns a result row."""
    spec = CRASH_POINTS[point]
    trigger = spec.triggers[seed % len(spec.triggers)]
    wd = workdir or tempfile.mkdtemp(prefix="svff_chaos_")
    clock = VirtualClock()
    try:
        pool = DevicePool(devices=tuple(f"chaosdev{i}" for i in range(8)),
                          max_vfs=4)
        mgr = SVFFManager(pool, workdir=wd,
                          staging=StagingEngine(num_queues=2),
                          scheduler=policy)
        tenants: dict[str, SimTenant] = {}

        def make(tid: str, s: int) -> SimTenant:
            tenants[tid] = SimTenant(tid, seed=s, clock=clock,
                                     placement=policy)
            return tenants[tid]

        bystander = make("vm0", seed * 13 + 1)
        mig_rid = target = None
        if trigger == "migrate_request":
            # serve-shaped cell: sv0 decodes a request mid-flight, sv1 is
            # the (idle, capacious) migration target
            victim = SimServeTenant("sv0", seed=seed * 13 + 2,
                                    clock=clock, placement=policy)
            target = SimServeTenant("sv1", seed=seed * 13 + 3,
                                    clock=clock, placement=policy)
            tenants[victim.tid], tenants[target.tid] = victim, target
            mgr.init(num_vfs=4, tenants=[bystander, victim, target],
                     devices_per_vf=2)
            bystander.run_steps(1 + seed % 3)
            victim.submit_burst(3)
            for _ in range(6):               # drive to a decoding slot
                victim.run_steps(1)
                if victim.peek_migratable() is not None:
                    break
            mig_rid = victim.peek_migratable()
            if mig_rid is None:
                raise InvariantViolation(
                    "setup: sv0 never reached an in-flight request")
        elif trigger in ("attach_group", "reshape"):
            # gang-shaped cell: pg0 is a pipeline lead with shells up to
            # width 3, vm0 the bystander. 8 devices / 4 VFs at 2 devices
            # each: bystander + lead + one shell = 3 VFs, leaving one
            # free so the grow direction of reshape is placeable.
            victim = SimPipelineTenant("pg0", seed=seed * 13 + 2,
                                       clock=clock, placement=policy,
                                       width=2, max_width=3)
            tenants[victim.tid] = victim
            for sh in victim.gang_shells:
                tenants[sh.tid] = sh
            mgr.init(num_vfs=4, tenants=[bystander], devices_per_vf=2)
            bystander.run_steps(1 + seed % 3)
            if trigger == "reshape":
                # the gang must already be live, with traffic in flight
                # so I10 is checked ACROSS the crashed width change
                mgr.attach_group(victim)
                victim.submit_burst(2)
                victim.run_steps(2)
                victim._crash_reshape_k = 1 if seed % 2 else 3
        else:
            other = make("vm1", seed * 13 + 2)
            mgr.init(num_vfs=3, tenants=[bystander, other],
                     devices_per_vf=2)
            bystander.run_steps(1 + seed % 3)
            other.run_steps(1 + (seed // 3) % 3)
            if trigger == "unpause":
                mgr.pause(other)
                victim = other
            elif trigger == "attach":
                victim = make("vm2", seed * 13 + 3)
            else:
                victim = other
        check_invariants(mgr)
        pre_status = victim.status
        pre_steps = {tid: tn.steps_done for tid, tn in tenants.items()}

        stepped = _fire(mgr, trigger, point, victim)

        # the manager process is gone; rebuild from the survivors
        mgr = recover_manager(mgr, tenants, policy=policy, workdir=wd)
        check_invariants(mgr)                       # I1-I8 (incl. I4 bits)

        # the cataloged outcome: rolled back == never issued,
        # rolled forward == fully applied
        want = (pre_status if spec.outcome == "none"
                else _COMPLETED_STATUS[trigger])
        if trigger == "qmp":
            want = pre_status
        if trigger == "attach_group" and spec.outcome == "none":
            # the one rollback that does not restore the pre-op status:
            # the lead was attached before the window, so rolling the
            # gang back detaches it (state parked on disk, catalogued)
            want = "detached"
        if victim.status != want:
            raise InvariantViolation(
                f"outcome: {trigger} + {point} left {victim.tid} "
                f"{victim.status!r}, catalogue says {want!r}")
        for tid, steps in pre_steps.items():
            add = stepped if tid == victim.tid else 0
            if tenants[tid].steps_done != steps + add:
                raise InvariantViolation(
                    f"step counter drift for {tid} across crash+recover: "
                    f"{tenants[tid].steps_done} != {steps + add}")

        if trigger in ("attach_group", "reshape"):
            # I14 sharpened per-cell: the recovered gang is at exactly
            # the cataloged width with exactly width-1 running shells —
            # a half-attached gang or half-applied reshape fails here
            # even before check_invariants would catch it
            live = [sh.tid for sh in victim.gang_shells
                    if sh.status == "running"]
            if trigger == "attach_group" and spec.outcome == "none":
                if live:
                    raise InvariantViolation(
                        f"gang rollback after {point} left shells "
                        f"running: {live}")
            else:
                want_k = (victim._crash_reshape_k
                          if trigger == "reshape"
                          and spec.outcome == "complete" else 2)
                if victim.stage_width != want_k:
                    raise InvariantViolation(
                        f"gang outcome: width {victim.stage_width} != "
                        f"cataloged {want_k} after {point} recovery")
                if len(live) != want_k - 1:
                    raise InvariantViolation(
                        f"gang outcome: {len(live)} running shells "
                        f"{live} after {point} recovery, want "
                        f"{want_k - 1}")

        if trigger == "migrate_request":
            # I13 sharpened per-cell: the request survives on exactly the
            # cataloged side, no slot stays frozen, and driving both
            # engines to completion yields the no-migration oracle token
            # stream (extended I10 — zero in-flight work lost)
            owner, loser = ((target, victim) if spec.outcome == "complete"
                            else (victim, target))
            if not owner.owns_request(mig_rid):
                raise InvariantViolation(
                    f"migration outcome: {owner.tid} should own request "
                    f"{mig_rid} after {point} recovery, but does not")
            if loser.owns_request(mig_rid):
                raise InvariantViolation(
                    f"migration outcome: request {mig_rid} live on BOTH "
                    f"engines after {point} recovery")
            if victim._migrating:
                raise InvariantViolation(
                    f"frozen slot survived recovery: {victim._migrating}")
            req = next(r for r in victim.requests if r.rid == mig_rid)
            for _ in range(40):
                victim.run_steps(1)
                target.run_steps(1)
                if req.done:
                    break
            if not req.done:
                raise InvariantViolation(
                    f"request {mig_rid} stranded after {point} recovery")
            oracle = SimServeTenant.expected_output(req.seed, req.rid)
            if req.out != oracle:
                raise InvariantViolation(
                    f"I10 after migration crash: request {mig_rid} "
                    f"emitted {req.out}, oracle {oracle}")
            check_invariants(mgr)

        # post-recovery liveness: survivors still reconfigure and step
        # with bit-identical state
        if victim.status == "paused":
            mgr.unpause(victim)
        elif victim.status == "detached":
            if getattr(victim, "gang_shells", None):
                mgr.attach_group(victim)    # a parked gang re-attaches whole
            else:
                mgr.attach(victim)
        if victim.status == "running":
            victim.run_steps(1)
        mgr.pause(bystander)
        mgr.unpause(bystander)
        bystander.run_steps(1)
        check_invariants(mgr)
        return {"point": point, "trigger": trigger, "seed": seed,
                "policy": policy, "outcome": spec.outcome, "ok": True}
    except InvariantViolation as e:
        raise InvariantViolation(
            f"crash case point={point} seed={seed} policy={policy} "
            f"trigger={trigger}: {e}") from e
    finally:
        crash_plane.disarm()
        if workdir is None:
            shutil.rmtree(wd, ignore_errors=True)


def crash_matrix(points: Optional[Iterable[str]] = None,
                 seeds: Sequence[int] = tuple(range(20)),
                 policies: Sequence[str] = ("first_fit", "best_fit",
                                            "fair_share"),
                 raise_on_fail: bool = True) -> dict:
    """The full crash matrix: points x seeds x policies. Returns the
    result table the CI chaos job uploads (see EXPERIMENTS.md §Chaos)."""
    points = list(points) if points is not None else list(CRASH_POINTS)
    cases, failures = [], []
    for point in points:
        for policy in policies:
            for seed in seeds:
                try:
                    cases.append(run_crash_case(point, seed, policy))
                except Exception as e:
                    # not only InvariantViolation: a red cell that dies
                    # with e.g. a recovery RuntimeError must still land
                    # in failures[] so the matrix artifact reports it
                    # instead of aborting the whole sweep
                    if raise_on_fail:
                        raise
                    failures.append({"point": point, "seed": seed,
                                     "policy": policy, "error": repr(e)})
    return {"cases": cases, "failures": failures,
            "summary": {"points": len(points),
                        "seeds": len(list(seeds)),
                        "policies": list(policies),
                        "num_cases": len(cases) + len(failures),
                        "num_failures": len(failures)}}
