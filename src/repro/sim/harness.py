"""ScenarioRunner — executes a generated scenario against the real stack.

One runner = one scenario = one fresh ``DevicePool`` (simulated device
tokens), ``SVFFManager`` (with the configured placement policy), real
``StagingEngine`` / ``RecordStore`` / ``CheckpointStore`` on a throwaway
workdir, and ``SimTenant``s. After EVERY op — successful or rejected —
``check_invariants`` runs; any violation raises ``InvariantViolation``
tagged ``seed=<s> op#<i>``, which reproduces the failure exactly:

    ScenarioRunner(ScenarioConfig(seed=<s>, policy=<p>)).run()

Expected rejections (admission failures, illegal transitions, I/O on a
paused device, ...) are recorded per-op — never exceptions — because the
property under test is that a rejected op is ATOMIC: the system state it
leaves behind still satisfies every invariant.
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
import time
import zlib
from typing import Optional

from repro.core.autoscaler import (Autoscaler, AutoscaleConfig,
                                   EngineStats, TelemetrySnapshot)
from repro.core import ManagerError, SVFFManager
from repro.core.fault import Supervisor
from repro.core.pool import DevicePool, PoolError
from repro.core.pause import PauseError
from repro.core.records import RecordError
from repro.core.staging import StagingEngine
from repro.core.tenant import DevicePausedError
from repro.core.vf import VFState, VFTransitionError
from repro.serve.paged import CacheExhausted
from repro.sim.chaos import _fire, recover_manager
from repro.sim.clock import VirtualClock
from repro.sim.invariants import (InvariantViolation, check_autoscale,
                                  check_invariants, check_pause_timings,
                                  check_timings)
from repro.sim.scenario import Op, ScenarioConfig, generate_scenario
from repro.sim.tenant import (SimPipelineTenant, SimServeTenant,
                              SimTenant)

#: exception types an op may legally be rejected with (atomically).
#: All TYPED: a blanket KeyError here once masked real bugs (e.g. a
#: missing-snapshot lookup) as "expected rejections" — the manager now
#: raises ManagerError/UnknownTenantError for those paths instead.
REJECTIONS = (PoolError, PauseError, VFTransitionError, DevicePausedError,
              RecordError, ManagerError)


@dataclasses.dataclass
class OpResult:
    op: Op
    status: str                 # ok | rejected
    error: Optional[str] = None
    virtual_t: float = 0.0


@dataclasses.dataclass
class ScenarioResult:
    config: ScenarioConfig
    ops: list[OpResult]
    reconf_timings: list[dict]
    wall_seconds: float
    virtual_seconds: float
    final: dict

    @property
    def num_ok(self) -> int:
        return sum(1 for r in self.ops if r.status == "ok")

    @property
    def num_rejected(self) -> int:
        return sum(1 for r in self.ops if r.status == "rejected")

    def fingerprint(self) -> str:
        """Digest of the full outcome — equal across replays of a seed."""
        parts = []
        for r in self.ops:
            tag = f"{r.op.kind}:{r.op.tenant}:{r.status}"
            if r.op.point:
                tag += f":{r.op.point}"
            parts.append(tag)
        for tid in sorted(self.final["tenants"]):
            q = self.final["tenants"][tid]
            parts.append(f"{tid}={q['status']}@{q['steps_done']}")
        return f"{zlib.crc32('|'.join(parts).encode()):08x}"


#: policy-loop sizing for sim serving tenants (SimServeTenant has 2 slots
#: and bursts of up to ~12, so hot = load >= ceil(0.75 * 6) = 5)
SIM_SLO_MAX_LOAD = 6
#: sv0 is the scenario's fixed traffic ingress (serve_submit/serve_step
#: target it by name), so it is pinned against scale_in
SIM_AUTOSCALE = AutoscaleConfig(hysteresis=1, cooldown=1,
                                rebalance_gap=4, max_engines=4,
                                pinned=("sv0",))


class ScenarioRunner:
    def __init__(self, cfg: ScenarioConfig, workdir: Optional[str] = None):
        self.cfg = cfg
        self.workdir = workdir
        self.clock = VirtualClock()
        self.mgr: Optional[SVFFManager] = None
        self.sup: Optional[Supervisor] = None
        self.tenants: dict[str, SimTenant] = {}
        self.expected_steps: dict[str, int] = {}
        autocfg = SIM_AUTOSCALE
        if cfg.migrate_rate > 0:
            # with migration traffic the scenario attaches sv1 as a
            # fixed migration target — pin it like sv0 so the
            # autoscaler can't scale it in under the generator's
            # validity model (which schedules ops against sv1)
            autocfg = dataclasses.replace(SIM_AUTOSCALE,
                                          pinned=("sv0", "sv1"))
        self.autoscaler = Autoscaler(autocfg)
        self._as_epoch = 0
        self._last_autoscale = None       # pending I11 check

    # ----------------------------------------------------------------- ops
    def _tenant(self, tid: str) -> SimTenant:
        if tid not in self.tenants:
            if tid.startswith("pg"):
                # pipeline gang lead: a serving tenant that spans up to
                # max_width VFs; its shell members register alongside it
                # so crash recovery and the step-counter check see them
                lead = SimPipelineTenant(
                    tid, seed=self.cfg.seed, clock=self.clock,
                    placement=self.cfg.policy,
                    leaf_size=self.cfg.leaf_size)
                self.tenants[tid] = lead
                for sh in lead.gang_shells:
                    self.tenants[sh.tid] = sh
                    self.expected_steps[sh.tid] = 0
            elif tid.startswith("sv"):
                # serving tenants: paged toy engine, I10-checked outputs
                self.tenants[tid] = SimServeTenant(
                    tid, seed=self.cfg.seed, clock=self.clock,
                    placement=self.cfg.policy)
            else:
                self.tenants[tid] = SimTenant(
                    tid, seed=self.cfg.seed * 1009 + len(self.tenants),
                    leaf_size=self.cfg.leaf_size, clock=self.clock,
                    placement=self.cfg.policy)
            self.expected_steps[tid] = 0
        return self.tenants[tid]

    def _apply(self, op: Op) -> Optional[dict]:
        mgr, clock = self.mgr, self.clock
        if op.kind == "init":
            devices = tuple(f"simdev{i}"
                            for i in range(self.cfg.num_devices))
            pool = DevicePool(devices=devices, max_vfs=self.cfg.max_vfs)
            self.mgr = SVFFManager(pool, workdir=self._wd,
                                   staging=StagingEngine(num_queues=2),
                                   scheduler=self.cfg.policy)
            self.sup = Supervisor(self.mgr, clock=self.clock.now)
            tns = [self._tenant(f"vm{i}") for i in range(op.num_tenants)]
            self.mgr.init(op.num_vfs, tns,
                          devices_per_vf=op.devices_per_vf)
            clock.advance(0.05)                 # rescan + partition cost
            return None
        assert mgr is not None, "scenario must start with init"
        if op.kind == "attach":
            tn = self._tenant(op.tenant)
            if getattr(tn, "gang_shells", None):
                mgr.attach_group(tn)     # lead + shells, atomically
            else:
                mgr.attach(tn)
        elif op.kind == "reshape":
            # journaled gang width change: attach/detach shell members
            # to reach op.num_vfs stages, then apply the template
            mgr.reshape(self._tenant(op.tenant), op.num_vfs)
            clock.advance(0.02)
        elif op.kind == "detach":
            mgr.detach(self._tenant(op.tenant))
            clock.advance(0.02)
        elif op.kind == "pause":
            t = mgr.pause(self._tenant(op.tenant))
            check_pause_timings(t, live=False)
            clock.advance(0.01)
        elif op.kind == "pause_live":
            tn = self._tenant(op.tenant)
            stepped = [0]

            def _live_step():
                # the tenant keeps working between pre-copy rounds — the
                # whole point of the live path (invariant I4 then proves
                # those steps survive the pause bit-exactly)
                tn.run_steps(1)
                stepped[0] += 1
            t = mgr.pause_live(tn, rounds=2, step_fn=_live_step)
            self.expected_steps[op.tenant] += stepped[0]
            check_pause_timings(t, live=True)
            clock.advance(0.01)
        elif op.kind == "unpause":
            mgr.unpause(self._tenant(op.tenant))
            clock.advance(0.01)
        elif op.kind == "reconf":
            timings = mgr.reconf(op.num_vfs,
                                 devices_per_vf=op.devices_per_vf)
            check_timings(timings)
            clock.advance(0.05)
            return timings
        elif op.kind == "migrate":
            mgr.migrate(self._tenant(op.tenant))
            clock.advance(0.02)
        elif op.kind == "fault":
            tn = self._tenant(op.tenant)
            tn.inject_failure()
            pre_running = {t for t, tn2 in self.tenants.items()
                           if tn2.status == "running" and t in mgr.tenants}
            self.sup.run_round(1)
            # every healthy running tenant advanced one step; the faulted
            # one raised before stepping and was migrated with its state
            for t in pre_running:
                if t != op.tenant:
                    self.expected_steps[t] += 1
            kinds = [e["kind"] for e in self.sup.events[-2:]]
            if kinds != ["failure", "migrated"]:
                raise InvariantViolation(
                    f"fault on {op.tenant} not recovered: {kinds}")
        elif op.kind == "step":
            self._tenant(op.tenant).run_steps(op.steps)
            self.expected_steps[op.tenant] += op.steps
        elif op.kind == "serve_submit":
            # guest-side queueing — legal even while the engine is paused
            self._tenant(op.tenant).submit_burst(op.burst)
        elif op.kind == "serve_step":
            # the named tenant first — preserving the rejection behaviour
            # when it is paused — then every other running serving tenant
            # (autoscaled engines share the drive loop)
            self._tenant(op.tenant).run_steps(op.steps)
            self.expected_steps[op.tenant] += op.steps
            for tid in sorted(self.tenants):
                tn = self.tenants[tid]
                if (tid != op.tenant and tid.startswith("sv")
                        and tn.status == "running"):
                    tn.run_steps(op.steps)
                    self.expected_steps[tid] += op.steps
        elif op.kind == "autoscale":
            self._autoscale_step()
            clock.advance(0.005)
        elif op.kind == "migrate_request":
            # deterministic pair pick among the running serving engines:
            # source = first (sorted) one with a migratable in-flight
            # request, target = first other running one. No such pair is
            # a no-op — the op is about what happens WHEN a migration
            # runs, not about manufacturing one — and a target-side
            # CacheExhausted is a clean journaled abort (the source
            # keeps serving, invariant I13 still checked after the op).
            svs = [tn for tn in self._serve_tenants()
                   if tn.status == "running"]
            src = next((tn for tn in svs
                        if tn.peek_migratable() is not None), None)
            dst = next((tn for tn in svs
                        if src is not None and tn.tid != src.tid), None)
            if src is not None and dst is not None:
                try:
                    mgr.migrate_request(src, dst)
                except CacheExhausted:
                    pass
                clock.advance(0.01)
        elif op.kind == "crash":
            # kill the manager at the named crash point mid-trigger-op,
            # then rebuild it via SVFFManager.recover (with the I9
            # double-recovery check inside recover_manager)
            victim = self._tenant(op.tenant) if op.tenant else None
            stepped = _fire(mgr, op.trigger, op.point, victim)
            if op.tenant:
                self.expected_steps[op.tenant] += stepped
            self.mgr = recover_manager(mgr, self.tenants,
                                       policy=self.cfg.policy,
                                       workdir=self._wd, num_queues=2)
            self.sup = Supervisor(self.mgr, clock=self.clock.now)
            clock.advance(0.1)              # manager restart + recovery
        else:
            raise ValueError(f"unknown op {op.kind}")
        return None

    # ------------------------------------------------------- elastic plane
    def _serve_tenants(self) -> list:
        return [self.tenants[tid] for tid in sorted(self.tenants)
                if tid.startswith("sv")]

    def _autoscale_snapshot(self) -> TelemetrySnapshot:
        """Telemetry over the serving tenants: load = guest-side queue +
        in-flight slots. ``grow_budget`` is 0 — the sim's executor only
        takes the cheap path (attach to an existing free VF), it never
        runs a grow-reconf, and the planner must know that."""
        self._as_epoch += 1
        stats = []
        for tn in self._serve_tenants():
            queued = len(tn.queue) if tn.queue is not None else 0
            inflight = (sum(r is not None for r in tn.active)
                        if tn.active is not None else 0)
            stats.append(EngineStats(
                tid=tn.tid, index=int(tn.tid[2:] or 0), status=tn.status,
                load=queued + inflight, queue_depth=queued,
                inflight=inflight, prefill_jobs=0))
        pool = self.mgr.pool
        free_vfs = sum(1 for vf in pool.vfs.values()
                       if vf.state == VFState.DETACHED
                       and vf.owner is None and vf.devices)
        return TelemetrySnapshot(
            epoch=self._as_epoch, slo_max_load=SIM_SLO_MAX_LOAD,
            engines=tuple(stats), free_vfs=free_vfs, grow_budget=0)

    def _autoscale_step(self):
        """One policy-loop epoch over the serving tenants. The planned
        action is remembered for the I11 check that runs with the post-op
        invariants (so a violation carries the seed/op# tag), then
        executed through the ordinary journaled manager ops."""
        action = self.autoscaler.observe(self._autoscale_snapshot())
        if action is None:
            return
        self._last_autoscale = (action, self.autoscaler.cfg)
        if action.kind == "scale_out":
            # prefer re-attaching a previously scaled-in tenant (its
            # state restores from the detach snapshot) over minting one
            parked = [tn.tid for tn in self._serve_tenants()
                      if tn.status == "detached"]
            nxt = 1 + max((int(tn.tid[2:] or 0)
                           for tn in self._serve_tenants()), default=0)
            new = self._tenant(parked[0] if parked else f"sv{nxt}")
            self.mgr.attach(new)
            # like the fleet: the fresh engine immediately takes queued
            # work off the hottest serving tenant (queued requests have
            # emitted nothing, so moving them is I10-safe)
            def _load(tn):
                return (len(tn.queue)
                        + sum(r is not None for r in tn.active))
            hot = max((tn for tn in self._serve_tenants()
                       if tn.status == "running" and tn.tid != new.tid),
                      key=_load, default=None)
            while (hot is not None and hot.queue
                   and _load(hot) - _load(new) > 1):
                new.queue.append(hot.queue.pop())
        elif action.kind == "scale_in":
            self.mgr.detach(self.tenants[action.victim])
        else:                                     # rebalance
            src = self.tenants[action.victim]
            dst = self.tenants[action.target]

            def _gap(a, b):
                return (len(a.queue)
                        + sum(r is not None for r in a.active)
                        - len(b.queue)
                        - sum(r is not None for r in b.active))
            while src.queue and _gap(src, dst) > 1:
                dst.queue.append(src.queue.pop())
            # queue-stealing alone can't close the gap when the hot
            # engine's load is IN-FLIGHT: steal live requests through
            # the journaled migration op. CacheExhausted (target KV
            # full) or a manager refusal ends the steal cleanly — the
            # request stays live on the source.
            while (_gap(src, dst) > 1
                   and src.peek_migratable() is not None):
                try:
                    self.mgr.migrate_request(src, dst)
                except (CacheExhausted, ManagerError):
                    break
            self.mgr.migrate(src)

    # ----------------------------------------------------------------- run
    def run(self) -> ScenarioResult:
        from repro.core.scheduler import make_scheduler
        make_scheduler(self.cfg.policy)     # fail fast on a policy typo
        ops = generate_scenario(self.cfg)
        self._wd = self.workdir or tempfile.mkdtemp(prefix="svff_sim_")
        results: list[OpResult] = []
        reconf_timings: list[dict] = []
        t0 = time.perf_counter()
        try:
            for i, op in enumerate(ops):
                try:
                    timings = self._apply(op)
                    if timings is not None:
                        reconf_timings.append(timings)
                    results.append(OpResult(op, "ok",
                                            virtual_t=self.clock.now()))
                    self.clock.stamp("ok", op=op.kind, tenant=op.tenant)
                except REJECTIONS as e:
                    if op.kind == "init":
                        raise    # a scenario with no system is no scenario
                    results.append(OpResult(op, "rejected", error=repr(e),
                                            virtual_t=self.clock.now()))
                    self.clock.stamp("rejected", op=op.kind,
                                     tenant=op.tenant)
                try:
                    check_invariants(self.mgr)
                    self._check_step_counters()
                    if self._last_autoscale is not None:
                        act, cfg = self._last_autoscale
                        self._last_autoscale = None
                        check_autoscale(act, cfg)      # I11
                except InvariantViolation as e:
                    raise InvariantViolation(
                        f"seed={self.cfg.seed} policy={self.cfg.policy} "
                        f"op#{i} {op}: {e}") from e
            final = self.mgr.query()
        finally:
            if self.workdir is None:
                shutil.rmtree(self._wd, ignore_errors=True)
        return ScenarioResult(
            config=self.cfg, ops=results, reconf_timings=reconf_timings,
            wall_seconds=time.perf_counter() - t0,
            virtual_seconds=self.clock.now(), final=final)

    def _check_step_counters(self):
        for tid, want in self.expected_steps.items():
            got = self.tenants[tid].steps_done
            if got != want:
                raise InvariantViolation(
                    f"step counter drift for {tid}: {got} != {want}")


def run_scenario(seed: int, policy: str = "first_fit",
                 **kw) -> ScenarioResult:
    """Convenience: run one seeded scenario, return its result."""
    return ScenarioRunner(ScenarioConfig(seed=seed, policy=policy,
                                         **kw)).run()
