"""ScenarioRunner — executes a generated scenario against the real stack.

One runner = one scenario = one fresh ``DevicePool`` (simulated device
tokens), ``SVFFManager`` (with the configured placement policy), real
``StagingEngine`` / ``RecordStore`` / ``CheckpointStore`` on a throwaway
workdir, and ``SimTenant``s. After EVERY op — successful or rejected —
``check_invariants`` runs; any violation raises ``InvariantViolation``
tagged ``seed=<s> op#<i>``, which reproduces the failure exactly:

    ScenarioRunner(ScenarioConfig(seed=<s>, policy=<p>)).run()

Expected rejections (admission failures, illegal transitions, I/O on a
paused device, ...) are recorded per-op — never exceptions — because the
property under test is that a rejected op is ATOMIC: the system state it
leaves behind still satisfies every invariant.
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
import time
import zlib
from typing import Optional

from repro.core.fault import Supervisor
from repro.core.manager import ManagerError, SVFFManager
from repro.core.pool import DevicePool, PoolError
from repro.core.pause import PauseError
from repro.core.records import RecordError
from repro.core.staging import StagingEngine
from repro.core.tenant import DevicePausedError
from repro.core.vf import VFTransitionError
from repro.sim.chaos import _fire, recover_manager
from repro.sim.clock import VirtualClock
from repro.sim.invariants import (InvariantViolation, check_invariants,
                                  check_pause_timings, check_timings)
from repro.sim.scenario import Op, ScenarioConfig, generate_scenario
from repro.sim.tenant import SimServeTenant, SimTenant

#: exception types an op may legally be rejected with (atomically).
#: All TYPED: a blanket KeyError here once masked real bugs (e.g. a
#: missing-snapshot lookup) as "expected rejections" — the manager now
#: raises ManagerError/UnknownTenantError for those paths instead.
REJECTIONS = (PoolError, PauseError, VFTransitionError, DevicePausedError,
              RecordError, ManagerError)


@dataclasses.dataclass
class OpResult:
    op: Op
    status: str                 # ok | rejected
    error: Optional[str] = None
    virtual_t: float = 0.0


@dataclasses.dataclass
class ScenarioResult:
    config: ScenarioConfig
    ops: list[OpResult]
    reconf_timings: list[dict]
    wall_seconds: float
    virtual_seconds: float
    final: dict

    @property
    def num_ok(self) -> int:
        return sum(1 for r in self.ops if r.status == "ok")

    @property
    def num_rejected(self) -> int:
        return sum(1 for r in self.ops if r.status == "rejected")

    def fingerprint(self) -> str:
        """Digest of the full outcome — equal across replays of a seed."""
        parts = []
        for r in self.ops:
            tag = f"{r.op.kind}:{r.op.tenant}:{r.status}"
            if r.op.point:
                tag += f":{r.op.point}"
            parts.append(tag)
        for tid in sorted(self.final["tenants"]):
            q = self.final["tenants"][tid]
            parts.append(f"{tid}={q['status']}@{q['steps_done']}")
        return f"{zlib.crc32('|'.join(parts).encode()):08x}"


class ScenarioRunner:
    def __init__(self, cfg: ScenarioConfig, workdir: Optional[str] = None):
        self.cfg = cfg
        self.workdir = workdir
        self.clock = VirtualClock()
        self.mgr: Optional[SVFFManager] = None
        self.sup: Optional[Supervisor] = None
        self.tenants: dict[str, SimTenant] = {}
        self.expected_steps: dict[str, int] = {}

    # ----------------------------------------------------------------- ops
    def _tenant(self, tid: str) -> SimTenant:
        if tid not in self.tenants:
            if tid.startswith("sv"):
                # serving tenants: paged toy engine, I10-checked outputs
                self.tenants[tid] = SimServeTenant(
                    tid, seed=self.cfg.seed, clock=self.clock,
                    placement=self.cfg.policy)
            else:
                self.tenants[tid] = SimTenant(
                    tid, seed=self.cfg.seed * 1009 + len(self.tenants),
                    leaf_size=self.cfg.leaf_size, clock=self.clock,
                    placement=self.cfg.policy)
            self.expected_steps[tid] = 0
        return self.tenants[tid]

    def _apply(self, op: Op) -> Optional[dict]:
        mgr, clock = self.mgr, self.clock
        if op.kind == "init":
            devices = tuple(f"simdev{i}"
                            for i in range(self.cfg.num_devices))
            pool = DevicePool(devices=devices, max_vfs=self.cfg.max_vfs)
            self.mgr = SVFFManager(pool, workdir=self._wd,
                                   staging=StagingEngine(num_queues=2),
                                   scheduler=self.cfg.policy)
            self.sup = Supervisor(self.mgr, clock=self.clock.now)
            tns = [self._tenant(f"vm{i}") for i in range(op.num_tenants)]
            self.mgr.init(op.num_vfs, tns,
                          devices_per_vf=op.devices_per_vf)
            clock.advance(0.05)                 # rescan + partition cost
            return None
        assert mgr is not None, "scenario must start with init"
        if op.kind == "attach":
            mgr.attach(self._tenant(op.tenant))
        elif op.kind == "detach":
            mgr.detach(self._tenant(op.tenant))
            clock.advance(0.02)
        elif op.kind == "pause":
            t = mgr.pause(self._tenant(op.tenant))
            check_pause_timings(t, live=False)
            clock.advance(0.01)
        elif op.kind == "pause_live":
            tn = self._tenant(op.tenant)
            stepped = [0]

            def _live_step():
                # the tenant keeps working between pre-copy rounds — the
                # whole point of the live path (invariant I4 then proves
                # those steps survive the pause bit-exactly)
                tn.run_steps(1)
                stepped[0] += 1
            t = mgr.pause_live(tn, rounds=2, step_fn=_live_step)
            self.expected_steps[op.tenant] += stepped[0]
            check_pause_timings(t, live=True)
            clock.advance(0.01)
        elif op.kind == "unpause":
            mgr.unpause(self._tenant(op.tenant))
            clock.advance(0.01)
        elif op.kind == "reconf":
            timings = mgr.reconf(op.num_vfs,
                                 devices_per_vf=op.devices_per_vf)
            check_timings(timings)
            clock.advance(0.05)
            return timings
        elif op.kind == "migrate":
            mgr.migrate(self._tenant(op.tenant))
            clock.advance(0.02)
        elif op.kind == "fault":
            tn = self._tenant(op.tenant)
            tn.inject_failure()
            pre_running = {t for t, tn2 in self.tenants.items()
                           if tn2.status == "running" and t in mgr.tenants}
            self.sup.run_round(1)
            # every healthy running tenant advanced one step; the faulted
            # one raised before stepping and was migrated with its state
            for t in pre_running:
                if t != op.tenant:
                    self.expected_steps[t] += 1
            kinds = [e["kind"] for e in self.sup.events[-2:]]
            if kinds != ["failure", "migrated"]:
                raise InvariantViolation(
                    f"fault on {op.tenant} not recovered: {kinds}")
        elif op.kind == "step":
            self._tenant(op.tenant).run_steps(op.steps)
            self.expected_steps[op.tenant] += op.steps
        elif op.kind == "serve_submit":
            # guest-side queueing — legal even while the engine is paused
            self._tenant(op.tenant).submit_burst(op.burst)
        elif op.kind == "serve_step":
            self._tenant(op.tenant).run_steps(op.steps)
            self.expected_steps[op.tenant] += op.steps
        elif op.kind == "crash":
            # kill the manager at the named crash point mid-trigger-op,
            # then rebuild it via SVFFManager.recover (with the I9
            # double-recovery check inside recover_manager)
            victim = self._tenant(op.tenant) if op.tenant else None
            stepped = _fire(mgr, op.trigger, op.point, victim)
            if op.tenant:
                self.expected_steps[op.tenant] += stepped
            self.mgr = recover_manager(mgr, self.tenants,
                                       policy=self.cfg.policy,
                                       workdir=self._wd, num_queues=2)
            self.sup = Supervisor(self.mgr, clock=self.clock.now)
            clock.advance(0.1)              # manager restart + recovery
        else:
            raise ValueError(f"unknown op {op.kind}")
        return None

    # ----------------------------------------------------------------- run
    def run(self) -> ScenarioResult:
        from repro.core.scheduler import make_scheduler
        make_scheduler(self.cfg.policy)     # fail fast on a policy typo
        ops = generate_scenario(self.cfg)
        self._wd = self.workdir or tempfile.mkdtemp(prefix="svff_sim_")
        results: list[OpResult] = []
        reconf_timings: list[dict] = []
        t0 = time.perf_counter()
        try:
            for i, op in enumerate(ops):
                try:
                    timings = self._apply(op)
                    if timings is not None:
                        reconf_timings.append(timings)
                    results.append(OpResult(op, "ok",
                                            virtual_t=self.clock.now()))
                    self.clock.stamp("ok", op=op.kind, tenant=op.tenant)
                except REJECTIONS as e:
                    if op.kind == "init":
                        raise    # a scenario with no system is no scenario
                    results.append(OpResult(op, "rejected", error=repr(e),
                                            virtual_t=self.clock.now()))
                    self.clock.stamp("rejected", op=op.kind,
                                     tenant=op.tenant)
                try:
                    check_invariants(self.mgr)
                    self._check_step_counters()
                except InvariantViolation as e:
                    raise InvariantViolation(
                        f"seed={self.cfg.seed} policy={self.cfg.policy} "
                        f"op#{i} {op}: {e}") from e
            final = self.mgr.query()
        finally:
            if self.workdir is None:
                shutil.rmtree(self._wd, ignore_errors=True)
        return ScenarioResult(
            config=self.cfg, ops=results, reconf_timings=reconf_timings,
            wall_seconds=time.perf_counter() - t0,
            virtual_seconds=self.clock.now(), final=final)

    def _check_step_counters(self):
        for tid, want in self.expected_steps.items():
            got = self.tenants[tid].steps_done
            if got != want:
                raise InvariantViolation(
                    f"step counter drift for {tid}: {got} != {want}")


def run_scenario(seed: int, policy: str = "first_fit",
                 **kw) -> ScenarioResult:
    """Convenience: run one seeded scenario, return its result."""
    return ScenarioRunner(ScenarioConfig(seed=seed, policy=policy,
                                         **kw)).run()
