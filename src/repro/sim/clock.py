"""VirtualClock — deterministic time for the scenario simulator.

Real SVFF timings (Table II) come from ``time.perf_counter``; a property
harness cannot assert on those. The simulator therefore threads a virtual
clock through every simulated component: operations *advance* it by
modelled costs, and the event log is stamped in virtual seconds, so the
same seed always yields the same timeline.
"""
from __future__ import annotations


class VirtualClock:
    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self.events: list[dict] = []

    def now(self) -> float:
        return self._now

    def advance(self, seconds: float) -> float:
        if seconds < 0:
            raise ValueError(f"clock cannot go backwards ({seconds})")
        self._now += seconds
        return self._now

    def stamp(self, kind: str, **info) -> dict:
        ev = {"t": self._now, "kind": kind, **info}
        self.events.append(ev)
        return ev
