"""Invariant checker — asserted after EVERY op of every scenario.

  I1  exclusive ownership: a tenant owns at most one VF, a VF at most one
      tenant, and device sets of device-holding VFs are pairwise disjoint
      and within-pool (IOMMU isolation; delegates to the pool's own check)
  I2  state-machine coherence: running tenant <-> ATTACHED VF with
      devices; paused tenant <-> PAUSED VF holding NO devices, owner kept
  I3  pause durability: every paused tenant has a config-space snapshot
      in host RAM whose step counter matches the tenant's, and the
      snapshot set contains EXACTLY the paused tenants
  I4  bit-identity: a running SimTenant's state equals
      ``expected_state(seed, steps_done)`` bit-for-bit — any corruption
      across pause/unpause/migrate/detach round-trips shows here
  I5  records <-> pool: the on-disk attach records are exactly the
      attached-or-paused tenants, and each record names the tenant's VF;
      detached tenants have a disk snapshot to re-attach from
  I6  Table-II timing dicts are well-formed: exactly the paper's four
      macro steps + total, all finite and non-negative, total = sum
  I7  pause stall accounting (``check_pause_timings``): every pause's
      PhaseTimings contains the three stop-and-copy steps, tenant-visible
      ``stop_s`` <= ``total``, only ``precopy_*`` phases may be
      background, and a live pause must have run background pre-copy —
      i.e. the reported stall is never under- or over-stated
  I8  journal/pool/records mutual consistency: at every quiescent point
      the WAL has no pending entries and no torn ``*.part`` files exist
      (records or journal dir), and replaying the committed entries in
      order predicts exactly each journaled tenant's live status — i.e.
      no committed intent contradicts the world, and no effect exists
      without a committed intent
  I9  recovery idempotence (checked by the chaos harness, not here):
      ``SVFFManager.recover`` applied twice equals once, bit-identically
      (``repro.sim.chaos.recover_manager``), and recovered tenants still
      satisfy I4
  I10 serve-token determinism: every request a serving tenant has emitted
      tokens for — finished or in flight — matches the no-reconfiguration
      oracle (``SimServeTenant.expected_output``) token-for-token. A
      request's output is identical with and without a pause/pause_live/
      migrate mid-flight; any byte corrupted in the paged KV state by a
      reconfiguration round-trip surfaces here as token divergence
  I11 autoscale justification (``check_autoscale``, run by the harness
      after every autoscale op): every action the autoscaler took must be
      justified by the telemetry snapshot it read — scale_out only with a
      hot engine AND spare capacity, scale_in only of an idle victim
      above the floor, rebalance only across a real hot/cold gap with
      queued work to move. Paired with I10 (checked after the same op),
      this is the claim that the control plane never reconfigures without
      telemetry evidence and never perturbs a token stream doing so
  I12 page-refcount accounting: for every tenant holding a
      ``BlockAllocator``, refcounts recomputed from the per-rid page
      chains equal the allocator's live refcount map (its own
      ``check_invariants`` — free/owned partition, trie registration
      agreement), AND every active slot's block-table row spells out
      exactly its request's allocator chain. An over-decref (double
      free) frees a page a prefix-sharing sibling still reads through;
      a CoW that repoints the chain but not the table row (or vice
      versa) makes reads and ownership disagree — both surface here
  I13 request-migration liveness: across all serve-shaped tenants, every
      request is LIVE (queued, active, or prefilling) on at most one
      engine, every rid that owns allocator pages corresponds to a
      request live on that same engine, and the same rid never owns
      pages in two allocators — i.e. the source's pages are freed iff
      the target committed, and an aborted/crashed migration never
      leaves a request duplicated, stranded, or page-orphaned. A slot
      frozen by an in-flight migration counts as live on the SOURCE
      (extraction copies, never moves). I10 extends across migration:
      a migrated request's token stream still equals its no-migration
      oracle, because extraction ships the exact page bytes plus
      pos/last_token and sampling is counter-seeded
  I14 gang/template coherence: every RUNNING pipeline gang lead runs at
      a width it has a registered stage template for, exactly width-1 of
      its shell members are running (one VF per stage, so with the
      lead's own VF the gang spans exactly ``width`` VFs), and the
      active template's stage bounds strictly partition periods
      0..num_periods into width non-empty stages — i.e. a live engine's
      VF set always matches exactly one registered template and its
      stage-resident state partitions cleanly. A crashed gang op
      (attach_group / reshape) must recover to a state satisfying this,
      so a half-attached gang or a half-applied width change is a
      violation, not a transient
  I15 federation single-serve + epoch fencing (``check_federation``):
      across ALL hosts of a federation, every request is SERVED (queued,
      or active in an unfrozen slot) by at most one engine on one host;
      a slot frozen by an in-flight outbound migration serves nothing
      and — at quiescent points — exists only under a PENDING journaled
      migrate entry (the deferred cross-host case, where the partition
      struck mid-ship and the source keeps the request frozen rather
      than guessing); and every host's epoch fence is bounded by the
      newest coordinator's epoch, so a coordinator that lost a handoff
      is rejected (``SplitBrainError``) by any host the successor
      reached — no request is ever admitted twice by racing coordinators
  I16 federation recovery idempotence (checked by the network-fault
      harness, not here): ``FederationCoordinator.recover`` over ANY
      subset of hosts, applied twice in any order, equals once —
      bit-identically under ``federation_fingerprint`` — including
      deferred cross-host migrations, which resolve exactly once after
      the partition heals (the multi-host lift of I9)

Violations raise ``InvariantViolation`` tagged by the caller with the
scenario seed and op index, which is all that is needed to reproduce.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.vf import VFState

TIMING_KEYS = frozenset({"rescan", "remove_vf", "change_num_vf", "add_vf",
                         "total"})


class InvariantViolation(AssertionError):
    pass


def _fail(msg: str):
    raise InvariantViolation(msg)


def check_invariants(mgr) -> None:
    pool = mgr.pool

    # -- I1: exclusive ownership / device disjointness -----------------------
    try:
        pool._check_invariants()
    except Exception as e:
        _fail(f"I1 pool isolation: {e}")
    owner_of = {}
    for vf in pool.vfs.values():
        if vf.owner is not None:
            if vf.owner in owner_of:
                _fail(f"I1 tenant {vf.owner} owns both "
                      f"{owner_of[vf.owner]} and {vf.vf_id}")
            owner_of[vf.owner] = vf.vf_id

    # -- I2: tenant status <-> VF state --------------------------------------
    for tid, tn in mgr.tenants.items():
        if tn.status == "running":
            if tn.vf_id is None or tn.vf_id not in pool.vfs:
                _fail(f"I2 running {tid} has no VF ({tn.vf_id})")
            vf = pool.vfs[tn.vf_id]
            if vf.state != VFState.ATTACHED or vf.owner != tid:
                _fail(f"I2 running {tid}: VF {vf.vf_id} is "
                      f"{vf.state.value}/owner={vf.owner}")
            if not vf.devices:
                _fail(f"I2 running {tid}: VF {vf.vf_id} holds no devices")
        elif tn.status == "paused":
            vf = pool.vfs.get(tn.vf_id)
            if vf is None:
                _fail(f"I2 paused {tid}: VF {tn.vf_id} vanished")
            if vf.state != VFState.PAUSED or vf.owner != tid:
                _fail(f"I2 paused {tid}: VF {vf.vf_id} is "
                      f"{vf.state.value}/owner={vf.owner}")
            if vf.devices:
                _fail(f"I2 paused {tid}: VF {vf.vf_id} still holds "
                      f"{len(vf.devices)} devices")
        elif tn.status == "detached":
            if tn.vf_id is not None:
                _fail(f"I2 detached {tid} still points at {tn.vf_id}")

    # -- I3: snapshots == paused tenants, counters preserved -----------------
    paused_ids = {tid for tid, tn in mgr.tenants.items()
                  if tn.status == "paused"}
    snap_ids = set(mgr.snapshots)
    if snap_ids != paused_ids:
        _fail(f"I3 snapshots {sorted(snap_ids)} != paused "
              f"{sorted(paused_ids)}")
    for tid in paused_ids:
        snap = mgr.snapshots[tid]
        if snap.steps_done != mgr.tenants[tid].steps_done:
            _fail(f"I3 {tid}: snapshot step {snap.steps_done} != tenant "
                  f"step {mgr.tenants[tid].steps_done}")
        if snap.tenant_id != tid:
            _fail(f"I3 snapshot for {tid} names {snap.tenant_id}")

    # -- I4: bit-identical state (SimTenant only) -----------------------------
    for tid, tn in mgr.tenants.items():
        if tn.status != "running" or not hasattr(tn, "expected_now"):
            continue
        want = tn.expected_now()
        got = tn.export_state()
        import jax
        wl, gl = jax.tree.leaves(want), jax.tree.leaves(got)
        if len(wl) != len(gl):
            _fail(f"I4 {tid}: state tree shape changed")
        for i, (w, g) in enumerate(zip(wl, gl)):
            if not np.array_equal(np.asarray(w), np.asarray(g)):
                _fail(f"I4 {tid}: leaf {i} not bit-identical after "
                      f"{tn.steps_done} steps")

    # -- I5: records on disk match pool state ---------------------------------
    attached_ids = {tid for tid, tn in mgr.tenants.items()
                    if tn.status in ("running", "paused")}
    rec_ids = set(mgr.records.list())
    if rec_ids != attached_ids:
        _fail(f"I5 records {sorted(rec_ids)} != attached "
              f"{sorted(attached_ids)}")
    for tid in attached_ids:
        rec = mgr.records.read(tid)
        if rec["tenant"] != tid:
            _fail(f"I5 record file {tid} names {rec['tenant']}")
        if rec["vf"]["vf_id"] != mgr.tenants[tid].vf_id:
            _fail(f"I5 {tid}: record VF {rec['vf']['vf_id']} != live "
                  f"{mgr.tenants[tid].vf_id}")
    parked = set(mgr._detached_steps())
    for tid, tn in mgr.tenants.items():
        if tn.status == "detached" and tid not in parked:
            _fail(f"I5 detached {tid} has no disk snapshot to re-attach")

    # -- I8: journal <-> pool <-> records mutual consistency ------------------
    journal = getattr(mgr, "journal", None)
    if journal is not None:
        # a DEFERRED cross-host migrate is the one legal pending entry at
        # a quiescent point: the destination host was unreachable during
        # recovery, so the entry stays pending (source slot frozen) until
        # a post-heal recover resolves it — I15 separately checks that
        # every frozen slot is covered by exactly such an entry
        pending = [e for e in journal.iter_entries()
                   if e["status"] == "pending"
                   and not e["details"].get("deferred_cross_host")]
        if pending:
            _fail(f"I8 journal has pending intents at a quiescent point: "
                  f"{[(e['seq'], e['op'], e['tenant']) for e in pending]}")
        parts = mgr.records.part_files()
        if parts:
            _fail(f"I8 orphaned record .part files: {parts}")
        import os
        jparts = [f for f in os.listdir(journal.dir) if f.endswith(".part")]
        if jparts:
            _fail(f"I8 orphaned journal .part files: {jparts}")
        # replay: the committed history must predict every journaled
        # tenant's live status (status transitions happen ONLY via
        # journaled ops, so history and world may never disagree)
        from repro.core.journal import COMPLETED_STATUS
        expect: dict = {}
        for e in journal.iter_entries():           # read-only, no copies
            if e["status"] != "committed":
                continue
            if e["op"] not in COMPLETED_STATUS:
                _fail(f"I8 committed entry {e['seq']} has unknown op "
                      f"{e['op']!r}")
            expect[e["tenant"]] = COMPLETED_STATUS[e["op"]]
        for tid, want in expect.items():
            tn = mgr.tenants.get(tid)
            if tn is None:
                _fail(f"I8 journal committed ops for unknown tenant {tid}")
            if tn.status != want:
                _fail(f"I8 {tid}: journal history says {want!r}, live "
                      f"status is {tn.status!r}")

    # -- I10: serve-token determinism across reconfigurations -----------------
    for tid, tn in mgr.tenants.items():
        if not hasattr(tn, "expected_output"):
            continue
        for req in getattr(tn, "requests", ()):
            # the oracle replays from the seed the request was MINTED
            # under — a rebalance may have handed it to another tenant
            want = tn.expected_output(getattr(req, "seed", tn.seed),
                                      req.rid)
            got = list(req.out)
            if req.done and got != want:
                _fail(f"I10 {tid} rid={req.rid}: finished output {got} "
                      f"!= oracle {want} (token divergence across a "
                      f"reconfiguration)")
            if not req.done and got != want[:len(got)]:
                _fail(f"I10 {tid} rid={req.rid}: in-flight prefix {got} "
                      f"diverged from oracle {want[:len(got)]}")
            if req.done and not req.out:
                _fail(f"I10 {tid} rid={req.rid}: done with no tokens")

    # -- I12: page refcounts == live block-table references --------------------
    for tid, tn in mgr.tenants.items():
        host = tn if hasattr(tn, "alloc") else getattr(tn, "engine", None)
        alloc = getattr(host, "alloc", None)
        if alloc is None:
            continue
        # the allocator's own books first: refcounts recomputed from the
        # per-rid chains must equal the live _ref map (an over-decref
        # frees a page a sibling still reads through)
        try:
            alloc.check_invariants()
        except AssertionError as e:
            _fail(f"I12 {tid}: allocator accounting: {e}")
        # then the device view: every active slot's table row must spell
        # out exactly its request's allocator chain (a CoW that repointed
        # the chain but not the table — or vice versa — diverges here)
        tables = getattr(host, "tables", None)
        if tables is not None:
            for s, req in enumerate(getattr(host, "active", ())):
                if req is None:
                    continue
                chain = alloc.pages_of(req.rid)
                row = [int(x) for x in tables[s][:len(chain)]]
                if row != chain:
                    _fail(f"I12 {tid} slot {s}: table row {row} != "
                          f"allocator chain {chain} for rid {req.rid}")

    # -- I13: request-migration liveness ---------------------------------------
    # Every request is live on at most ONE engine, and page ownership
    # follows liveness: a rid owning allocator pages must be live (active,
    # prefilling, or mid-migration-frozen — all of which keep the request
    # in ``active``/``_jobs``) on that same engine. Together these say a
    # migration frees the source's pages iff the target committed, and
    # never duplicates or strands a request.
    live_on: dict = {}                         # rid -> hosting tid
    for tid, tn in mgr.tenants.items():
        host = tn if hasattr(tn, "alloc") else getattr(tn, "engine", None)
        if host is None or not hasattr(host, "active"):
            continue
        live_here = ([r for r in getattr(host, "queue", ()) ]
                     + [r for r in host.active if r is not None]
                     + [j.req for j in getattr(host, "_jobs", {}).values()])
        seen_here: set = set()
        for req in live_here:
            rid = req.rid
            if rid in seen_here:
                _fail(f"I13 {tid}: request {rid} appears twice on one "
                      f"engine (queue/slots/jobs)")
            seen_here.add(rid)
            if rid in live_on:
                _fail(f"I13 request {rid} live on BOTH {live_on[rid]} "
                      f"and {tid} (migration duplicated it)")
            live_on[rid] = tid
        alloc = getattr(host, "alloc", None)
        if alloc is None:
            continue
        for rid in alloc.owners():
            if rid not in seen_here:
                _fail(f"I13 {tid}: allocator pages owned by rid {rid} "
                      f"with no live request on this engine (source "
                      f"pages not freed after a committed migration, or "
                      f"a leaked admission)")

    # -- I14: gang/template coherence ------------------------------------------
    # A pipeline gang lead that is RUNNING must be at a registered
    # template width, with exactly width-1 running shells (one VF per
    # stage counting the lead's own) and stage bounds that strictly
    # partition its periods. Checked only at quiescent points, so a
    # crashed gang op that recovers half-attached shows up here.
    for tid, tn in mgr.tenants.items():
        shells = getattr(tn, "gang_shells", None)
        if not shells or getattr(tn, "status", None) != "running":
            continue
        k = getattr(tn, "stage_width", 1)
        if not tn.has_template(k):
            _fail(f"I14 {tid}: live at width {k} with no registered "
                  f"stage template")
        live = [sh.tid for sh in shells
                if getattr(sh, "status", None) == "running"]
        if len(live) != k - 1:
            _fail(f"I14 {tid}: width {k} but {len(live)} running "
                  f"shells {live} (want exactly {k - 1})")
        bounds = tuple(tn.stage_bounds())
        nper = getattr(tn, "num_periods", None)
        if (len(bounds) != k + 1 or bounds[0] != 0
                or bounds[-1] != nper
                or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:]))):
            _fail(f"I14 {tid}: stage bounds {bounds} do not partition "
                  f"{nper} periods into {k} non-empty stages")


def _serving_map(host) -> tuple:
    """(serving, frozen): rid -> engine tid for requests an engine on
    ``host`` is SERVING (queued, or active in an unfrozen slot), and for
    requests frozen by an in-flight outbound migration (serving nobody)."""
    serving: dict = {}
    frozen_map: dict = {}
    for tn in host.serve_targets():
        tid = getattr(tn, "tid", repr(tn))
        frozen = set(getattr(tn, "_migrating", ()))
        for req in getattr(tn, "queue", ()):
            serving.setdefault(req.rid, tid)
        for req in getattr(tn, "active", ()):
            if req is None:
                continue
            if req.rid in frozen:
                frozen_map[req.rid] = tid
            else:
                serving.setdefault(req.rid, tid)
    return serving, frozen_map


def check_federation(hosts, coordinators=()) -> None:
    """I15 — cross-host single-serve + epoch fencing, checked at every
    quiescent point of a federation scenario:

      1. every rid is served by at most one engine across ALL hosts;
      2. every frozen (mid-migration) slot is covered by a PENDING
         journaled migrate entry on its own host naming that rid — i.e. a
         frozen request is accounted for, never silently stranded, and
         only a deferred cross-host migration may survive quiescence;
      3. no host's epoch fence exceeds the newest coordinator's epoch
         (fences only come from coordinators, monotone), so exactly the
         coordinators at the top epoch can drive fenced hosts.

    Per-host invariants (I1..I14) are the per-manager checker's job —
    run ``check_invariants(host.mgr)`` separately."""
    owner: dict = {}                         # rid -> (host_id, tid)
    for host in hosts:
        serving, frozen_map = _serving_map(host)
        for rid, tid in serving.items():
            if rid in owner:
                _fail(f"I15 request {rid} served by BOTH "
                      f"{owner[rid][0]}/{owner[rid][1]} and "
                      f"{host.host_id}/{tid} (dual-serve)")
            owner[rid] = (host.host_id, tid)
        if frozen_map:
            pending_rids = {
                e["details"].get("rid")
                for e in host.mgr.journal.iter_entries()
                if e["status"] == "pending"
                and e["op"] == "migrate_request"}
            for rid, tid in frozen_map.items():
                if rid not in pending_rids:
                    _fail(f"I15 {host.host_id}/{tid}: slot frozen for rid "
                          f"{rid} with no pending journaled migrate entry "
                          f"(stranded freeze)")
    if coordinators:
        top = max(c.epoch for c in coordinators)
        for host in hosts:
            if host.fence_epoch > top:
                _fail(f"I15 {host.host_id}: fence epoch "
                      f"{host.fence_epoch} exceeds newest coordinator "
                      f"epoch {top} (fence from nowhere)")


def check_autoscale(action, cfg) -> None:
    """I11 — an autoscaler action must be justified by the telemetry
    snapshot it carries (``core.autoscaler.justify_action`` re-derives
    the action's necessary conditions from that snapshot alone). The
    token-stream half of the invariant — the action must not perturb any
    request's output — is I10, which the harness checks after the same
    op."""
    from repro.core.autoscaler import justify_action
    err = justify_action(action, cfg)
    if err is not None:
        _fail(f"I11 unjustified autoscale action "
              f"(snapshot {action.snapshot.describe()}): {err}")


def check_timings(timings: dict) -> None:
    """I6 — a reconf's Table-II dict is well-formed."""
    if set(timings) != TIMING_KEYS:
        _fail(f"I6 timing keys {sorted(timings)} != "
              f"{sorted(TIMING_KEYS)}")
    for k, v in timings.items():
        if not isinstance(v, float) or not math.isfinite(v) or v < 0:
            _fail(f"I6 timing {k}={v!r} not a finite non-negative float")
    body = sum(v for k, v in timings.items() if k != "total")
    if abs(body - timings["total"]) > 1e-6:
        _fail(f"I6 total {timings['total']} != sum of steps {body}")


#: the tenant-visible phases every pause's stop-and-copy must contain
PAUSE_STOP_PHASES = frozenset({"save_config_space", "unregister_pci",
                               "unregister_vfio"})


def check_pause_timings(t, live: bool = False) -> None:
    """I7 — a pause's ``PhaseTimings`` is well-formed and its stall is
    bounded: the tenant-visible ``stop_s`` never exceeds ``total``, only
    pre-copy rounds may run in the background, and a live pause accounts
    its rounds as background (so stop-and-copy is the ONLY stall)."""
    for k, v in t.phases.items():
        if not isinstance(v, float) or not math.isfinite(v) or v < 0:
            _fail(f"I7 pause phase {k}={v!r} not finite/non-negative")
    if not PAUSE_STOP_PHASES <= set(t.phases):
        _fail(f"I7 pause phases {sorted(t.phases)} missing stop-and-copy "
              f"steps {sorted(PAUSE_STOP_PHASES)}")
    if t.stop_s > t.total + 1e-9:
        _fail(f"I7 stop_s {t.stop_s} exceeds total {t.total}")
    for name in t.background:
        if not name.startswith("precopy_"):
            _fail(f"I7 non-precopy phase {name!r} marked background "
                  f"(stall under-reported)")
    if t.background & PAUSE_STOP_PHASES:
        _fail(f"I7 stop-and-copy phase marked background: {t.background}")
    if live:
        if not t.background:
            _fail("I7 live pause ran no background pre-copy rounds")
        precopy = {k for k in t.phases if k.startswith("precopy_")}
        if precopy != t.background:
            # a precopy phase recorded with stop=True would inflate the
            # reported stall; a stop phase in background would hide it
            _fail(f"I7 background {sorted(t.background)} != recorded "
                  f"pre-copy rounds {sorted(precopy)}")
    elif t.background:
        _fail(f"I7 stop-the-world pause has background phases "
              f"{sorted(t.background)}")
