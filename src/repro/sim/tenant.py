"""SimTenant — a lightweight tenant that drives the REAL manager stack.

The production ``Tenant`` binds jax meshes and compiled executables, which
makes thousands-of-scenarios property testing impossible (and pointless:
the numerics are covered by the tier-1 tests). ``SimTenant`` implements
the same duck-typed protocol ``SVFFManager`` / ``core.pause`` /
``core.fault`` consume — ``bind``/``suspend``/``resume``/``detach``/
``export_state``/``export_specs``/``shardings_for``/``state_template``/
``run_steps``/``inject_failure`` — over small numpy pytrees, so every
scenario exercises the real pool, scheduler, pause, staging, records and
checkpoint code paths.

The crucial property: a SimTenant's state is a PURE FUNCTION of
``(seed, steps_done)`` — ``expected_state(seed, k)`` recomputes it from
scratch. The invariant checker uses this to assert bit-identity after any
pause/unpause/migrate/detach round-trip without shadow bookkeeping.
"""
from __future__ import annotations

import types
from typing import Optional

import jax
import numpy as np

from repro.core.tenant import DevicePausedError
from repro.core.vf import VirtualFunction
from repro.sim.clock import VirtualClock

_LEAVES = ("w0", "w1")        # params leaves
_OPT = ("mu",)                # optimizer leaves


def _tree_shapes(leaf_size: int) -> dict:
    return {"params": {k: (leaf_size,) for k in _LEAVES},
            "opt": {k: (leaf_size,) for k in _OPT}}


class SimTenant:
    #: virtual seconds per op, mirroring Table-II's cost asymmetry
    STEP_COST = 1e-3
    COMPILE_COST = 0.25       # "flash the bitstream" on a new slice

    def __init__(self, tid: str, seed: int = 0, *, leaf_size: int = 16,
                 clock: Optional[VirtualClock] = None,
                 placement: str = "first_fit"):
        self.tid = tid
        self.seed = int(seed)
        self.leaf_size = int(leaf_size)
        self.clock = clock
        self.status = "created"        # created|running|paused|detached
        self.vf_id: Optional[str] = None
        self.steps_done = 0
        self.workload = "sim"
        self._state = None
        self._exec_cache: dict = {}
        self.step_times: list[float] = []
        self._fail_next = False
        # what SVFFManager reads off tenant.run
        self.run = types.SimpleNamespace(
            model=types.SimpleNamespace(name=f"sim-{tid}"),
            placement=placement, seed=self.seed)

    # ------------------------------------------------------- deterministic state
    @staticmethod
    def _base(seed: int, leaf_size: int) -> dict:
        shapes = _tree_shapes(leaf_size)
        out = {"params": {}, "opt": {}}
        for grp in ("params", "opt"):
            for i, (k, shp) in enumerate(sorted(shapes[grp].items())):
                rng = np.random.default_rng([7001, seed, i, grp == "opt"])
                out[grp][k] = rng.standard_normal(shp).astype(np.float32)
        return out

    @staticmethod
    def _delta(seed: int, step: int, leaf_size: int) -> dict:
        shapes = _tree_shapes(leaf_size)
        out = {"params": {}, "opt": {}}
        for grp in ("params", "opt"):
            for i, (k, shp) in enumerate(sorted(shapes[grp].items())):
                rng = np.random.default_rng(
                    [7002, seed, step, i, grp == "opt"])
                out[grp][k] = (rng.standard_normal(shp) * 1e-2
                               ).astype(np.float32)
        return out

    @classmethod
    def expected_state(cls, seed: int, steps: int,
                       leaf_size: int = 16) -> dict:
        """Recompute the exact state after ``steps`` update steps."""
        state = cls._base(seed, leaf_size)
        for k in range(steps):
            d = cls._delta(seed, k, leaf_size)
            state = jax.tree.map(lambda a, b: a + b, state, d)
        return state

    def expected_now(self) -> dict:
        return self.expected_state(self.seed, self.steps_done,
                                   self.leaf_size)

    # ------------------------------------------------------------- protocol
    def bind(self, vf: VirtualFunction, state=None, *,
             flash: bool = True) -> float:
        if state is not None:
            self._state = jax.tree.map(np.asarray, state)
        elif self._state is None:
            self._state = self._base(self.seed, self.leaf_size)
        key = (tuple(vf.mesh_shape), tuple(str(d) for d in vf.devices))
        compile_s = 0.0
        if key not in self._exec_cache:
            self._exec_cache[key] = True
            compile_s = self.COMPILE_COST
        if self.clock is not None:
            self.clock.advance(compile_s)
        self._active_key = key
        self.vf_id = vf.vf_id
        self.status = "running"
        vf.emulated.update({"tenant": self.tid, "status": "running",
                            "steps_done": self.steps_done})
        return compile_s

    def run_steps(self, n: int = 1) -> dict:
        if self.status == "paused":
            raise DevicePausedError(
                f"{self.tid}: device {self.vf_id} is paused")
        if self.status != "running":
            raise RuntimeError(f"{self.tid}: no device attached")
        if self._fail_next:
            self._fail_next = False
            raise RuntimeError(f"{self.tid}: injected device failure")
        for _ in range(n):
            d = self._delta(self.seed, self.steps_done, self.leaf_size)
            self._state = jax.tree.map(lambda a, b: a + b, self._state, d)
            self.steps_done += 1
            if self.clock is not None:
                self.clock.advance(self.STEP_COST)
            self.step_times.append(self.STEP_COST)
        return {"loss": float(np.abs(self._state["params"]["w0"]).mean())}

    # -- pause plumbing ------------------------------------------------------
    def export_state(self):
        return self._state

    def export_specs(self):
        return {}                      # sim carries no PartitionSpecs

    def shardings_for(self, vf: VirtualFunction):
        return None                    # staging places on default device

    def state_template(self):
        return jax.tree.map(np.zeros_like,
                            self._base(self.seed, self.leaf_size))

    def suspend(self):
        self._state = None
        self.status = "paused"

    def resume(self, state, vf: VirtualFunction):
        self.status = "running"
        self.bind(vf, state=state)

    def detach(self):
        self._state = None
        self.vf_id = None
        self.status = "detached"

    # -- introspection -------------------------------------------------------
    def query(self) -> dict:
        return {"tenant": self.tid, "status": self.status,
                "vf": self.vf_id, "steps_done": self.steps_done,
                "workload": self.workload,
                "exec_keys": [list(map(str, k)) for k in self._exec_cache]}

    def inject_failure(self):
        self._fail_next = True


class ServeSimTenant:
    """Serving-shaped pause-protocol stub: big IMMUTABLE params plus a
    small hot cache that every decode step replaces — the exact dirty
    profile ``ServeEngine.dirty_keys`` reports. Shared by the pause-path
    benchmark (HC5) and the staging tests so both exercise one copy of
    the duck-typed tenant protocol."""

    def __init__(self, params, cache, tid: str = "serve0"):
        self.tid = tid
        self.steps_done = 0
        self.status = "running"
        self.vf_id: Optional[str] = None
        self._exec_cache: dict = {}
        self.params = params
        self.cache = cache

    def step(self):
        self.cache = self.cache + 1.0       # mutates ONLY the cache
        self.steps_done += 1

    def export_state(self):
        return {"params": self.params, "cache": self.cache}

    def export_specs(self):
        return {}

    def shardings_for(self, vf):
        return None

    def state_template(self):
        return jax.tree.map(np.zeros_like, self.export_state())

    def suspend(self):
        self.params = None
        self.cache = None
        self.status = "paused"

    def resume(self, state, vf: VirtualFunction):
        self.params, self.cache = state["params"], state["cache"]
        self.status = "running"
