"""SimTenant — a lightweight tenant that drives the REAL manager stack.

The production ``Tenant`` binds jax meshes and compiled executables, which
makes thousands-of-scenarios property testing impossible (and pointless:
the numerics are covered by the tier-1 tests). ``SimTenant`` implements
the same duck-typed protocol ``SVFFManager`` / ``core.pause`` /
``core.fault`` consume — ``bind``/``suspend``/``resume``/``detach``/
``export_state``/``export_specs``/``shardings_for``/``state_template``/
``run_steps``/``inject_failure`` — over small numpy pytrees, so every
scenario exercises the real pool, scheduler, pause, staging, records and
checkpoint code paths.

The crucial property: a SimTenant's state is a PURE FUNCTION of
``(seed, steps_done)`` — ``expected_state(seed, k)`` recomputes it from
scratch. The invariant checker uses this to assert bit-identity after any
pause/unpause/migrate/detach round-trip without shadow bookkeeping.
"""
from __future__ import annotations

import types
from typing import Optional

import jax
import numpy as np

from repro.core.tenant import DevicePausedError
from repro.core.vf import VirtualFunction
from repro.sim.clock import VirtualClock

_LEAVES = ("w0", "w1")        # params leaves
_OPT = ("mu",)                # optimizer leaves


def _tree_shapes(leaf_size: int) -> dict:
    return {"params": {k: (leaf_size,) for k in _LEAVES},
            "opt": {k: (leaf_size,) for k in _OPT}}


class SimTenant:
    #: virtual seconds per op, mirroring Table-II's cost asymmetry
    STEP_COST = 1e-3
    COMPILE_COST = 0.25       # "flash the bitstream" on a new slice

    def __init__(self, tid: str, seed: int = 0, *, leaf_size: int = 16,
                 clock: Optional[VirtualClock] = None,
                 placement: str = "first_fit"):
        self.tid = tid
        self.seed = int(seed)
        self.leaf_size = int(leaf_size)
        self.clock = clock
        self.status = "created"        # created|running|paused|detached
        self.vf_id: Optional[str] = None
        self.steps_done = 0
        self.workload = "sim"
        self._state = None
        self._exec_cache: dict = {}
        self.step_times: list[float] = []
        self._fail_next = False
        # what SVFFManager reads off tenant.run
        self.run = types.SimpleNamespace(
            model=types.SimpleNamespace(name=f"sim-{tid}"),
            placement=placement, seed=self.seed)

    # ------------------------------------------------------- deterministic state
    @staticmethod
    def _base(seed: int, leaf_size: int) -> dict:
        shapes = _tree_shapes(leaf_size)
        out = {"params": {}, "opt": {}}
        for grp in ("params", "opt"):
            for i, (k, shp) in enumerate(sorted(shapes[grp].items())):
                rng = np.random.default_rng([7001, seed, i, grp == "opt"])
                out[grp][k] = rng.standard_normal(shp).astype(np.float32)
        return out

    @staticmethod
    def _delta(seed: int, step: int, leaf_size: int) -> dict:
        shapes = _tree_shapes(leaf_size)
        out = {"params": {}, "opt": {}}
        for grp in ("params", "opt"):
            for i, (k, shp) in enumerate(sorted(shapes[grp].items())):
                rng = np.random.default_rng(
                    [7002, seed, step, i, grp == "opt"])
                out[grp][k] = (rng.standard_normal(shp) * 1e-2
                               ).astype(np.float32)
        return out

    @classmethod
    def expected_state(cls, seed: int, steps: int,
                       leaf_size: int = 16) -> dict:
        """Recompute the exact state after ``steps`` update steps."""
        state = cls._base(seed, leaf_size)
        for k in range(steps):
            d = cls._delta(seed, k, leaf_size)
            state = jax.tree.map(lambda a, b: a + b, state, d)
        return state

    def expected_now(self) -> dict:
        return self.expected_state(self.seed, self.steps_done,
                                   self.leaf_size)

    # ------------------------------------------------------------- protocol
    def bind(self, vf: VirtualFunction, state=None, *,
             flash: bool = True) -> float:
        if state is not None:
            self._state = jax.tree.map(np.asarray, state)
        elif self._state is None:
            self._state = self._base(self.seed, self.leaf_size)
        key = (tuple(vf.mesh_shape), tuple(str(d) for d in vf.devices))
        compile_s = 0.0
        if key not in self._exec_cache:
            self._exec_cache[key] = True
            compile_s = self.COMPILE_COST
        if self.clock is not None:
            self.clock.advance(compile_s)
        self._active_key = key
        self.vf_id = vf.vf_id
        self.status = "running"
        vf.emulated.update({"tenant": self.tid, "status": "running",
                            "steps_done": self.steps_done})
        return compile_s

    def run_steps(self, n: int = 1) -> dict:
        if self.status == "paused":
            raise DevicePausedError(
                f"{self.tid}: device {self.vf_id} is paused")
        if self.status != "running":
            raise RuntimeError(f"{self.tid}: no device attached")
        if self._fail_next:
            self._fail_next = False
            raise RuntimeError(f"{self.tid}: injected device failure")
        for _ in range(n):
            d = self._delta(self.seed, self.steps_done, self.leaf_size)
            self._state = jax.tree.map(lambda a, b: a + b, self._state, d)
            self.steps_done += 1
            if self.clock is not None:
                self.clock.advance(self.STEP_COST)
            self.step_times.append(self.STEP_COST)
        return {"loss": float(np.abs(self._state["params"]["w0"]).mean())}

    # -- pause plumbing ------------------------------------------------------
    def export_state(self):
        return self._state

    def export_specs(self):
        return {}                      # sim carries no PartitionSpecs

    def shardings_for(self, vf: VirtualFunction):
        return None                    # staging places on default device

    def state_template(self):
        return jax.tree.map(np.zeros_like,
                            self._base(self.seed, self.leaf_size))

    def suspend(self):
        self._state = None
        self.status = "paused"

    def resume(self, state, vf: VirtualFunction):
        self.status = "running"
        self.bind(vf, state=state)

    def detach(self):
        self._state = None
        self.vf_id = None
        self.status = "detached"

    # -- introspection -------------------------------------------------------
    def query(self) -> dict:
        return {"tenant": self.tid, "status": self.status,
                "vf": self.vf_id, "steps_done": self.steps_done,
                "workload": self.workload,
                "exec_keys": [list(map(str, k)) for k in self._exec_cache]}

    def inject_failure(self):
        self._fail_next = True


class SimServeTenant:
    """A deterministic toy *serving* tenant for the scenario simulator —
    the serve-plane analogue of ``SimTenant``.

    It mirrors the real ``ServeEngine``'s control flow (queue -> paged
    admission through the REAL ``serve.paged.BlockAllocator`` -> batched
    decode over block-table-indirected pages -> slot recycling) over tiny
    integer arrays, so thousands of scenario ops stay cheap while the
    allocator and the pause/staging round-trip get real coverage.

    The crucial property (invariant I10): every emitted token is a pure
    function of the request identity and the CONTENT of the tenant's
    state arrays — ``expected_output(seed, rid)`` replays the request
    with no engine at all, so any byte the pause/unpause/migrate paths
    corrupt in pages/tables/pos/last shows up as token divergence, and a
    request served across a mid-flight reconfiguration must produce
    exactly the tokens it would have produced without one.
    """

    VOCAB = 97
    PAGE = 4
    SLOTS = 2
    MAX_PAGES = 4                         # per-slot table width
    M = (1 << 31) - 1

    def __init__(self, tid: str, seed: int = 0, *,
                 clock: Optional[VirtualClock] = None,
                 placement: str = "first_fit"):
        from repro.serve.paged import BlockAllocator
        self.tid = tid
        self.seed = int(seed)
        self.clock = clock
        self.status = "created"
        self.vf_id: Optional[str] = None
        self.steps_done = 0
        self.workload = "serve"
        self._exec_cache: dict = {}
        self.step_times: list[float] = []
        self._fail_next = False
        self.run = types.SimpleNamespace(
            model=types.SimpleNamespace(name=f"sim-serve-{tid}"),
            placement=placement, seed=self.seed)
        self.num_pages = 1 + self.SLOTS * self.MAX_PAGES
        self.alloc = BlockAllocator(self.num_pages, self.PAGE)
        # device state (round-trips through the real staging/pause paths)
        self.pages = np.zeros((self.num_pages, self.PAGE), np.int64)
        self.tables = np.zeros((self.SLOTS, self.MAX_PAGES), np.int32)
        self.pos = np.full((self.SLOTS,), -1, np.int64)
        self.last = np.zeros((self.SLOTS,), np.int64)
        # host-side request plane (guest RAM: survives pause like a queue
        # in the real engine's process)
        self.queue: "list" = []
        self.active: list = [None] * self.SLOTS
        self.requests: list = []          # every request ever submitted
        self._next_rid = 0
        self.shared_hits = 0              # pages admitted without a copy
        self.cow_splits = 0               # decode writes that split a page
        self.preemptions = 0              # CoW exhaustion -> recompute
        #: rid -> slot frozen by an in-flight outbound migration (mirrors
        #: ServeEngine._migrating): the slot keeps its request/pages, is
        #: skipped by decode, thaws on release (commit) or abort
        self._migrating: dict = {}
        self.migrations_in = 0
        self.migrations_out = 0
        self.migration_stall_ticks = 0

    # ----------------------------------------------------- the toy "model"
    @classmethod
    def _cell(cls, tok: int, i: int) -> int:
        return ((tok + 1) * (2654435761 * (i + 1) % cls.M)) % cls.M

    @classmethod
    def _digest_tok(cls, cells) -> int:
        return int(sum(cells) % cls.M) % cls.VOCAB

    @classmethod
    def make_prompt(cls, seed: int, rid: int) -> tuple:
        """Odd rids draw unique prompts; even rids open with a PAGE+1-token
        seed-only "system prefix" (rid % 4 == 0 requests are the prefix
        verbatim), so scenario traffic naturally exercises the allocator's
        prefix-trie sharing, partial-page hits, and CoW splits."""
        if rid % 2:
            plen = 1 + (rid * 7 + seed) % 5
            return tuple((seed * 31 + rid * 17 + j * 13) % cls.VOCAB
                         for j in range(plen))
        sys_prefix = tuple((seed * 11 + j * 7 + 3) % cls.VOCAB
                           for j in range(cls.PAGE + 1))
        if rid % 4 == 0:
            return sys_prefix
        tail = 1 + (rid // 2 + seed) % 3
        return sys_prefix + tuple(
            (seed * 31 + rid * 17 + j * 13) % cls.VOCAB
            for j in range(tail))

    @classmethod
    def make_max_new(cls, seed: int, rid: int) -> int:
        return 1 + (rid + seed) % 5       # includes prefill-finish (== 1)

    @classmethod
    def expected_output(cls, seed: int, rid: int) -> list:
        """Oracle: the tokens this request produces when served with NO
        mid-flight reconfiguration (pure replay of the recurrence)."""
        prompt = cls.make_prompt(seed, rid)
        max_new = cls.make_max_new(seed, rid)
        cells = [cls._cell(t, i) for i, t in enumerate(prompt)]
        out = [cls._digest_tok(cells)]
        while len(out) < max_new:
            cells.append(cls._cell(out[-1], len(cells)))
            out.append(cls._digest_tok(cells))
        return out

    # ---------------------------------------------------------- traffic
    def submit_burst(self, n: int = 1):
        """n requests arrive (queueing is guest-side: works while paused).
        Each request records the seed its prompt/oracle derive from, so a
        rebalance may hand it to ANOTHER serving tenant and I10 still
        replays it against the right oracle."""
        for _ in range(n):
            rid = self._next_rid
            self._next_rid += 1
            req = types.SimpleNamespace(
                rid=rid, seed=self.seed,
                prompt=self.make_prompt(self.seed, rid),
                max_new=self.make_max_new(self.seed, rid),
                out=[], done=False)
            self.queue.append(req)
            self.requests.append(req)

    def submit_request(self, rid: int, seed: Optional[int] = None):
        """One request with an EXTERNAL identity arrives — the federation
        routing path (``core.host.Host.submit``): the coordinator mints
        the rid (epoch-salted, disjoint from the local ``submit_burst``
        space) and the prompt/oracle derive from ``(seed, rid)`` exactly
        like locally-minted traffic, so I10/I15 replay it with no extra
        bookkeeping. Returns the request object."""
        seed = self.seed if seed is None else int(seed)
        req = types.SimpleNamespace(
            rid=int(rid), seed=seed,
            prompt=self.make_prompt(seed, rid),
            max_new=self.make_max_new(seed, rid),
            out=[], done=False)
        self.queue.append(req)
        self.requests.append(req)
        return req

    # page-table helpers over the flat logical view -------------------------
    def _cells_of(self, slot: int, upto: int):
        row = self.tables[slot]
        return [int(self.pages[row[i // self.PAGE], i % self.PAGE])
                for i in range(upto + 1)]

    def _write(self, slot: int, i: int, val: int):
        row = self.tables[slot]
        self.pages[row[i // self.PAGE], i % self.PAGE] = val

    def _admit(self):
        from repro.serve.paged import CacheExhausted
        for s in range(self.SLOTS):
            if self.active[s] is not None:
                continue
            while self.queue:
                req = self.queue[0]
                need = self.alloc.pages_needed(len(req.prompt)
                                               + req.max_new)
                try:
                    pages = self.alloc.allocate(req.rid, need,
                                                tokens=req.prompt)
                except CacheExhausted:
                    return                      # back off, keep order
                self.queue.pop(0)
                shared = self.alloc.shared_count(req.rid)
                self.shared_hits += shared
                self.tables[s, :] = 0
                self.tables[s, :len(pages)] = pages
                self.pos[s] = len(req.prompt) - 1
                # shared pages already hold these exact cells (cells are
                # pure functions of token + absolute index); writing them
                # would scribble on pages siblings are reading through
                for i, t in enumerate(req.prompt):
                    if i >= shared * self.PAGE:
                        self._write(s, i, self._cell(t, i))
                tok = self._digest_tok(self._cells_of(s, self.pos[s]))
                req.out.append(tok)
                if len(req.out) >= req.max_new:    # finished at prefill
                    req.done = True
                    self.alloc.free(req.rid)
                    self.tables[s, :] = 0
                    self.pos[s] = -1
                    continue                        # slot re-offered
                self.alloc.register_prefix(req.rid)
                self.last[s] = tok
                self.active[s] = req
                break

    def _preempt(self, s: int):
        """CoW exhaustion valve: drop the slot's work, free its pages and
        requeue it at the FRONT — tokens are a pure function of request
        identity, so the recompute is token-identical (I10)."""
        req = self.active[s]
        self.alloc.free(req.rid)
        req.out.clear()
        self.active[s] = None
        self.tables[s, :] = 0
        self.pos[s] = -1
        self.queue.insert(0, req)
        self.preemptions += 1

    def _engine_step(self):
        from repro.serve.paged import CacheExhausted
        self._admit()
        frozen = set(self._migrating.values())
        for s in range(self.SLOTS):
            req = self.active[s]
            if req is None:
                continue
            if s in frozen:               # mid-migration: slot is frozen
                self.migration_stall_ticks += 1
                continue
            # copy-on-write: this step's KV cell must land in a PRIVATE
            # page; a shared one is split first (one page, one table row)
            pi = (int(self.pos[s]) + 1) // self.PAGE
            chain = self.alloc.pages_of(req.rid)
            if self.alloc.refcount(chain[pi]) > 1:
                try:
                    old, new = self.alloc.cow(req.rid, pi)
                except CacheExhausted:
                    self._preempt(s)
                    continue
                self.pages[new] = self.pages[old]
                self.tables[s, pi] = new
                self.cow_splits += 1
            self.pos[s] += 1
            self._write(s, int(self.pos[s]),
                        self._cell(int(self.last[s]), int(self.pos[s])))
            tok = self._digest_tok(self._cells_of(s, int(self.pos[s])))
            req.out.append(tok)
            self.last[s] = tok
            if len(req.out) >= req.max_new:
                req.done = True
                self.alloc.free(req.rid)
                self.active[s] = None
                self.tables[s, :] = 0
                self.pos[s] = -1

    # -- request migration (mirrors ServeEngine's protocol) -----------------
    def peek_migratable(self, rid=None):
        """Pure query: the rid ``extract_request`` would pick."""
        frozen = set(self._migrating.values())
        for s in range(self.SLOTS):
            req = self.active[s]
            if req is None or s in frozen:
                continue
            if rid is None or req.rid == rid:
                return req.rid
        return None

    def extract_request(self, rid=None):
        """Freeze one in-flight request and copy out everything the
        target needs: page bytes of its chain, pos, last token, prompt
        tokens (for trie re-sharing). Non-destructive — the source keeps
        its pages until ``release_request``."""
        rid = self.peek_migratable(rid)
        if rid is None:
            return None
        slot = next(s for s in range(self.SLOTS)
                    if self.active[s] is not None
                    and self.active[s].rid == rid)
        chain = self.alloc.pages_of(rid)
        self._migrating[rid] = slot
        return {"rid": rid, "req": self.active[slot], "slot": slot,
                "chain_len": len(chain), "page_size": self.PAGE,
                "tokens": self.alloc.tokens_of(rid),
                "pos": int(self.pos[slot]), "last": int(self.last[slot]),
                "state": {"cells": self.pages[chain].copy()}}

    def admit_migrated(self, payload, state):
        """Admit a migrated request into a free slot. Re-shares trie
        pages for FULL prompt pages only — the partly-filled last prompt
        page may already hold this request's decode cells, which a
        sibling's registered page does not. Raises ``CacheExhausted``
        (side-effect-free) when no slot or not enough pages."""
        from repro.serve.paged import CacheExhausted
        rid = payload["rid"]
        if self.owns_request(rid):        # idempotent recovery replay
            return
        slot = next((s for s in range(self.SLOTS)
                     if self.active[s] is None), None)
        if slot is None:
            raise CacheExhausted(
                f"request {rid}: no free slot on migration target "
                f"{self.tid}")
        tokens = payload.get("tokens")
        share = None
        if tokens:
            share = tokens[:self.PAGE * (len(tokens) // self.PAGE)] or None
        pages = self.alloc.allocate(rid, payload["chain_len"],
                                    tokens=share)
        shared = self.alloc.shared_count(rid)
        self.shared_hits += shared
        cells = np.asarray(state["cells"], np.int64)
        for i, p in enumerate(pages):
            if i >= shared:
                self.pages[p] = cells[i]
        self.tables[slot, :] = 0
        self.tables[slot, :len(pages)] = pages
        self.pos[slot] = payload["pos"]
        self.last[slot] = payload["last"]
        self.active[slot] = payload["req"]
        if share:
            self.alloc.register_prefix(rid)
        self.migrations_in += 1

    def release_request(self, rid) -> bool:
        """Commit side of an outbound migration: free our copy. Idempotent
        (recovery may roll the same release forward twice)."""
        slot = self._migrating.pop(rid, None)
        if slot is None:
            return False
        self.alloc.free(rid)
        self.active[slot] = None
        self.tables[slot, :] = 0
        self.pos[slot] = -1
        self.migrations_out += 1
        return True

    def abort_migration(self, rid) -> bool:
        """Thaw the frozen slot — the request never left (side-effect-free
        on the request object)."""
        return self._migrating.pop(rid, None) is not None

    def abort_incoming(self, rid):
        """Target-side rollback of a (possibly partial) admission."""
        if rid not in self.alloc.owners():
            return
        for s, req in enumerate(self.active):
            if req is not None and req.rid == rid:
                self.active[s] = None
                self.tables[s, :] = 0
                self.pos[s] = -1
                break
        self.alloc.free(rid)

    def owns_request(self, rid) -> bool:
        if any(r is not None and r.rid == rid for r in self.active):
            return True
        if any(r.rid == rid for r in self.queue):
            return True
        return rid in self.alloc.owners()

    # ------------------------------------------------------------- protocol
    def bind(self, vf: VirtualFunction, state=None, *,
             flash: bool = True) -> float:
        if state is not None:
            self.pages = np.array(state["pages"], np.int64)
            self.tables = np.array(state["tables"], np.int32)
            self.pos = np.array(state["pos"], np.int64)
            self.last = np.array(state["last"], np.int64)
        key = (tuple(vf.mesh_shape), tuple(str(d) for d in vf.devices))
        self._exec_cache.setdefault(key, True)
        self.vf_id = vf.vf_id
        self.status = "running"
        vf.emulated.update({"tenant": self.tid, "status": "running",
                            "steps_done": self.steps_done})
        return 0.0

    def run_steps(self, n: int = 1) -> dict:
        if self.status == "paused":
            raise DevicePausedError(
                f"{self.tid}: device {self.vf_id} is paused")
        if self.status != "running":
            raise RuntimeError(f"{self.tid}: no device attached")
        if self._fail_next:
            self._fail_next = False
            raise RuntimeError(f"{self.tid}: injected device failure")
        for _ in range(n):
            self._engine_step()
            self.steps_done += 1
            if self.clock is not None:
                self.clock.advance(SimTenant.STEP_COST)
            self.step_times.append(SimTenant.STEP_COST)
        return {"inflight": sum(r is not None for r in self.active),
                "queued": len(self.queue)}

    def export_state(self):
        return {"pages": self.pages, "tables": self.tables,
                "pos": self.pos, "last": self.last}

    def export_specs(self):
        return {}

    def shardings_for(self, vf: VirtualFunction):
        return None

    def state_template(self):
        return jax.tree.map(np.zeros_like, {
            "pages": np.zeros((self.num_pages, self.PAGE), np.int64),
            "tables": np.zeros((self.SLOTS, self.MAX_PAGES), np.int32),
            "pos": np.zeros((self.SLOTS,), np.int64),
            "last": np.zeros((self.SLOTS,), np.int64)})

    def suspend(self):
        self.pages = self.tables = None
        self.pos = self.last = None
        self.status = "paused"

    def resume(self, state, vf: VirtualFunction):
        self.status = "running"
        self.bind(vf, state=state)

    def detach(self):
        self.pages = self.tables = None
        self.pos = self.last = None
        self.vf_id = None
        self.status = "detached"

    def query(self) -> dict:
        return {"tenant": self.tid, "status": self.status,
                "vf": self.vf_id, "steps_done": self.steps_done,
                "workload": self.workload,
                "queued": len(self.queue),
                "inflight": sum(r is not None for r in self.active),
                "migrating": sorted(self._migrating),
                "exec_keys": [list(map(str, k)) for k in self._exec_cache]}

    def inject_failure(self):
        self._fail_next = True


class SimPipelineTenant(SimServeTenant):
    """A serving tenant that LEADS a pipeline gang — the sim analogue of
    the fleet's ``PipelineServeEngine`` + shell tenants.

    The lead is a full ``SimServeTenant`` (queue, paged KV, I10 oracle)
    that additionally carries ``gang_shells``: one plain ``SimTenant``
    per extra stage, pre-built at MAX width so a grow-reshape attaches an
    existing shell instead of minting one (mirrors the fleet, where
    shells are created up to ``max_stage_width`` for headroom). Shell
    tids use a ``.`` separator (``pg0.s1``) because tids become
    RecordStore file names.

    ``apply_reshape(k)`` only moves the width pointer: the toy model's
    cells are pure functions of absolute indices, so token bit-identity
    across a reshape (I10) holds by construction here — what the sim
    adds on top is the MANAGEMENT-plane story (journaled gang ops, crash
    windows, I14 gang coherence), which is exactly what the real engine
    cannot exercise cheaply at scenario scale."""

    #: period count the stage templates partition (divisible by 1..3)
    SIM_NPER = 12

    def __init__(self, tid: str, seed: int = 0, *,
                 clock: Optional[VirtualClock] = None,
                 placement: str = "first_fit", width: int = 2,
                 max_width: int = 3, leaf_size: int = 16):
        super().__init__(tid, seed=seed, clock=clock, placement=placement)
        assert 1 <= width <= max_width <= self.SIM_NPER
        self._width = int(width)
        self.max_stage_width = int(max_width)
        self.num_periods = self.SIM_NPER
        self.reshape_count = 0
        # disjoint rid space: sv* engines mint rids from 0 and share one
        # request plane (rebalance/migration moves rids between them);
        # the gang lead never exchanges requests with them, but I13
        # keys liveness by rid across ALL serve-shaped tenants
        self._next_rid = 1_000_000
        self.gang_shells = tuple(
            SimTenant(f"{tid}.s{i}", seed=seed * 31 + i,
                      leaf_size=leaf_size, clock=clock,
                      placement=placement)
            for i in range(1, max_width))
        for sh in self.gang_shells:
            sh.lead = self

    # -- template / width protocol (manager gang ops + I14) ----------------
    @property
    def stage_width(self) -> int:
        return self._width

    def has_template(self, k: int) -> bool:
        return 1 <= k <= self.max_stage_width

    def stage_bounds(self) -> tuple:
        base, rem = divmod(self.SIM_NPER, self._width)
        bounds = [0]
        for i in range(self._width):
            bounds.append(bounds[-1] + base + (1 if i < rem else 0))
        return tuple(bounds)

    def apply_reshape(self, k: int) -> None:
        """Pure width relayout, idempotent at the current width (the
        manager's crash recovery re-applies it unconditionally)."""
        if k == self._width:
            return
        if not self.has_template(k):
            raise ValueError(f"no sim stage template for K={k}")
        self._width = int(k)
        self.reshape_count += 1


class ServeSimTenant:
    """Serving-shaped pause-protocol stub: big IMMUTABLE params plus a
    small hot cache that every decode step replaces — the exact dirty
    profile ``ServeEngine.dirty_keys`` reports. Shared by the pause-path
    benchmark (HC5) and the staging tests so both exercise one copy of
    the duck-typed tenant protocol."""

    def __init__(self, params, cache, tid: str = "serve0"):
        self.tid = tid
        self.steps_done = 0
        self.status = "running"
        self.vf_id: Optional[str] = None
        self._exec_cache: dict = {}
        self.params = params
        self.cache = cache

    def step(self):
        self.cache = self.cache + 1.0       # mutates ONLY the cache
        self.steps_done += 1

    def export_state(self):
        return {"params": self.params, "cache": self.cache}

    def export_specs(self):
        return {}

    def shardings_for(self, vf):
        return None

    def state_template(self):
        return jax.tree.map(np.zeros_like, self.export_state())

    def suspend(self):
        self.params = None
        self.cache = None
        self.status = "paused"

    def resume(self, state, vf: VirtualFunction):
        self.params, self.cache = state["params"], state["cache"]
        self.status = "running"
