"""Scenario DSL + seeded generator.

A *scenario* is a deterministic sequence of ``Op``s derived entirely from
``ScenarioConfig.seed`` — ``generate_scenario(cfg)`` called twice returns
identical tuples, so any failing run is reproducible from its seed alone
(see ``repro.sim`` package docstring). This module is SINGLE-host: one
manager, one op stream. The multi-host plane — coordinator routing,
partitions, lease handoffs — has its own op DSL and generator in
``repro.sim.federation`` (same conventions: frozen op dataclass, every
fault-rate knob defaults to 0, same-seed-same-stream).

Op kinds (the paper's management surface + fault injection):

  init     first op always: create the pool, partition into VFs, attach
           the initial tenants
  attach   bind a (new or previously detached) tenant via the scheduler
  detach   standard SR-IOV detach (state parked on disk)
  pause    SVFF pause (state staged to host RAM, devices released)
  pause_live  pre-copy live pause: the tenant keeps stepping through
           background snapshot rounds, then a short stop-and-copy; the
           harness checks the stall accounting (invariant I7) and the
           usual bit-identity on unpause (I4)
  unpause  restore a paused tenant onto its VF
  reconf   full reconfiguration cycle (grow or shrink #VF) — returns the
           Table-II timing dict the invariant checker validates
  migrate  pause -> reallocate -> unpause (straggler mitigation)
  fault    inject a device failure, then run a Supervisor round that must
           recover the tenant via migration (core/fault.py)
  step     the tenant's own workload advances N steps
  crash    kill the manager at a named crash point while it runs a
           trigger op (``repro.sim.chaos.CRASH_POINTS``), then rebuild it
           with ``SVFFManager.recover`` — the harness checks invariants
           I1-I8 plus recovery idempotence (I9) afterwards
  serve_submit  a burst of requests arrives at the serving tenant sv0
           (guest-side queueing: legal even while sv0 is paused)
  serve_step    the serving engines advance N iterations (admit + batched
           decode over paged KV); invariant I10 then checks every
           request's tokens against the no-reconfiguration oracle
  autoscale  one elastic-control-plane epoch: the harness snapshots the
           serving tenants' telemetry, runs the ``core.autoscaler``
           policy loop, and executes the planned action (attach a new
           serving tenant / detach an idle one / move queued requests
           hot->cold + migrate) through the journaled manager ops;
           invariant I11 then checks the action against the snapshot
  reshape  re-instantiate the pipeline gang lead pg0 at a new stage
           width K' through the journaled ``SVFFManager.reshape`` gang
           op (attach/detach the shell members, apply the registered
           template); invariant I14 then checks the gang's VF set
           matches the template and I10 that in-flight token streams
           crossed the width change bit-identically
  migrate_request  live-migrate one in-flight request between running
           serving engines through the journaled manager op: extract
           its KV block chain on the source, ship it through the
           staging pipeline, admit it on the target, free the source
           pages; invariant I13 then checks single ownership and I10
           that the request's token stream is unchanged (a
           CacheExhausted abort on the target is a legal, clean no-op)

The generator keeps a conservative validity model (who is running/paused/
detached, how many VFs exist) so sequences are mostly executable, and —
at ``chaos_rate`` — deliberately emits invalid ops (attach with no free
VF, detach of a paused VF, double pause, ...) to exercise the manager's
rejection atomicity: a rejected op must leave every invariant intact.
``crash_rate`` (default 0, so pre-chaos scenarios are byte-identical)
additionally emits crash ops; since every crash point has a cataloged
deterministic recovery outcome (rolled back or rolled forward), the
model tracks post-recovery state exactly and later ops stay valid.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

OP_KINDS = ("init", "attach", "detach", "pause", "pause_live", "unpause",
            "reconf", "migrate", "fault", "step", "crash",
            "serve_submit", "serve_step", "autoscale", "migrate_request",
            "reshape")

#: arrival-pattern shapes for serve_submit bursts ("bursty" is the
#: original mix and the default; the others model the traffic traces the
#: elastic control plane is benchmarked on)
ARRIVAL_PATTERNS = ("bursty", "ramp", "spike", "diurnal")


@dataclasses.dataclass(frozen=True)
class Op:
    kind: str
    tenant: Optional[str] = None
    num_vfs: Optional[int] = None
    devices_per_vf: Optional[int] = None
    num_tenants: Optional[int] = None      # init only
    steps: int = 1
    chaos: bool = False                     # expected to be rejected
    point: Optional[str] = None             # crash only: crash point name
    trigger: Optional[str] = None           # crash only: op that reaches it
    burst: int = 0                          # serve_submit only: #requests

    def __post_init__(self):
        assert self.kind in OP_KINDS, self.kind


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    seed: int = 0
    num_ops: int = 24
    num_devices: int = 16
    max_vfs: int = 6
    max_tenants: int = 5
    policy: str = "first_fit"
    leaf_size: int = 16
    chaos_rate: float = 0.08
    crash_rate: float = 0.0
    # serve-traffic ops (0 keeps pre-serve sequences byte-identical): at
    # this rate the scenario interleaves serve_submit (bursty arrivals) /
    # serve_step ops on a dedicated serving tenant "sv0" that is attached
    # right after init and participates in pause/pause_live/unpause/
    # migrate like any other tenant — invariant I10 checks its tokens
    serve_rate: float = 0.0
    # elastic control plane (0 keeps pre-autoscale sequences byte-
    # identical): at this rate — only meaningful with serve_rate > 0 —
    # the scenario emits ``autoscale`` ops; the harness runs one policy-
    # loop epoch per op and I11 checks every action it takes
    autoscale_rate: float = 0.0
    # request live migration (0 keeps earlier sequences byte-identical):
    # at this rate — only meaningful with serve_rate > 0 — the scenario
    # attaches a second serving engine "sv1" at init and emits
    # ``migrate_request`` ops; the harness deterministically picks a
    # (src, dst) pair among the running serving engines and runs the
    # journaled ``SVFFManager.migrate_request`` op (no migratable
    # request / no pair is a no-op; CacheExhausted is a clean abort)
    migrate_rate: float = 0.0
    # elastic pipeline gang (0 keeps earlier sequences byte-identical):
    # at this rate the scenario attaches a pipeline gang lead "pg0" at
    # width K=2 right after init (via the journaled attach_group) and
    # emits ``reshape`` ops that alternate its width 2<->3, interleaved
    # with serve traffic on pg0 so width changes cross in-flight token
    # streams; invariant I14 checks gang/template coherence after every
    # op. Enabled only when the VF/device budget can hold the trainers,
    # the sv engines AND the gang at max width (3 VFs)
    reshape_rate: float = 0.0
    # serve_submit burst shape (see ARRIVAL_PATTERNS): "bursty" (default,
    # the original draw), "ramp" (bursts grow across the scenario),
    # "spike" (mostly quiet with rare large bursts), "diurnal" (sinusoid)
    arrival: str = "bursty"


# weights for the op mix after init (step dominates: tenants mostly work)
_WEIGHTS = (("step", 30), ("pause", 6), ("pause_live", 6), ("unpause", 14),
            ("reconf", 10), ("attach", 10), ("detach", 6), ("migrate", 7),
            ("fault", 6))


def generate_scenario(cfg: ScenarioConfig) -> tuple[Op, ...]:
    rng = random.Random(0x5FF ^ (cfg.seed * 2654435761 % 2**31))
    ops: list[Op] = []
    serve = cfg.serve_rate > 0 and cfg.max_vfs >= 2
    mig = serve and cfg.migrate_rate > 0

    nvf = rng.randint(1, min(4, cfg.max_vfs))
    per = rng.choice([1, 2]) if cfg.num_devices >= 4 * nvf else 1
    m = rng.randint(1, nvf)
    if serve:
        # make room for the dedicated serving tenant sv0 (and, with
        # migration traffic, the target engine sv1): one/two more VFs
        # than train tenants, within BOTH the VF and the device budget
        extra = 2 if mig else 1
        nvf = min(max(nvf, m + extra), cfg.max_vfs, cfg.num_devices)
        m = min(m, nvf - extra) or 1
        if per * nvf > cfg.num_devices:
            per = 1
        if nvf < 2:
            serve = mig = False      # no room for a second VF: no sv0
        elif nvf < m + 2:
            mig = False              # no room for sv1: no migrations
    pipe = cfg.reshape_rate > 0
    if pipe:
        # the gang lead pg0 spans up to 3 VFs (width alternates 2<->3):
        # enable only when trainers + serve engines + the gang at max
        # width all fit the VF and device budgets
        sv_extra = (2 if mig else 1) if serve else 0
        want = m + sv_extra + 3
        if want <= min(cfg.max_vfs, cfg.num_devices):
            nvf = max(nvf, want)
            if per * nvf > cfg.num_devices:
                per = 1
        else:
            pipe = False
    ops.append(Op("init", num_vfs=nvf, devices_per_vf=per, num_tenants=m))

    # validity model
    running = [f"vm{i}" for i in range(m)]
    paused: list[str] = []
    detached: list[str] = []
    next_id = m
    total_vfs = nvf          # conservative lower bound (see sim README)
    if serve:
        # sv0 joins the shared validity model: pause/pause_live/unpause/
        # migrate/step pick it like any tenant; detach/fault never do
        ops.append(Op("attach", tenant="sv0"))
        ops.append(Op("serve_submit", tenant="sv0",
                      burst=rng.choice([1, 2, 3])))
        running.append("sv0")
        if mig:
            # the migration target engine: joins the shared validity
            # model like sv0 (pause/unpause/migrate/step may pick it;
            # detach/fault never do), so migrate_request ops compose
            # with live pauses and autoscaling
            ops.append(Op("attach", tenant="sv1"))
            running.append("sv1")
    gang_k = 0
    if pipe:
        # the gang lead stays OUT of the shared validity model: its
        # width changes are driven exclusively by reshape ops, never by
        # pause/detach/fault/migrate draws. ``gang_k`` tracks how many
        # VFs the gang occupies (lead + width-1 shells) so the attach /
        # reconf budgets below stay honest.
        ops.append(Op("attach", tenant="pg0"))      # harness: attach_group
        ops.append(Op("serve_submit", tenant="pg0",
                      burst=rng.choice([1, 2])))
        gang_k = 2

    def tenant_count():
        return len(running) + len(paused) + len(detached) + 0

    while len(ops) < cfg.num_ops:
        # gated on truthiness so autoscale_rate=0 draws nothing and the
        # pre-autoscale op stream stays byte-identical (same trick as
        # crash_rate below)
        if serve and cfg.autoscale_rate and \
                rng.random() < cfg.autoscale_rate:
            ops.append(Op("autoscale"))
            continue
        if gang_k and rng.random() < cfg.reshape_rate:
            # gated on gang_k truthiness so reshape_rate=0 draws nothing
            r = rng.random()
            if r < 0.4:
                k_new = 3 if gang_k == 2 else 2
                free = total_vfs - len(running) - len(paused) - gang_k
                if k_new > gang_k and free < k_new - gang_k:
                    # no idle VF for the extra shell: serve instead
                    ops.append(Op("serve_step", tenant="pg0", steps=1))
                else:
                    ops.append(Op("reshape", tenant="pg0",
                                  num_vfs=k_new))
                    gang_k = k_new
            elif r < 0.7:
                ops.append(Op("serve_submit", tenant="pg0",
                              burst=rng.choice([1, 2, 3])))
            else:
                ops.append(Op("serve_step", tenant="pg0",
                              steps=rng.randint(1, 2)))
            continue
        if mig and rng.random() < cfg.migrate_rate:
            # harness picks the (src, dst) pair deterministically among
            # the running serving engines; no pair / nothing in flight
            # is a no-op, so the op is valid regardless of model state
            ops.append(Op("migrate_request"))
            continue
        if serve and rng.random() < cfg.serve_rate:
            op = _serve_op(rng, cfg, len(ops) / max(cfg.num_ops, 1),
                           running, paused)
            if op is not None:
                ops.append(op)
                continue
        if cfg.crash_rate and rng.random() < cfg.crash_rate:
            # crash ops mutate the model per the cataloged recovery
            # outcome, so the sequence stays valid after the recovery
            # (gang VFs are subtracted so attach triggers stay reachable)
            op = _crash_op(rng, cfg, running, paused, detached,
                           total_vfs - gang_k, next_id)
            if op is not None:
                if op.trigger == "attach" and op.tenant == f"vm{next_id}":
                    next_id += 1
                ops.append(op)
                continue
        if rng.random() < cfg.chaos_rate:
            op = _chaos_op(rng, running, paused, detached, next_id)
            if op is not None:
                ops.append(op)
                continue
        kind = _weighted(rng)
        if kind == "step" and running:
            ops.append(Op("step", tenant=rng.choice(sorted(running)),
                          steps=rng.randint(1, 3)))
        elif kind in ("pause", "pause_live") and running:
            t = rng.choice(sorted(running))
            running.remove(t); paused.append(t)
            ops.append(Op(kind, tenant=t))
        elif kind == "unpause" and paused:
            t = rng.choice(sorted(paused))
            paused.remove(t); running.append(t)
            ops.append(Op("unpause", tenant=t))
        elif kind == "reconf":
            # gang members (lead + shells) hold VFs like any live tenant
            occupied = len(running) + len(paused) + gang_k
            lo = 1
            hi = cfg.max_vfs
            n = rng.randint(lo, hi)
            # budget so survivors + creations + later unpauses always fit
            p = 1 if cfg.num_devices < 2 * (n + occupied) else \
                rng.choice([1, 2])
            if p * (n + occupied) > cfg.num_devices:
                p = 1
            if n < len(running) + gang_k:    # keep every live tenant placeable
                n = (len(running) + gang_k) or 1
            ops.append(Op("reconf", num_vfs=n, devices_per_vf=p))
            total_vfs = max(n, occupied)
        elif kind == "attach":
            free = total_vfs - len(running) - len(paused) - gang_k
            if free <= 0:
                continue
            if detached and rng.random() < 0.5:
                t = rng.choice(sorted(detached))
                detached.remove(t)
            elif tenant_count() < cfg.max_tenants:
                t = f"vm{next_id}"; next_id += 1
            else:
                continue
            running.append(t)
            ops.append(Op("attach", tenant=t))
        elif kind == "detach" and _nonserve(running):
            # the serving tenant is never detached: its request plane
            # (queue/in-flight batch) lives in guest RAM, which detach
            # (unlike pause) does not preserve
            t = rng.choice(_nonserve(running))
            running.remove(t); detached.append(t)
            ops.append(Op("detach", tenant=t))
        elif kind == "migrate" and running:
            ops.append(Op("migrate", tenant=rng.choice(sorted(running))))
        elif kind == "fault" and _nonserve(running):
            ops.append(Op("fault", tenant=rng.choice(_nonserve(running))))
    return tuple(ops)


def _nonserve(tenants: list) -> list:
    return sorted(t for t in tenants if not t.startswith("sv"))


def _serve_op(rng: random.Random, cfg: ScenarioConfig, frac: float,
              running, paused) -> Optional[Op]:
    """Serve-traffic op: arrivals per ``cfg.arrival`` (the queue accepts
    even while the engine is PAUSED — the guest keeps its device) and
    engine steps (only legal while running)."""
    if "sv0" in running:
        if rng.random() < 0.55:
            return Op("serve_submit", tenant="sv0",
                      burst=_burst(rng, cfg, frac))
        return Op("serve_step", tenant="sv0", steps=rng.randint(1, 3))
    if "sv0" in paused:
        return Op("serve_submit", tenant="sv0",
                  burst=rng.choice([1, 2]))
    return None


def _burst(rng: random.Random, cfg: ScenarioConfig, frac: float) -> int:
    """Burst size for one serve_submit. ``bursty`` reproduces the original
    draw byte-for-byte; the others shape arrivals over scenario progress
    ``frac`` (the traffic traces the autoscaler is exercised against)."""
    if cfg.arrival == "ramp":
        return rng.choice([1, 2]) + int(6 * frac)      # bursty-ramp
    if cfg.arrival == "spike":
        return 12 if rng.random() < 0.12 else rng.choice([1, 1, 2])
    if cfg.arrival == "diurnal":
        import math
        base = 1 + int(4 * (0.5 - 0.5 * math.cos(2 * math.pi * frac)))
        return base + rng.choice([0, 1])
    return rng.choice([1, 1, 2, 3, 6])                 # bursty (default)


def _weighted(rng: random.Random) -> str:
    total = sum(w for _, w in _WEIGHTS)
    x = rng.randrange(total)
    for kind, w in _WEIGHTS:
        if x < w:
            return kind
        x -= w
    return "step"


def _crash_op(rng, cfg, running, paused, detached, total_vfs,
              next_id) -> Optional[Op]:
    """A crash-injection op that is guaranteed to reach its crash point,
    with the model advanced to the cataloged recovery outcome."""
    from repro.sim.chaos import CRASH_POINTS

    cands = []                       # (point, trigger, tenant | None)
    free = total_vfs - len(running) - len(paused)
    can_new = (len(running) + len(paused) + len(detached)
               < cfg.max_tenants)
    for point in sorted(CRASH_POINTS):
        spec = CRASH_POINTS[point]
        for trig in spec.triggers:
            if trig in ("pause", "pause_live") and running:
                cands.append((point, trig, rng.choice(sorted(running))))
            elif trig == "detach" and _nonserve(running):
                cands.append((point, trig, rng.choice(_nonserve(running))))
            elif trig == "unpause" and paused:
                cands.append((point, trig, rng.choice(sorted(paused))))
            elif trig == "attach" and free > 0:
                if detached and (not can_new or rng.random() < 0.5):
                    cands.append((point, trig,
                                  rng.choice(sorted(detached))))
                elif can_new:
                    cands.append((point, trig, f"vm{next_id}"))
            elif trig == "qmp":
                cands.append((point, trig, None))
            elif trig in ("migrate_request", "attach_group", "reshape"):
                # needs preconditions the validity model cannot track
                # (an in-flight request + target KV headroom, or a gang
                # lead with the right shell/VF configuration); these
                # crash windows are covered by the run_crash_case
                # matrix instead
                continue
    if not cands:
        return None
    point, trig, t = cands[rng.randrange(len(cands))]
    if CRASH_POINTS[point].outcome == "complete":
        if trig == "attach":
            if t in detached:
                detached.remove(t)
            running.append(t)
        elif trig in ("pause", "pause_live"):
            running.remove(t); paused.append(t)
        elif trig == "detach":
            running.remove(t); detached.append(t)
        elif trig == "unpause":
            paused.remove(t); running.append(t)
    return Op("crash", tenant=t, point=point, trigger=trig)


def _chaos_op(rng, running, paused, detached, next_id) -> Optional[Op]:
    """An op the manager must REJECT without corrupting state."""
    choices = []
    if paused:
        choices += [Op("detach", tenant=rng.choice(sorted(paused)),
                       chaos=True),            # paused VF can't detach
                    Op("pause", tenant=rng.choice(sorted(paused)),
                       chaos=True),            # double pause
                    Op("pause_live", tenant=rng.choice(sorted(paused)),
                       chaos=True),            # live pause of paused VF
                    Op("step", tenant=rng.choice(sorted(paused)),
                       chaos=True)]            # I/O while paused
    if running:
        choices += [Op("unpause", tenant=rng.choice(sorted(running)),
                       chaos=True),            # not paused
                    Op("attach", tenant=rng.choice(sorted(running)),
                       chaos=True)]            # already attached
    if detached:
        choices += [Op("pause", tenant=rng.choice(sorted(detached)),
                       chaos=True)]            # no VF to pause
    if not choices:
        return None
    return rng.choice(choices)
