"""Three-term roofline model for TPU v5e (the target hardware).

  compute_s    = HLO_FLOPs_per_device / peak_FLOPs
  memory_s     = HLO_bytes_per_device / HBM_bw
  collective_s = sum over collectives of shard_bytes * axis_factor / ICI_bw

cost_analysis() of the SPMD-partitioned module reports per-device flops /
bytes; collective bytes come from the HLO parse (also per-device shard
sizes). For ring-algorithm collectives over an axis of size A a device
moves ~(A-1)/A of the gathered bytes per all-gather (≈1x shard bytes * the
number of hops) — we charge shard_bytes * 2 for all-reduce (reduce-scatter
+ all-gather) and * 1 for the others; the axis-size subtlety is inside the
shard shapes already. This is a first-order model: good enough to rank
bottlenecks and steer the perf loop, and we report raw terms so readers
can re-derive.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.runtime.hlo import CollectiveStats


@dataclass(frozen=True)
class Peaks:
    """Injectable peak-rate constants. The defaults are TPU v5e per-chip
    numbers (the repo's target hardware), but benchmarks and CI gates
    pass their own — a gate on 'fraction of peak' must pin WHICH peak it
    measured against, or the number silently drifts across backends.
    ``row()``/achieved-fraction reports carry the peaks used."""
    flops: float = 197e12         # FLOP/s (bf16)
    hbm_bw: float = 819e9         # B/s
    ici_bw: float = 50e9          # B/s per link

    def row(self) -> dict:
        return {"peak_flops": self.flops, "peak_hbm_bw": self.hbm_bw,
                "peak_ici_bw": self.ici_bw}


DEFAULT_PEAKS = Peaks()

# module-level aliases kept for existing callers — canonical values live
# in Peaks so they can be overridden per Roofline / per benchmark
PEAK_FLOPS_BF16 = DEFAULT_PEAKS.flops
HBM_BW = DEFAULT_PEAKS.hbm_bw
ICI_BW = DEFAULT_PEAKS.ici_bw

_AR_FACTOR = 2.0                  # all-reduce = RS + AG


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float              # per device
    hlo_bytes: float              # per device
    collective_bytes: float       # per device
    collective_detail: dict = field(default_factory=dict)
    model_flops: float = 0.0      # 6*N*D (global, fwd+bwd) or serve analogue
    peaks: Peaks = DEFAULT_PEAKS

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / self.peaks.flops

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / self.peaks.hbm_bw

    @property
    def collective_s(self) -> float:
        by = self.collective_detail.get("bytes_by_op", {})
        t = 0.0
        for op, b in by.items():
            t += (b * (_AR_FACTOR if op == "all-reduce" else 1.0)
                  / self.peaks.ici_bw)
        if not by:
            t = self.collective_bytes / self.peaks.ici_bw
        return t

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: dominant term (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): how much compiled compute is
        'useful' (catches remat/redundancy waste)."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        denom = self.step_s * self.chips * self.peaks.flops
        return self.model_flops / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "step_s": self.step_s, "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac, "mfu": self.mfu,
            "collective_detail": self.collective_detail,
            **self.peaks.row(),
        }


def kernel_roofline(name: str, flops: float, bytes_moved: float,
                    wall_s: float, peaks: Peaks = DEFAULT_PEAKS) -> dict:
    """Achieved-vs-peak report for ONE kernel invocation (the decode-
    roofline benchmark's row shape): analytic FLOPs/bytes for the kernel,
    measured wall time, and the achieved fractions against ``peaks``.
    ``bound`` is the analytic bottleneck; ``achieved_*_frac`` is what the
    measurement actually hit — the gap between them is the kernel's
    headroom (or the host's interpret-mode overhead)."""
    compute_s = flops / peaks.flops if peaks.flops else 0.0
    memory_s = bytes_moved / peaks.hbm_bw if peaks.hbm_bw else 0.0
    ideal_s = max(compute_s, memory_s)
    return {
        "name": name,
        "flops": flops,
        "bytes": bytes_moved,
        "wall_s": wall_s,
        "ideal_s": ideal_s,
        "bound": "compute" if compute_s >= memory_s else "memory",
        "achieved_flops_per_s": flops / wall_s if wall_s else 0.0,
        "achieved_bw": bytes_moved / wall_s if wall_s else 0.0,
        "achieved_bw_frac": (bytes_moved / wall_s / peaks.hbm_bw
                             if wall_s and peaks.hbm_bw else 0.0),
        "peak_frac": ideal_s / wall_s if wall_s else 0.0,
        **peaks.row(),
    }


def measure_local_peaks(copy_mb: float = 64.0, reps: int = 3) -> Peaks:
    """Measure THIS host's achievable rates — jitted elementwise-copy
    bandwidth and a square-matmul FLOP rate — and return them as a
    ``Peaks``. CPU CI reports achieved-vs-peak fractions against the
    backend the benchmark actually ran on, not TPU datasheet numbers;
    ``ici_bw`` keeps the default (no local collective to measure)."""
    import jax
    import jax.numpy as jnp

    n = max(1, int(copy_mb * 1e6 / 4))
    x = jnp.arange(n, dtype=jnp.float32)
    copy = jax.jit(lambda a: a + 1.0)
    copy(x).block_until_ready()
    best = min(_timed(lambda: copy(x).block_until_ready())
               for _ in range(reps))
    bw = 2.0 * n * 4 / best                      # one read + one write

    m = 512
    a = jnp.ones((m, m), jnp.float32)
    mm = jax.jit(lambda u: u @ u)
    mm(a).block_until_ready()
    best = min(_timed(lambda: mm(a).block_until_ready())
               for _ in range(reps))
    fl = 2.0 * m ** 3 / best
    return Peaks(flops=fl, hbm_bw=bw, ici_bw=DEFAULT_PEAKS.ici_bw)


def _timed(fn) -> float:
    import time
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def model_flops_estimate(model_cfg, shape_cfg) -> float:
    """MODEL_FLOPS: 6*N_active*D for training; 2*N_active*tokens for
    inference steps (prefill: D=B*S tokens; decode: B tokens)."""
    n_active = model_cfg.active_param_count()
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    if shape_cfg.kind == "train":
        return 6.0 * n_active * B * S
    if shape_cfg.kind == "prefill":
        return 2.0 * n_active * B * S
    return 2.0 * n_active * B          # decode: one token per sequence
