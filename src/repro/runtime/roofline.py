"""Three-term roofline model for TPU v5e (the target hardware).

  compute_s    = HLO_FLOPs_per_device / peak_FLOPs
  memory_s     = HLO_bytes_per_device / HBM_bw
  collective_s = sum over collectives of shard_bytes * axis_factor / ICI_bw

cost_analysis() of the SPMD-partitioned module reports per-device flops /
bytes; collective bytes come from the HLO parse (also per-device shard
sizes). For ring-algorithm collectives over an axis of size A a device
moves ~(A-1)/A of the gathered bytes per all-gather (≈1x shard bytes * the
number of hops) — we charge shard_bytes * 2 for all-reduce (reduce-scatter
+ all-gather) and * 1 for the others; the axis-size subtlety is inside the
shard shapes already. This is a first-order model: good enough to rank
bottlenecks and steer the perf loop, and we report raw terms so readers
can re-derive.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.runtime.hlo import CollectiveStats

# TPU v5e constants (per chip) — task-specified
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link

_AR_FACTOR = 2.0                  # all-reduce = RS + AG


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float              # per device
    hlo_bytes: float              # per device
    collective_bytes: float       # per device
    collective_detail: dict = field(default_factory=dict)
    model_flops: float = 0.0      # 6*N*D (global, fwd+bwd) or serve analogue

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        by = self.collective_detail.get("bytes_by_op", {})
        t = 0.0
        for op, b in by.items():
            t += b * (_AR_FACTOR if op == "all-reduce" else 1.0) / ICI_BW
        if not by:
            t = self.collective_bytes / ICI_BW
        return t

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: dominant term (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): how much compiled compute is
        'useful' (catches remat/redundancy waste)."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        denom = self.step_s * self.chips * PEAK_FLOPS_BF16
        return self.model_flops / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_dev": self.hlo_flops,
            "hlo_bytes_per_dev": self.hlo_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "step_s": self.step_s, "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac, "mfu": self.mfu,
            "collective_detail": self.collective_detail,
        }


def model_flops_estimate(model_cfg, shape_cfg) -> float:
    """MODEL_FLOPS: 6*N_active*D for training; 2*N_active*tokens for
    inference steps (prefill: D=B*S tokens; decode: B tokens)."""
    n_active = model_cfg.active_param_count()
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    if shape_cfg.kind == "train":
        return 6.0 * n_active * B * S
    if shape_cfg.kind == "prefill":
        return 2.0 * n_active * B * S
    return 2.0 * n_active * B          # decode: one token per sequence
