"""Distributed-optimization collectives: int8-compressed gradient
all-reduce (beyond-paper; OptimizerConfig.grad_compression="int8").

Classic quantized ring all-reduce is re-expressed TPU-natively as
reduce-scatter (full precision within the shard reduction) followed by an
int8-quantized all-gather: each device owns an exact fp32 partial for its
shard, packs it with the qdma_pack blockwise quantizer, and gathers the
packed shards. Only the GATHER phase is lossy (one quantization per value
— error is NOT accumulated across devices like naive quantized rings).

Payload on the wire: ~4x smaller for the gather phase; the reduce-scatter
phase stays exact, so total bytes ≈ (1 + 1/4)/2 of a plain fp32
all-reduce. Used by examples / available to the trainer for DP meshes;
the dry-run default keeps the paper-faithful exact path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import axis_size, shard_map


def _pack(x, block):
    from repro.kernels import ops as kops
    return kops.qdma_pack(x, block=block)


def _unpack(q, s, dtype):
    from repro.kernels import ops as kops
    return kops.qdma_unpack(q, s, dtype=dtype)


def compressed_psum_mean(x: jax.Array, axis: str, *, block: int = 256):
    """Mean over ``axis`` with an int8-compressed gather phase.

    Call INSIDE shard_map. x: any shape; flattened internally to
    (n_dev, -1) rows padded to a block multiple.
    """
    n = axis_size(axis)
    flat = x.astype(jnp.float32).reshape(-1)
    per = -(-flat.size // n)                    # ceil
    per = -(-per // block) * block              # block multiple
    pad = n * per - flat.size
    flat = jnp.pad(flat, (0, pad))
    rows = flat.reshape(n, per)
    # exact reduce-scatter: each device ends with the true mean of its row
    mine = jax.lax.psum_scatter(rows, axis, scatter_dimension=0,
                                tiled=False) / n
    # lossy gather: quantize my exact shard once, gather packed shards
    q, s = _pack(mine.reshape(1, per), block=block)
    qg = jax.lax.all_gather(q, axis, axis=0)        # (n, 1, per) int8
    sg = jax.lax.all_gather(s, axis, axis=0)
    out = _unpack(qg.reshape(n, per), sg.reshape(n, per // block),
                  "float32")
    return out.reshape(-1)[:x.size].reshape(x.shape).astype(x.dtype)


def compressed_grad_allreduce(stacked_grads, mesh: Mesh,
                              axis: str = "data", block: int = 256):
    """Tree-wise compressed mean over per-replica gradients.

    stacked_grads: pytree whose leaves have a leading replica dim equal to
    the DP axis size (sharded over ``axis``). Returns the replica mean,
    replicated. Tiny leaves (< 4 blocks) use an exact pmean — compression
    overhead isn't worth the bytes there.
    """
    n = mesh.shape[axis]

    def inner(gs):
        def one(g):
            g = g[0]                              # my replica's partial
            if g.size < 4 * block:
                return jax.lax.pmean(g, axis)
            return compressed_psum_mean(g, axis, block=block)
        return jax.tree.map(one, gs)

    in_specs = (jax.tree.map(lambda _: P(axis), stacked_grads),)
    out_specs = jax.tree.map(lambda _: P(), stacked_grads)
    return shard_map(inner, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)(stacked_grads)
