"""Sharding context + partition rules.

Model code never names mesh axes directly: it calls ``constrain(x, kind)``
with a *logical* kind ("hidden", "logits", ...). The active
``ShardingRules`` (installed by the step builder / dry-run via
``sharding_scope``) resolves kinds to PartitionSpecs for the current mesh,
with divisibility fallbacks so the same model code runs on the unit mesh
(CPU tests), the single-pod 16x16 mesh, and the multi-pod 2x16x16 mesh.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import keystr
from repro.configs.base import MeshConfig, RunConfig

_TLS = threading.local()


def current_rules() -> Optional["ShardingRules"]:
    return getattr(_TLS, "rules", None)


@contextlib.contextmanager
def sharding_scope(rules: Optional["ShardingRules"]):
    prev = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield
    finally:
        _TLS.rules = prev


class ShardingRules:
    """Resolves logical activation kinds and parameter paths to specs."""

    def __init__(self, mesh_cfg: MeshConfig, run_cfg: RunConfig,
                 mesh: Optional[Mesh] = None):
        self.mesh_cfg = mesh_cfg
        self.run = run_cfg
        self.mesh = mesh
        self.axis_size = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
        self.dp_axes = mesh_cfg.data_axes           # e.g. ("pod", "data")
        self.model_axis = "model" if "model" in mesh_cfg.axes else None
        self.fsdp_axes = self.dp_axes if run_cfg.sharding.fsdp else ()

    def attn_mode(self, num_heads=None) -> str:
        """'heads' when kv heads divide the model axis, else 'seq'."""
        kv = self.run.model.num_kv_heads
        m = self.axis_size.get("model", 1)
        if m <= 1:
            return "heads"
        if kv % m == 0 and (num_heads is None or num_heads % m == 0):
            return "heads"
        return "seq"

    # -- helpers -----------------------------------------------------------
    def _size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.axis_size.get(a, 1) for a in axes]))

    def _fit(self, dim: int, axes):
        """Return ``axes`` if ``dim`` is divisible by their product else None."""
        if not axes:
            return None
        sz = self._size(axes)
        if sz <= 1:
            return None
        if dim % sz != 0:
            return None
        if isinstance(axes, tuple) and len(axes) == 1:
            return axes[0]
        return axes

    def spec(self, kind: str, shape) -> P:
        """Activation PartitionSpec by logical kind."""
        dp = tuple(self.dp_axes)
        mdl = self.model_axis
        if kind == "batch":          # (B, S) token ids
            return P(self._fit(shape[0], dp), None)
        if kind == "hidden":         # (B, S, D)
            sp = (mdl if (self.run.sharding.seq_shard_acts and mdl) else None)
            return P(self._fit(shape[0], dp),
                     self._fit(shape[1], (sp,) if sp else ()), None)
        if kind == "hidden_full":    # (B, S, D) gathered for TP matmuls
            if not self.run.sharding.seq_shard_acts:
                raise KeyError(kind)     # no-op unless SP mode (constrain
                                         # returns x unchanged)
            return P(self._fit(shape[0], dp), None, None)
        if kind == "logits":         # (B, S, V) or (B, V)
            if self.run.sharding.seq_shard_acts and mdl and len(shape) == 3:
                # SP: logits sequence-sharded, vocab local -> softmax/CE
                # fully local (lm_head is replicated over model in SP mode)
                return P(self._fit(shape[0], dp),
                         self._fit(shape[1], (mdl,)), None)
            v_ax = self._fit(shape[-1], (mdl,) if mdl else ())
            if len(shape) == 3:
                return P(self._fit(shape[0], dp), None, v_ax)
            return P(self._fit(shape[0], dp), v_ax)
        if kind == "attn_q":         # (B, S, H, hd) — q/o inside attention
            # Heads-TP when the kv heads divide the model axis (classic
            # Megatron); otherwise sequence-parallel attention: q sharded
            # on S, k/v replicated over model — the (S,T) logits stay
            # LOCAL. Without this, GSPMD may shard the hd contraction and
            # all-reduce the quadratic logits tensor (§Perf iteration 2).
            dpq = self._fit(shape[0], dp)
            if self.attn_mode(shape[2]) == "heads":
                return P(dpq, None, self._fit(shape[2], (mdl,)), None)
            return P(dpq, self._fit(shape[1], (mdl,) if mdl else ()),
                     None, None)
        if kind == "attn_kv":        # (B, T, K, hd)
            dpq = self._fit(shape[0], dp)
            if self.attn_mode(None) == "heads":
                return P(dpq, None, self._fit(shape[2], (mdl,)), None)
            return P(dpq, None, None, None)
        if kind == "kv_cache":       # (B, S, K, h) — decode cache
            b_ax = self._fit(shape[0], dp)
            if b_ax is None and self.run.sharding.shard_kv_seq:
                # batch too small (long_500k): shard sequence over everything
                all_ax = tuple(a for a in (*dp, mdl) if a)
                return P(None, self._fit(shape[1], all_ax), None, None)
            seq_ax = (self._fit(shape[1], (mdl,) if mdl else ())
                      if self.run.sharding.shard_kv_seq else None)
            return P(b_ax, seq_ax, None, None)
        if kind == "state":          # (B, ...) recurrent state
            return P(self._fit(shape[0], dp), *([None] * (len(shape) - 1)))
        if kind == "expert":         # (E, G, C, D) MoE expert inputs
            return P(self._fit(shape[0], (mdl,) if mdl else ()),
                     self._fit(shape[1], dp), None, None)
        if kind == "moe_mask":       # (G, sg, E) routing one-hots
            return P(self._fit(shape[0], dp), None,
                     self._fit(shape[2], (mdl,) if mdl else ()))
        if kind == "moe_counts":     # (G, E)
            return P(self._fit(shape[0], dp),
                     self._fit(shape[1], (mdl,) if mdl else ()))
        if kind == "moe_dispatch":   # (G, sg, E, C) dispatch/combine
            # E sharded over model from CONSTRUCTION: both dispatch einsums
            # and (critically) their transposes then stay local on the
            # model axis — otherwise bwd gathers the full-E dispatch
            # cotangent (~17 GB/layer on arctic; §Perf HC2 it.4)
            return P(self._fit(shape[0], dp), None,
                     self._fit(shape[2], (mdl,) if mdl else ()), None)
        raise KeyError(kind)

    # -- parameters --------------------------------------------------------
    # Rules matched (first hit) against '/'-joined path suffixes. %F = fsdp
    # axes, %M = model axis. Specs are for the LOGICAL (unstacked) leaf;
    # period-stacked leaves get a leading None.
    PARAM_RULES = [
        (r"embed/tok$",            ("%M", None)),
        (r"lm_head$",              ("%F", "%M")),
        (r"(wq|wk|wv|xq|xk|xv)$",  ("%F", "%M")),
        (r"(wo|xo)$",              ("%M", "%F")),
        (r"ffn/(wi|wg)$",          ("%F", "%M")),
        (r"ffn/wo$",               ("%M", "%F")),
        (r"moe/router$",           (None, None)),
        (r"moe/(wi|wg)$",          ("%M", "%F", None)),
        (r"moe/wo$",               ("%M", None, "%F")),
        (r"in_proj$",              ("%F", "%M")),
        (r"out_proj$",             ("%M", "%F")),
        (r"conv_w$",               (None, "%M")),
        (r"conv_b$",               ("%M",)),
        (r"w_up$",                 ("%F", "%M")),
        (r"w_out$",                ("%M", "%F")),
        (r"(w_i|w_f)$",            ("%F", None)),
        (r"(w_z|w_o)$",            ("%F", "%M")),
        (r"(r_z|r_i|r_f|r_o)$",    (None, None, None)),
        (r"up_(wi|wg)$",           ("%F", "%M")),
        (r"up_wo$",                ("%M", "%F")),
    ]

    def param_spec(self, path: str, shape) -> P:
        stacked = "/layers/" in path           # period-stacked leaf
        logical = shape[1:] if stacked else shape
        spec: list = [None] * len(logical)
        if self.run.sharding.seq_shard_acts and re.search(r"lm_head$", path):
            # SP mode: lm_head vocab-replicated so logits stay seq-sharded
            return P(self._fit(logical[0], self.fsdp_axes), None)
        for pat, axes in self.PARAM_RULES:
            if re.search(pat, path):
                for i, a in enumerate(axes):
                    if a == "%F":
                        spec[i] = self._fit(logical[i], self.fsdp_axes)
                    elif a == "%M":
                        spec[i] = self._fit(
                            logical[i], (self.model_axis,)
                            if self.model_axis else ())
                    else:
                        spec[i] = None
                break
        if stacked:
            spec = [None] + spec
        return P(*spec)

    def param_specs(self, tree) -> dict:
        def one(path, leaf):
            p = keystr(path, simple=True, separator="/")
            return self.param_spec(p, leaf.shape)
        return jax.tree_util.tree_map_with_path(one, tree)

    def named(self, spec_tree):
        assert self.mesh is not None
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """Apply a logical sharding constraint if a scope is active (no-op on
    the unit mesh / in plain CPU tests)."""
    rules = current_rules()
    if rules is None or rules.mesh_cfg.num_devices <= 1:
        return x
    try:
        spec = rules.spec(kind, x.shape)
    except KeyError:
        return x
    if rules.mesh is not None:
        spec = NamedSharding(rules.mesh, spec)
    return jax.lax.with_sharding_constraint(x, spec)
