"""HLO text analysis: collective-byte accounting for the roofline.

``compiled.cost_analysis()`` has no collective term, so we parse the
optimized (post-SPMD-partitioning) HLO and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Shapes in the partitioned module are PER-DEVICE shard shapes, so the sums
are bytes-per-device; collective time ~ bytes_per_device / link_bw (ring
algorithms move O(shard bytes) per device per hop-step, see
runtime/roofline.py for the axis-size factor).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# matches e.g.  bf16[16,256,448]{2,1,0}  or  f32[]  (layout part optional)
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# result side of an HLO instruction: "  %name = <result-type> op-name(...)"
_INSTR_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|[a-z0-9_\[\],{}\s/]*?)\s*"
    r"(?P<op>" + "|".join(COLLECTIVE_OPS) + r")(?:-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=lambda: defaultdict(int))
    count_by_op: dict = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def describe(self) -> dict:
        return {"bytes_by_op": dict(self.bytes_by_op),
                "count_by_op": dict(self.count_by_op),
                "total_bytes": self.total_bytes,
                "total_count": self.total_count}


_DEF_RE = re.compile(r"%(?P<name>[\w.\-]+)\s*=\s*(?P<rest>.*)")
_OPERANDS_RE = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)*)\)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_COMP_SIG_RE = re.compile(r"^%?([\w.\-]+)\s+\(([^)]*)\)\s*->", re.M)


def _build_defs(hlo_text: str) -> dict:
    defs = {}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = _DEF_RE.match(ls)
        if m:
            defs[m.group("name")] = m.group("rest")
    return defs


def _comp_param_dtypes(hlo_text: str) -> dict:
    out = {}
    for m in _COMP_SIG_RE.finditer(hlo_text):
        out[m.group(1)] = re.findall(
            r":\s*(" + "|".join(_DTYPE_BYTES) + r")\[", m.group(2))
    return out


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective instruction.

    Result bytes bound what a device receives (gather-like); they equal
    operand bytes for all-reduce / all-to-all / permute. '-start/-done'
    async pairs are counted once.

    CPU-lowering correction: the XLA:CPU SPMD pipeline hoists bf16->f32
    converts ABOVE reshard collectives (TPU keeps them in bf16), doubling
    apparent payloads. An f32 collective whose operand is a convert(-fusion)
    fed by bf16 is charged at bf16 width.
    """
    stats = CollectiveStats()
    defs = _build_defs(hlo_text)
    comp_params = _comp_param_dtypes(hlo_text)
    for line in hlo_text.splitlines():
        if "-done(" in line:      # async completion: already counted
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        rtype = m.group("rtype")
        nbytes = _shape_bytes(rtype)
        if nbytes == 0:
            nbytes = _shape_bytes(line.split("(")[0])
        # --- convert-hoist correction ---------------------------------
        if "f32[" in rtype:
            om = _OPERANDS_RE.search(line[m.end("op"):])
            ops_ = (om.group(1).replace("%", "").split(", ")
                    if om and om.group(1) else [])
            for opr in ops_:
                d = defs.get(opr.strip(), "")
                if "convert" in opr or "convert" in d[:80]:
                    cm = _CALLS_RE.search(d)
                    fed_bf16 = ("bf16[" in d or (
                        cm and "bf16" in "".join(
                            comp_params.get(cm.group(1), []))))
                    if fed_bf16 or "convert" in opr:
                        nbytes //= 2
                        break
        stats.bytes_by_op[op] += nbytes
        stats.count_by_op[op] += 1
    return stats


def scan_op_counts(hlo_text: str, ops=("fusion", "custom-call", "while",
                                       "copy", "transpose")) -> dict:
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"\b{op}\(", hlo_text))
    return out
