"""Distributed runtime: partitioning, HLO analysis, roofline, pipeline PP."""
