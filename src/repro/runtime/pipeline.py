"""Optional pipeline parallelism: a GPipe-style microbatched pipeline over
a dedicated "pipe" mesh axis, built on shard_map + collective_permute.

Not used by the fixed production meshes (axes pod/data/model — see
DESIGN.md §3); provided for deployments that trade a mesh axis for
pipeline stages (e.g. very deep models across slower inter-slice links).

The schedule is plain GPipe: M microbatches flow through S stages in
M + S - 1 ticks; each tick every stage computes its resident microbatch
and the activations rotate one hop with collective_permute. Bubble
fraction = (S-1)/(M+S-1), reported by ``bubble_fraction``.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def bubble_fraction(num_microbatches: int, num_stages: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def pipeline_apply(stage_fn: Callable, stage_params, x, mesh: Mesh,
                   axis: str = "pipe"):
    """Run ``y = stage_{S-1}(...stage_0(x))`` as a GPipe pipeline.

    stage_fn: (params_for_one_stage, (mb, ...)) -> (mb, ...)   same shape
    stage_params: pytree with leading dim S (one slice per stage), sharded
                  over ``axis``
    x: (M, mb, ...) microbatches (replicated over ``axis``)
    Returns (M, mb, ...) outputs (replicated).
    """
    S = mesh.shape[axis]
    M = x.shape[0]

    def per_stage(params, xs):
        params = jax.tree.map(lambda p: p[0], params)   # local stage slice
        idx = jax.lax.axis_index(axis)
        T = M + S - 1
        state = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(t, carry):
            state, outs = carry
            # stage 0 ingests microbatch t (while available)
            mb_in = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), axis=0, keepdims=False)
            inp = jnp.where(idx == 0, mb_in, state)
            y = stage_fn(params, inp)
            # the last stage emits microbatch t-(S-1)
            ot = t - (S - 1)
            valid = (idx == S - 1) & (ot >= 0) & (ot < M)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(ot, 0, M - 1), axis=0),
                lambda o: o, outs)
            state = jax.lax.ppermute(y, axis, perm)
            return state, outs

        _, outs = jax.lax.fori_loop(0, T, tick, (state, outs))
        # only the last stage holds real outputs; share them around
        outs = jax.lax.psum(
            jnp.where(idx == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    others = tuple(None for _ in range(x.ndim - 1))
    xspec = P(*((None,) + others))
    fn = shard_map(per_stage, mesh=mesh, in_specs=(pspec, xspec),
                   out_specs=xspec, check_vma=False)
    return fn(stage_params, x)
