"""Optional pipeline parallelism: a GPipe-style microbatched pipeline over
a dedicated "pipe" mesh axis, built on shard_map + collective_permute.

Not used by the fixed production meshes (axes pod/data/model — see
DESIGN.md §3); provided for deployments that trade a mesh axis for
pipeline stages (e.g. very deep models across slower inter-slice links).

The schedule is plain GPipe: M microbatches flow through S stages in
M + S - 1 ticks; each tick every stage computes its resident microbatch
and the activations rotate one hop with collective_permute. Bubble
fraction = (S-1)/(M+S-1), reported by ``bubble_fraction``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def bubble_fraction(num_microbatches: int, num_stages: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def serve_schedule(num_microbatches: int, num_stages: int):
    """The GPipe work-item order for the HOST-side serving pipeline
    (``repro.serve.pipeline_engine``): (stage, microbatch) pairs in tick
    order, tick t = stage + microbatch. Executing items in this order
    satisfies both dependencies of item (s, m) — (s-1, m) ran at tick
    t-1 (activation hand-off) and (s, m-1) ran at tick t-1 (the stage's
    KV cache threads through its own microbatches)."""
    M, S = num_microbatches, num_stages
    for t in range(M + S - 1):
        for s in range(S):
            m = t - s
            if 0 <= m < M:
                yield s, m


@dataclasses.dataclass(frozen=True)
class ScheduleStats:
    """Measured pipeline utilization from per-item wall times.

    ``walls[s][m]`` is the measured wall of work item (stage s,
    microbatch m). The makespan is the GPipe critical path —
    finish(s, m) = max(finish(s-1, m), finish(s, m-1)) + walls[s][m] —
    and the measured bubble fraction is the idle share of the S-stage
    schedule area: 1 - sum(walls) / (S * makespan). With uniform walls
    this reduces exactly to ``bubble_fraction(M, S)``; with real walls
    it is the number the autoscaler's width actions should be justified
    by, not the analytic one."""
    num_stages: int
    num_microbatches: int
    makespan: float
    busy: float
    stage_busy: tuple

    @property
    def bubble(self) -> float:
        if self.makespan <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.busy /
                   (self.num_stages * self.makespan))


def schedule_stats(walls) -> ScheduleStats:
    """Fold per-item walls (list of S lists of M floats) into
    ``ScheduleStats`` via the GPipe finish-time recurrence."""
    S = len(walls)
    M = len(walls[0]) if S else 0
    finish = [[0.0] * M for _ in range(S)]
    for s, m in serve_schedule(M, S):
        up = finish[s - 1][m] if s > 0 else 0.0
        left = finish[s][m - 1] if m > 0 else 0.0
        finish[s][m] = max(up, left) + walls[s][m]
    makespan = finish[S - 1][M - 1] if S and M else 0.0
    stage_busy = tuple(float(sum(row)) for row in walls)
    return ScheduleStats(num_stages=S, num_microbatches=M,
                         makespan=float(makespan),
                         busy=float(sum(stage_busy)),
                         stage_busy=stage_busy)


def pipeline_apply(stage_fn: Callable, stage_params, x, mesh: Mesh,
                   axis: str = "pipe"):
    """Run ``y = stage_{S-1}(...stage_0(x))`` as a GPipe pipeline.

    stage_fn: (params_for_one_stage, (mb, ...)) -> (mb, ...)   same shape
    stage_params: pytree with leading dim S (one slice per stage), sharded
                  over ``axis``
    x: (M, mb, ...) microbatches (replicated over ``axis``)
    Returns (M, mb, ...) outputs (replicated).
    """
    S = mesh.shape[axis]
    M = x.shape[0]

    def per_stage(params, xs):
        params = jax.tree.map(lambda p: p[0], params)   # local stage slice
        idx = jax.lax.axis_index(axis)
        T = M + S - 1
        state = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(t, carry):
            state, outs = carry
            # stage 0 ingests microbatch t (while available)
            mb_in = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), axis=0, keepdims=False)
            inp = jnp.where(idx == 0, mb_in, state)
            y = stage_fn(params, inp)
            # the last stage emits microbatch t-(S-1)
            ot = t - (S - 1)
            valid = (idx == S - 1) & (ot >= 0) & (ot < M)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(ot, 0, M - 1), axis=0),
                lambda o: o, outs)
            state = jax.lax.ppermute(y, axis, perm)
            return state, outs

        _, outs = jax.lax.fori_loop(0, T, tick, (state, outs))
        # only the last stage holds real outputs; share them around
        outs = jax.lax.psum(
            jnp.where(idx == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    others = tuple(None for _ in range(x.ndim - 1))
    xspec = P(*((None,) + others))
    fn = shard_map(per_stage, mesh=mesh, in_specs=(pspec, xspec),
                   out_specs=xspec, check_vma=False)
    return fn(stage_params, x)
