PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast chaos chaos-fast bench bench-pause bench-sweep \
	bench-chaos bench-serve bench-elastic bench-prefix bench-migration \
	bench-roofline bench-pipeline bench-federation

test:            ## full tier-1 suite
	$(PYTHON) -m pytest -x -q

test-fast:       ## fast gate (skips @slow subprocess tests)
	$(PYTHON) -m pytest -x -q -m "not slow"

chaos:           ## full crash matrix via pytest (what CI runs on main)
	SVFF_CHAOS_FULL=1 $(PYTHON) -m pytest -x -q -m chaos

chaos-fast:      ## PR-gate crash matrix subset
	$(PYTHON) -m pytest -x -q -m chaos

bench: bench-pause bench-sweep bench-chaos bench-serve bench-elastic \
	bench-prefix bench-migration bench-roofline \
	bench-pipeline bench-federation  ## regenerate BENCH_*.json

bench-pause:
	$(PYTHON) benchmarks/pause_path.py --repeats 3 --out BENCH_pause_path.json

bench-sweep:
	$(PYTHON) benchmarks/scenario_sweep.py --scenarios 50 \
	    --out BENCH_scenario_sweep.json

bench-chaos:     ## the crash-matrix artifact (points x seeds x policies)
	$(PYTHON) benchmarks/crash_matrix.py --seeds 20 \
	    --out BENCH_crash_matrix.json

bench-serve:     ## serve-plane hot path (paged vs dense, live-pause p95)
	$(PYTHON) benchmarks/serve_path.py --repeats 2 \
	    --out BENCH_serve_path.json

bench-elastic:   ## static vs autoscaled fleet on ramp/spike/diurnal traces
	$(PYTHON) benchmarks/elastic_sweep.py --out BENCH_elastic.json

bench-prefix:    ## shared-prefix capacity ratio (CoW sharing vs copy-on-admit)
	$(PYTHON) benchmarks/prefix_share.py --out BENCH_prefix_share.json

bench-migration: ## request live migration (zero loss, stall, scale-in ITL)
	$(PYTHON) benchmarks/migration.py --out BENCH_migration.json

bench-roofline:  ## achieved-vs-peak bandwidth per decode kernel variant
	$(PYTHON) benchmarks/decode_roofline.py --out BENCH_decode_roofline.json

bench-pipeline:  ## K-VF pipeline engines (bit-identity, bubble, reshape)
	$(PYTHON) benchmarks/pipeline_serve.py --out BENCH_pipeline_serve.json

bench-federation: ## 8-host lease-routed fleet (exactly-once, bit-identity)
	$(PYTHON) benchmarks/federation.py --out BENCH_federation.json
