PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-pause bench-sweep

test:            ## full tier-1 suite
	$(PYTHON) -m pytest -x -q

test-fast:       ## fast gate (skips @slow subprocess tests)
	$(PYTHON) -m pytest -x -q -m "not slow"

bench: bench-pause bench-sweep   ## regenerate the BENCH_*.json artifacts

bench-pause:
	$(PYTHON) benchmarks/pause_path.py --repeats 3 --out BENCH_pause_path.json

bench-sweep:
	$(PYTHON) benchmarks/scenario_sweep.py --scenarios 50 \
	    --out BENCH_scenario_sweep.json
