"""Quickstart: the SVFF framework in ~40 lines.

Creates a device pool, partitions it into VFs, attaches two tenant training
jobs, pauses one through the QMP control plane while the pool is
reconfigured, and shows the guest's view throughout.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import json
import tempfile

from repro.configs import make_run_config
from repro.core import (ControlPlane, DevicePausedError, DevicePool,
                        SVFFManager, Tenant)


def main():
    run = make_run_config("qwen3-0.6b", "train_4k", smoke=True)
    pool = DevicePool()
    mgr = SVFFManager(pool, workdir=tempfile.mkdtemp(prefix="svff_qs_"))
    qmp = ControlPlane(mgr)

    # --- init: rescan, carve 4 VFs, flash, attach two VMs ------------------
    vms = [Tenant("vm0", run, local_batch=2, seq_len=32, seed=0),
           Tenant("vm1", run, local_batch=2, seq_len=32, seed=1)]
    mgr.init(num_vfs=4, tenants=vms, devices_per_vf=2)
    print("pool:", json.dumps(qmp.execute(
        {"execute": "query-vfs"})["return"], indent=1)[:400], "...")

    for vm in vms:
        m = vm.run_steps(3)
        print(f"{vm.tid}: 3 steps, loss={m['loss']:.3f}")

    # --- pause vm0 via QMP (the paper's device_pause command) --------------
    r = qmp.execute({"execute": "device_pause", "arguments": {"id": "vm0"}})
    print("device_pause ->", json.dumps(r["return"]["timings"]))
    print("vm0 guest view while paused:", vms[0].query()["status"],
          "| still sees VF:", vms[0].query()["vf"])
    try:
        vms[0].run_steps(1)
    except DevicePausedError as e:
        print("I/O while paused correctly refused:", e)

    # vm1 is untouched the whole time
    vms[1].run_steps(2)

    # --- unpause; vm0 continues where it left off ---------------------------
    qmp.execute({"execute": "device_pause",
                 "arguments": {"id": "vm0", "pause": False}})
    m = vms[0].run_steps(2)
    print(f"vm0 resumed: steps_done={vms[0].steps_done}, "
          f"loss={m['loss']:.3f}")

    # --- full reconfiguration cycle (Table II timings) ----------------------
    t = mgr.reconf(num_vfs=4, devices_per_vf=2)
    print("reconf timings (ms):",
          {k: round(v * 1000, 1) for k, v in t.items()})
    for vm in vms:
        vm.run_steps(1)
    print("all tenants live after reconf:",
          [(vm.tid, vm.steps_done) for vm in vms])


if __name__ == "__main__":
    main()
