"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps with checkpointing, through the real launch/train.py path.

The config is the qwen3-0.6b architecture scaled to ~100M params (same
family: GQA, qk_norm, SwiGLU, tied embeddings). On CPU this takes a while;
pass --steps to shorten. On a TPU slice the same file runs unmodified with
the full shape.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import dataclasses
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.configs.base as B
from repro.configs import register


def qwen3_100m() -> B.ModelConfig:
    # ~100M params: 12L x 640d, GQA 10H/kv2, d_ff 1920, 32k vocab
    return B.ModelConfig(
        name="qwen3-100m", family="dense",
        num_layers=12, d_model=640, num_heads=10, num_kv_heads=2,
        d_ff=1920, vocab_size=32000, head_dim=64, qk_norm=True,
        tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--workdir",
                    default=tempfile.mkdtemp(prefix="train100m_"))
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    register("qwen3-100m", qwen3_100m, qwen3_100m)
    cfg = qwen3_100m()
    print(f"model: {cfg.name}, params={cfg.param_count()/1e6:.1f}M")

    from repro.launch.train import main as train_main
    rc = train_main([
        "--arch", "qwen3-100m", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--lr", "1e-3", "--warmup", "20",
        "--workdir", args.workdir, "--checkpoint-every", "50",
        "--log-every", "10",
    ] + (["--resume"] if args.resume else []))
    lines = [json.loads(l) for l in
             open(os.path.join(args.workdir, "metrics.jsonl"))]
    print(f"loss: {lines[0]['loss']:.3f} -> {lines[-1]['loss']:.3f} "
          f"over {len(lines)} steps; checkpoints in {args.workdir}/ckpt")
    return rc


if __name__ == "__main__":
    sys.exit(main())
