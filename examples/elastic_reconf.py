"""Elastic reconfiguration — the paper's headline scenario, plus the
beyond-paper elasticity it enables:

 1. one tenant trains on a 2-device VF;
 2. demand arrives: the pool is reconfigured to add a second tenant —
    the first tenant is PAUSED (not detached: its guest keeps the device)
    and unpaused on the new layout, continuing bit-identically;
 3. the second tenant leaves; the first is elastically scaled UP to all 8
    devices via pause -> repartition -> unpause, with its train state
    resharded onto the larger mesh automatically;
 4. a straggler/failure is injected and the Supervisor migrates the tenant
    using the same pause machinery (fault tolerance = reconfiguration).

Run:  PYTHONPATH=src python examples/elastic_reconf.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile

import numpy as np
import jax

from repro.configs import make_run_config
from repro.core import DevicePool, SVFFManager, Supervisor, Tenant


def params_fingerprint(tn):
    leaf = jax.tree.leaves(tn.export_state()["params"])[1]
    return np.asarray(leaf).sum()


def main():
    run = make_run_config("qwen3-0.6b", "train_4k", smoke=True)
    pool = DevicePool()
    mgr = SVFFManager(pool, workdir=tempfile.mkdtemp(prefix="svff_el_"))

    # 1. single tenant on 2 devices
    a = Tenant("vmA", run, local_batch=2, seq_len=32, seed=0)
    mgr.init(num_vfs=4, tenants=[a], devices_per_vf=2)
    a.run_steps(3)
    print(f"[1] vmA on {pool.find(a.vf_id).mesh_shape} "
          f"steps={a.steps_done}")

    # 2. add a second tenant without disturbing vmA's guest
    fp_before = params_fingerprint(a)
    b = Tenant("vmB", run, local_batch=2, seq_len=32, seed=1)
    mgr.tenants["vmB"] = b
    t = mgr.reconf(num_vfs=4, new_tenants=[b], devices_per_vf=2)
    assert abs(params_fingerprint(a) - fp_before) < 1e-6
    a.run_steps(1)
    b.run_steps(1)
    print(f"[2] reconf added vmB in {t['total']*1000:.0f}ms; "
          f"vmA state preserved, both running")

    # 3. vmB leaves; scale vmA up to all 8 devices
    mgr.detach(b)
    mgr.pause(a)
    pool.set_num_vfs(1, devices_per_vf=8)
    mgr.unpause(a, num_devices=8)
    a.run_steps(2)
    print(f"[3] vmA elastically rescaled to "
          f"{pool.find(a.vf_id).mesh_shape} (8 devices), "
          f"steps={a.steps_done} — state was resharded on unpause")

    # 4. failure -> supervisor migrates via pause machinery.
    #    Scale vmA back to 4 devices so 4 stay free as spares.
    mgr.pause(a)
    pool.set_num_vfs(2, devices_per_vf=4)
    mgr.unpause(a, num_devices=4)
    sup = Supervisor(mgr)
    a.inject_failure()
    sup.run_round(1)
    print(f"[4] injected failure -> events: "
          f"{[e['kind'] for e in sup.events]}; vmA back at "
          f"steps={a.steps_done} on {pool.find(a.vf_id).mesh_shape}")


if __name__ == "__main__":
    main()
