"""Serve a small model with batched requests under the SVFF manager —
including a live pool reconfiguration mid-serving: the engine is paused
(requests keep queueing, nothing is dropped), the pool is repartitioned,
and serving resumes.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.configs import make_run_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(run, params, slots=4, max_len=64)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, run.model.vocab_size,
                                        int(rng.integers(4, 10))),
                    max_new_tokens=6)
            for i in range(10)]
    for r in reqs[:6]:
        eng.submit(r)

    t0 = time.perf_counter()
    for _ in range(4):              # serve a few waves
        eng.step()

    # --- reconfiguration arrives mid-serving -------------------------------
    eng.pause()
    print(f"[pause] engine paused after {time.perf_counter()-t0:.2f}s; "
          f"{sum(r.done for r in reqs)} done, queue keeps accepting:")
    for r in reqs[6:]:
        eng.submit(r)               # requests arrive WHILE paused
    print(f"        queued while paused: {len(eng.queue)}")
    time.sleep(0.1)                 # (the pool would repartition here)
    eng.unpause()

    steps = 0
    while (eng.step() or eng.queue) and steps < 500:
        steps += 1
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    dt = time.perf_counter() - t0
    print(f"[done] {done}/{len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s), {steps} decode steps after resume")
    assert done == len(reqs)


if __name__ == "__main__":
    main()
