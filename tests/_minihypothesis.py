"""Deterministic micro-fallback for `hypothesis` (see conftest.py).

The real dependency is declared in pyproject's test extra; containers
without it still run tests/test_properties.py through this shim, which
implements ONLY what those tests use: ``given`` with keyword strategies,
``settings(max_examples=..., deadline=...)`` as a decorator, and the
``integers`` / ``sampled_from`` strategies. Example draws are seeded per
test name, so runs are deterministic; a failing draw reports its
falsifying example like hypothesis would (without shrinking).
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def sampled_from(seq) -> _Strategy:
    items = list(seq)
    return _Strategy(lambda r: items[r.randrange(len(items))])


def booleans() -> _Strategy:
    return _Strategy(lambda r: bool(r.randrange(2)))


class settings:
    def __init__(self, max_examples: int = 100, deadline=None, **_):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._mh_settings = self
        return fn


def given(**strategies):
    def deco(fn):
        cfg = getattr(fn, "_mh_settings", settings())

        @functools.wraps(fn)
        def wrapper(*args, **kw):
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for _ in range(cfg.max_examples):
                vals = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **vals, **kw)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__name__}): "
                        f"{vals}") from e

        # strategy params are filled here, not by pytest fixtures — hide
        # the wrapped signature from collection
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def install():
    """Register this shim as the `hypothesis` package in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.booleans = booleans
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
