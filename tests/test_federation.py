"""Federation: lease-based cross-host control plane + network faults.

Covers the PR-10 surface end to end: TTL leases and heartbeat renewal,
cross-host admission routing (``choose_host`` over replicated
snapshots), epoch fencing across coordinator handoffs, journaled
cross-host request migration (including the partition-during-migrate
deferral, both window shapes), the network-fault chaos matrix
(I15/I16), journal auto-compaction under recovery (satellite), the
canonical typed-error hierarchy exports (satellite), and the
interleaved-journal-replay fingerprint property.
"""
import os

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import (AdmissionError, DoubleFreeError, Fabric,
                        FederationCoordinator, FederationError,
                        GangPlacementError, Host, HostCandidate,
                        HostUnreachableError, LeaseExpiredError,
                        ManagerError, SplitBrainError, SVFFManager,
                        UnknownRequestError, UnknownTenantError,
                        choose_host)
from repro.core.autoscaler import (Autoscaler, AutoscaleConfig,
                                   EngineStats, TelemetrySnapshot,
                                   justify_action)
from repro.sim.clock import VirtualClock
from repro.sim.federation import (FedScenarioConfig, LEASE_TTL,
                                  NETWORK_FAULTS, build_fed_cell,
                                  federation_fingerprint,
                                  generate_fed_scenario,
                                  network_fault_matrix, run_fed_scenario,
                                  run_network_fault_case)
from repro.sim.invariants import check_federation, check_invariants
from repro.sim.tenant import SimServeTenant

HSET = settings(max_examples=15, deadline=None)


# ---------------------------------------------------------------------------
# choose_host policies
# ---------------------------------------------------------------------------
def _cands():
    return [HostCandidate("a", load=4, capacity=8),
            HostCandidate("b", load=1, capacity=8),
            HostCandidate("c", load=6, capacity=8)]


def test_choose_host_policies():
    assert choose_host("first_fit", _cands()).host_id == "a"
    # best_fit: tightest remaining headroom that still fits
    assert choose_host("best_fit", _cands()).host_id == "c"
    # fair_share: most headroom
    assert choose_host("fair_share", _cands()).host_id == "b"


def test_choose_host_respects_need_and_rejects_typed():
    cands = [HostCandidate("a", load=7, capacity=8),
             HostCandidate("b", load=5, capacity=8)]
    assert choose_host("first_fit", cands, need=2).host_id == "b"
    with pytest.raises(AdmissionError):
        choose_host("first_fit", cands, need=4)
    with pytest.raises(Exception):
        choose_host("no_such_policy", cands)


# ---------------------------------------------------------------------------
# leases + heartbeats
# ---------------------------------------------------------------------------
def test_lease_grant_expiry_renewal(tmp_path):
    cell = build_fed_cell(0, workdir=str(tmp_path))
    co, clock = cell["coordinator"], cell["clock"]
    assert co.live_hosts() == ["h0", "h1", "h2"]
    clock.advance(LEASE_TTL + 0.1)
    assert co.live_hosts() == []          # all lapsed, nobody renewed
    with pytest.raises(LeaseExpiredError):
        co.migrate_request("h0", "h1")
    beat = co.heartbeat_all()
    assert beat["renewed"] == ["h0", "h1", "h2"]
    assert co.live_hosts() == ["h0", "h1", "h2"]
    # replicated snapshots are re-stamped by the renewal
    assert all(s["pulled_at"] == beat["t"] for s in co.snapshots.values())


def test_partitioned_host_keeps_aging_lease(tmp_path):
    cell = build_fed_cell(1, workdir=str(tmp_path))
    co, clock, fabric = (cell["coordinator"], cell["clock"],
                         cell["fabric"])
    fabric.partition([co.node_id, "h1", "h2"], ["h0"])
    clock.advance(1.0)
    co.heartbeat_all()
    # h0 unreachable: lease not renewed but not yet lapsed either
    assert "h0" in co.live_hosts()
    clock.advance(LEASE_TTL - 0.5)
    assert "h0" not in co.live_hosts()
    assert {"h1", "h2"} <= set(co.live_hosts())


# ---------------------------------------------------------------------------
# epoch fencing / split brain
# ---------------------------------------------------------------------------
def test_epoch_fence_monotone(tmp_path):
    clock = VirtualClock()
    h = Host("hx", workdir=str(tmp_path), clock=clock)
    h.check_epoch(3)
    h.check_epoch(3)                      # same epoch fine
    h.check_epoch(5)                      # newer adopted
    with pytest.raises(SplitBrainError):
        h.check_epoch(4)
    assert h.fence_epoch == 5
    assert h.telemetry.fenced == 1


def test_handoff_fences_old_coordinator(tmp_path):
    cell = build_fed_cell(2, workdir=str(tmp_path))
    co = cell["coordinator"]
    r_old = co.submit(seed=7)
    succ = co.handoff()
    assert succ.epoch == co.epoch + 1
    assert all(h.fence_epoch == succ.epoch
               for h in cell["hosts"])
    # stale coordinator: every host rejects it, its lease book drains
    with pytest.raises((AdmissionError, SplitBrainError)):
        co.submit(seed=7)
    # epoch-salted rid spaces never collide across the handoff
    r_new = succ.submit(seed=7)
    assert r_new["rid"] != r_old["rid"]
    assert r_new["rid"] // 1_000_000_000 == succ.epoch
    check_federation(cell["hosts"], [succ, co])


# ---------------------------------------------------------------------------
# cross-host request migration (no faults)
# ---------------------------------------------------------------------------
def test_cross_host_migrate_roundtrip_token_identical(tmp_path):
    cell = build_fed_cell(4, workdir=str(tmp_path))
    co, hosts = cell["coordinator"], cell["hosts"]
    subs = [co.submit(seed=11) for _ in range(3)]
    res = max(subs, key=lambda r: SimServeTenant.make_max_new(
        11, r["rid"]))
    src = next(h for h in hosts if h.host_id == res["host"])
    for tn in src.serve_targets():
        tn.run_steps(1)
    dst_id = "h1" if res["host"] != "h1" else "h2"
    out = co.migrate_request(res["host"], dst_id, res["rid"])
    assert out["rid"] == res["rid"]
    assert co.residency[res["rid"]] == dst_id
    dst = next(h for h in hosts if h.host_id == dst_id)
    assert dst.owner_engine(res["rid"]) is not None
    assert src.owner_engine(res["rid"]) is None
    check_federation(hosts, [co])
    for host in hosts:
        check_invariants(host.mgr)
    # drain everywhere; the migrated stream must equal its oracle
    for _ in range(40):
        for host in hosts:
            for tn in host.serve_targets():
                tn.run_steps(1)
    want = SimServeTenant.expected_output(11, res["rid"])
    # the request OBJECT stays in the source engine's history list
    # (extraction copies state, not bookkeeping) while the destination
    # drives it to completion — search fleet-wide
    got = next(r for host in hosts for tn in host.serve_targets()
               for r in tn.requests if r.rid == res["rid"])
    assert got.done and list(got.out) == want


def test_submit_exactly_once_and_reroute(tmp_path):
    cell = build_fed_cell(5, workdir=str(tmp_path))
    co = cell["coordinator"]
    res = co.submit(seed=3)
    with pytest.raises(FederationError):
        co.submit(rid=res["rid"], seed=3)
    # cut the chosen host at routing time: same rid lands elsewhere
    cell["fabric"].arm("fed_submit_route",
                       [co.node_id, "h1", "h2"], ["h0"])
    res2 = co.submit(seed=3)
    assert res2["host"] != "h0" and not res2["in_doubt"]
    owners = [h.host_id for h in cell["hosts"]
              if h.owner_engine(res2["rid"]) is not None]
    assert owners == [res2["host"]]


# ---------------------------------------------------------------------------
# the network-fault matrix (fast subset always on; full under chaos/CI)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("window", sorted(NETWORK_FAULTS))
def test_network_fault_window_recovers(window):
    for seed in range(3):
        res = run_network_fault_case(window, seed)
        assert res["ok"], res


def test_partition_during_migrate_regression():
    """Regression seed for the in-doubt distributed commit: the
    partition lands AFTER the remote admit, the journal entry defers,
    and recovery must roll FORWARD (dst serves, src frees exactly once)
    — rolling back would dual-serve the request (I15)."""
    res = run_network_fault_case("fed_migrate_after_admit", 0)
    assert res["ok"] and res["outcome"] == "defer_forward"


@pytest.mark.chaos
def test_network_fault_matrix_fast():
    out = network_fault_matrix(seeds=range(5))
    assert out["summary"]["num_failures"] == 0


@pytest.mark.chaos
@pytest.mark.slow
@pytest.mark.skipif(os.environ.get("SVFF_CHAOS_FULL") != "1",
                    reason="full network-fault matrix runs on main "
                           "(CI chaos job sets SVFF_CHAOS_FULL=1)")
def test_network_fault_matrix_full():
    """Acceptance: every window x >= 10 seeds, zero failures."""
    out = network_fault_matrix(seeds=range(10))
    assert out["summary"]["num_failures"] == 0
    assert out["summary"]["num_cases"] == len(NETWORK_FAULTS) * 10


# ---------------------------------------------------------------------------
# federation scenarios
# ---------------------------------------------------------------------------
def test_fed_scenario_deterministic():
    cfg = FedScenarioConfig(seed=9, num_ops=30)
    assert generate_fed_scenario(cfg) == generate_fed_scenario(cfg)
    a = run_fed_scenario(cfg)
    b = run_fed_scenario(cfg)
    assert a["fingerprint"] == b["fingerprint"]


def test_fed_scenario_zero_rates_have_no_faults():
    ops = generate_fed_scenario(FedScenarioConfig(seed=2, num_ops=60))
    kinds = {op.kind for op in ops}
    assert kinds <= {"init", "submit", "step", "beat"}


@pytest.mark.parametrize("seed", range(4))
def test_fed_scenario_fault_soup(seed):
    r = run_fed_scenario(FedScenarioConfig(
        seed=seed, num_ops=35, partition_rate=0.15, crash_rate=0.1,
        handoff_rate=0.05, migrate_rate=0.15, autoscale_rate=0.1))
    assert r["submitted"] >= 1


# ---------------------------------------------------------------------------
# property: interleaved journal replay reconciles to one fingerprint
# ---------------------------------------------------------------------------
@given(order=st.sampled_from([("h0", "h1"), ("h1", "h0"),
                              ("h0", "h1", "h0"), ("h1", "h1", "h0")]),
       seed=st.integers(0, 7))
@HSET
def test_interleaved_recovery_fingerprint(order, seed):
    """Two hosts carry journal entries (one a DEFERRED cross-host
    migrate); replaying their recoveries in ANY interleaving — including
    repeats — reconciles the federation to the same fingerprint (I16)."""
    import shutil
    import tempfile
    wd = tempfile.mkdtemp(prefix="svff_fed_prop_")
    try:
        _interleaved_recovery_body(wd, order, seed)
    finally:
        shutil.rmtree(wd, ignore_errors=True)


def _interleaved_recovery_body(wd, order, seed):
    cell = build_fed_cell(seed, workdir=wd)
    co, fabric = cell["coordinator"], cell["fabric"]
    subs = [co.submit(seed=seed) for _ in range(3)]
    res = max(subs, key=lambda r: SimServeTenant.make_max_new(
        seed, r["rid"]))
    src = next(h for h in cell["hosts"] if h.host_id == res["host"])
    for tn in src.serve_targets():
        tn.run_steps(1)
    dst_id = "h1" if res["host"] != "h1" else "h2"
    fabric.arm("fed_migrate_after_admit",
               [co.node_id] + [h.host_id for h in cell["hosts"]
                               if h.host_id != dst_id], [dst_id])
    with pytest.raises(HostUnreachableError):
        co.migrate_request(res["host"], dst_id, res["rid"])
    fabric.heal()
    # canonical single full recovery fixes the reference fingerprint
    co.recover()
    want = federation_fingerprint(cell["hosts"], co)
    # any further interleaving of per-host recoveries is a no-op
    for hid in order:
        co.recover([hid])
        assert federation_fingerprint(cell["hosts"], co) == want
    check_federation(cell["hosts"], [co])


# ---------------------------------------------------------------------------
# satellite: journal auto-compaction stays recovery-green
# ---------------------------------------------------------------------------
def test_journal_auto_compaction_recovery_green(tmp_path):
    from repro.core.journal import OpJournal
    from repro.core.pool import DevicePool
    from repro.core.staging import StagingEngine
    from repro.sim.chaos import recover_manager, state_fingerprint
    clock = VirtualClock()
    wd = str(tmp_path)
    pool = DevicePool(devices=tuple(f"cd{i}" for i in range(8)),
                      max_vfs=4)
    journal = OpJournal(os.path.join(wd, "journal"),
                        compact_every=6, compact_keep=4)
    mgr = SVFFManager(pool, staging=StagingEngine(num_queues=2),
                      workdir=wd, scheduler="first_fit", journal=journal)
    tenants = [SimServeTenant(f"hc.sv{j}", seed=j, clock=clock)
               for j in range(2)]
    mgr.init(num_vfs=3, tenants=tenants, devices_per_vf=2)
    # 22 journaled ops against a 6/keep-4 auto-compaction window
    for i in range(10):
        tn = tenants[i % 2]
        mgr.pause(tn)
        mgr.unpause(tn)
    entries = list(journal.iter_entries())
    assert len(entries) <= 10, \
        f"auto-compaction never bounded the WAL ({len(entries)} entries)"
    assert journal.pending() == []
    check_invariants(mgr)                          # I8 after compaction
    # I9: recovery over the compacted journal is an idempotent no-op
    before = state_fingerprint(mgr)
    mgr2 = recover_manager(mgr, {tn.tid: tn for tn in tenants},
                           policy="first_fit", workdir=wd)
    check_invariants(mgr2)
    assert state_fingerprint(mgr2) == before


# ---------------------------------------------------------------------------
# satellite: canonical typed-error hierarchy
# ---------------------------------------------------------------------------
def test_error_hierarchy_exports():
    import repro.core.errors as errors
    import repro.serve.paged as paged
    # historic homes re-export the SAME classes (no parallel hierarchies)
    assert paged.DoubleFreeError is DoubleFreeError
    assert paged.UnknownRequestError is UnknownRequestError
    assert DoubleFreeError is errors.DoubleFreeError
    # federation errors sit under ManagerError, so existing catch-alls
    # over manager ops keep working across the federation lift
    for exc in (FederationError, HostUnreachableError,
                LeaseExpiredError, SplitBrainError):
        assert issubclass(exc, ManagerError)
    assert issubclass(UnknownTenantError, ManagerError)
    assert issubclass(GangPlacementError, AdmissionError)
    assert SVFFManager is not None                 # canonical home import


# ---------------------------------------------------------------------------
# stale telemetry: the autoscaler's age arm (I11 lift)
# ---------------------------------------------------------------------------
def _snap(age, load=6):
    return TelemetrySnapshot(
        epoch=1, slo_max_load=6, free_vfs=1, age_s=age,
        engines=(EngineStats(tid="e0", index=0, status="running",
                             load=load),))


def test_stale_snapshot_suppresses_and_freezes_streaks():
    sc = Autoscaler(AutoscaleConfig(hysteresis=2, cooldown=0,
                                    max_staleness_s=2.0))
    assert sc.observe(_snap(0.0)) is None          # streak 1 of 2
    # stale epochs neither act nor advance the hot streak
    for _ in range(5):
        assert sc.observe(_snap(3.0)) is None
    assert sc._hot_streak == 1
    act = sc.observe(_snap(0.0))                   # streak 2 -> acts
    assert act is not None and act.kind == "scale_out"
    assert justify_action(act, sc.cfg) is None


def test_justify_rejects_stale_planned_action():
    cfg = AutoscaleConfig(max_staleness_s=2.0)
    from repro.core.autoscaler import AutoscaleAction
    act = AutoscaleAction("scale_out", _snap(5.0))
    err = justify_action(act, cfg)
    assert err is not None and "stale" in err


def test_metricsbus_replicate_is_stamped():
    from repro.serve.telemetry import MetricsBus
    bus = MetricsBus()
    rep = bus.replicate(12.5)
    assert rep["stamp"] == 12.5
    assert "engines" in rep and "rejected_recent" in rep
