"""PR-8 surfaces: in-kernel fused sampling (bit-identity against the
host oracle, invariant I10), int8-quantized paged KV (tolerance-bounded
parity against fp), the nearest-rank percentile fix, typed allocator
errors, Request temperature validation, and injectable roofline peaks."""
import math
import os
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import make_run_config
from repro.models.model import build_model
from repro.serve import (Request, ServeEngine, ServeFleet,
                         UnknownRequestError, percentile)
from repro.serve.paged import BlockAllocator

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks"))


@pytest.fixture(scope="module")
def setup():
    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    return run, model, params


# ===========================================================================
# percentile: ceil-based nearest rank (banker's-rounding regression)
# ===========================================================================
@pytest.mark.parametrize("n", range(2, 22))
def test_percentile_nearest_rank_exact(n):
    """Canonical nearest-rank over 1..n is the value ceil(q*n) — checked
    by DEFINITION for every window size the autoscaler actually sees, not
    against the implementation's own formula. The old round()-based index
    broke .5 ties toward even (p50 of n=4 picked rank 3, not 2)."""
    import serve_path
    xs = list(range(1, n + 1))
    rng = np.random.default_rng(n)
    shuffled = list(rng.permutation(xs))
    for q in (0.5, 0.9, 0.95, 0.99):
        want = min(n, math.ceil(q * n))
        assert percentile(shuffled, q) == want, (n, q)
        assert serve_path.pct(shuffled, q) == want, (n, q)


def test_percentile_banker_rounding_regression():
    # old round(q*(n-1)) code: round(1.5) = 2 -> the 3rd smallest; the
    # canonical nearest rank for p50 of n=4 is ceil(2) = 2 -> the 2nd
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.5) == 2.0
    assert percentile([], 0.5) == 0.0
    assert percentile([3.0], 0.95) == 3.0


# ===========================================================================
# typed allocator errors (UnknownRequestError)
# ===========================================================================
def test_extend_and_cow_unknown_rid_raise_typed_error():
    alloc = BlockAllocator(num_pages=8, page_size=4)
    alloc.allocate(1, 2)
    with pytest.raises(UnknownRequestError):
        alloc.extend(42, 1)
    with pytest.raises(UnknownRequestError):
        alloc.cow(42, 0)
    assert isinstance(UnknownRequestError("x"), RuntimeError)


def test_unknown_rid_surfaces_through_engine_lazy_growth(setup):
    """Only CacheExhausted is swallowed (admission backoff); a control-
    plane bug — the engine extending a rid the allocator no longer owns —
    must crash loudly through step(), not decode into page 0."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=1, max_len=48, paged=True,
                      page_size=4)
    req = Request(rid=0, prompt=np.arange(6) % 100, max_new_tokens=12)
    eng.submit(req)
    eng.step()                                    # admit + first decode
    eng.alloc.free(req.rid)                       # simulated stale slot map
    with pytest.raises(UnknownRequestError):
        for _ in range(12):
            eng.step()


# ===========================================================================
# Request temperature validation (the dead-clamp satellite)
# ===========================================================================
def test_request_rejects_subnormal_temperature():
    for bad in (1e-7, 5e-9, 9.9e-7):
        with pytest.raises(ValueError):
            Request(rid=0, prompt=[1, 2], max_new_tokens=1,
                    temperature=bad)
    # the boundary and greedy cases are all valid
    Request(rid=0, prompt=[1, 2], max_new_tokens=1, temperature=0.0)
    Request(rid=1, prompt=[1, 2], max_new_tokens=1, temperature=1e-6)
    Request(rid=2, prompt=[1, 2], max_new_tokens=1, temperature=-1.0)


# ===========================================================================
# kernels: int8 paged decode parity, fused sampling bit-identity
# ===========================================================================
def _paged_inputs(key, B=3, NP=3, page=8, H=4, K=2, hd=16):
    ks = jax.random.split(key, 4)
    P = 1 + B * NP
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, K, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, K, hd), jnp.float32)
    tables = (1 + jnp.arange(B * NP, dtype=jnp.int32)).reshape(B, NP)
    pos = jnp.asarray([NP * page - 1, page + 3, -1], jnp.int32)[:B]
    return q, kp, vp, tables, pos


def test_paged_decode_int8_parity_with_fp():
    from repro.kernels import ops
    from repro.kernels.ref import kv_quant_ref
    q, kp, vp, tables, pos = _paged_inputs(jax.random.key(1))
    want = ops.paged_decode(q, kp, vp, tables, pos, backend="ref")
    kq, ksc = kv_quant_ref(kp)
    vq, vsc = kv_quant_ref(vp)
    got = ops.paged_decode_quant(q, kq, vq, ksc, vsc, tables, pos,
                                 backend="ref")
    # int8 is lossy: bounded by the quantization step, not exact
    assert jnp.max(jnp.abs(got - want)) < 0.05
    # pos=-1 row (no valid tokens) is exactly zero on both paths
    if q.shape[0] >= 3:
        assert jnp.all(got[2] == 0)


def test_paged_decode_quant_kernel_matches_ref():
    from repro.kernels.paged_decode import paged_decode_quant
    from repro.kernels.ref import kv_quant_ref, paged_decode_quant_ref
    q, kp, vp, tables, pos = _paged_inputs(jax.random.key(2))
    kq, ksc = kv_quant_ref(kp)
    vq, vsc = kv_quant_ref(vp)
    want = paged_decode_quant_ref(q, kq, vq, ksc, vsc, tables, pos)
    got = paged_decode_quant(q, kq, vq, ksc, vsc, tables, pos,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_kv_quant_dequant_roundtrip_is_idempotent():
    """Migration invariant: dequantize -> requantize reproduces the same
    int8 bytes (row max lands exactly on +-127), so a request migrated
    out of an int8 pool and re-admitted is bit-identical."""
    from repro.kernels.ref import kv_dequant_ref, kv_quant_ref
    x = jax.random.normal(jax.random.key(3), (4, 8, 2, 16), jnp.float32)
    q1, s1 = kv_quant_ref(x)
    q2, s2 = kv_quant_ref(kv_dequant_ref(q1, s1, jnp.float32))
    assert jnp.array_equal(q1, q2)
    assert jnp.array_equal(s1, s2)


@pytest.mark.parametrize("temp,top_k", [(0.0, 0), (1e-6, 0), (0.7, 1),
                                        (0.7, 8), (1.3, 0), (2.5, 512)])
def test_fused_sample_bit_identical_to_host_oracle(setup, temp, top_k):
    """I10's oracle is ServeEngine._sample (host numpy); the fused kernel
    (ref lowering AND Pallas interpret) must reproduce it bit-for-bit —
    same argmax index, every row, greedy and noisy alike."""
    from repro.kernels import ops
    from repro.kernels.sampling import fused_sample as pallas_fused
    run, model, params = setup
    eng = ServeEngine(run, params, slots=1, max_len=48)
    V = run.model.vocab_size
    B, Vp = 5, V + 8                              # padded vocab tail
    logits = np.asarray(jax.random.normal(jax.random.key(4), (B, Vp)),
                        np.float32)
    reqs = [Request(rid=100 + i, prompt=[1], max_new_tokens=1,
                    temperature=temp, top_k=top_k, seed=7 + i)
            for i in range(B)]
    for i, r in enumerate(reqs):
        r.out = [0] * i                           # distinct counters
    want = [eng._sample(r, logits[i]) for i, r in enumerate(reqs)]

    lt = jnp.full((B,), temp, jnp.float32)
    lk = jnp.full((B,), top_k, jnp.int32)
    keys = jnp.asarray([[r.seed, r.rid, len(r.out)] for r in reqs],
                       jnp.int32)
    got_ref = ops.fused_sample(jnp.asarray(logits), lt, lk, keys,
                               vocab_size=V, backend="ref")
    got_pl = pallas_fused(jnp.asarray(logits), lt, lk, keys,
                          vocab_size=V, interpret=True)
    assert [int(t) for t in got_ref] == want
    assert [int(t) for t in got_pl] == want


# ===========================================================================
# engines: fused/int8 streams == host-sampled streams (I10 composed)
# ===========================================================================
def _serve(run, params, reqs_fn, **kw):
    eng = ServeEngine(run, params, slots=2, max_len=48, paged=True,
                      page_size=8, **kw)
    reqs = reqs_fn()
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    assert all(r.done and not r.error for r in reqs)
    return [r.out for r in reqs]


def _mixed_reqs():
    return [Request(rid=i, prompt=(np.arange(4 + i) * (i + 1)) % 100,
                    max_new_tokens=6,
                    temperature=0.8 if i % 2 else 0.0,
                    top_k=16 if i % 2 else 0, seed=5 + i)
            for i in range(4)]


def test_fused_engine_streams_bit_identical_to_host(setup):
    run, model, params = setup
    host = _serve(run, params, _mixed_reqs)
    fused = _serve(run, params, _mixed_reqs, fused_sampling=True)
    assert fused == host


def test_int8_fused_streams_match_int8_host(setup):
    """int8 KV perturbs logits, so its oracle is the host-sampled int8
    twin — same quantized cache, sampling on the host."""
    run, model, params = setup
    host = _serve(run, params, _mixed_reqs, kv_dtype="int8")
    fused = _serve(run, params, _mixed_reqs, kv_dtype="int8",
                   fused_sampling=True)
    assert fused == host


def test_i10_int8_fused_prefix_sharing_through_pause_live(setup):
    """The composed I10 regression: int8 KV + fused sampling + prefix
    sharing, served THROUGH a fleet pause_live/unpause, must emit the
    same token streams as the same engine with no reconfiguration."""
    run, model, params = setup
    shared = (np.arange(9) * 3) % 100             # trie-shared prefix

    def reqs_fn():
        return [Request(rid=i, prompt=np.concatenate([shared, [i]]),
                        max_new_tokens=6,
                        temperature=0.8 if i % 2 else 0.0,
                        top_k=16 if i % 2 else 0, seed=5 + i)
                for i in range(4)]

    kw = dict(slots=2, max_len=48, paged=True, page_size=8,
              kv_dtype="int8", fused_sampling=True, share_prefix=True)

    def fleet_serve(pause):
        fleet = ServeFleet(run, params, num_engines=1, num_devices=2,
                           workdir=tempfile.mkdtemp(), **kw)
        reqs = reqs_fn()
        for r in reqs:
            fleet.submit(r)
        for _ in range(2):
            fleet.step()
        if pause:
            fleet.pause_live("serve0", rounds=2)
            fleet.unpause("serve0")
        res = fleet.drain()
        assert res.drained and all(r.done and not r.error for r in reqs)
        return [r.out for r in reqs]

    oracle = fleet_serve(pause=False)
    assert fleet_serve(pause=True) == oracle
    # and the plain engine (no fleet loop) agrees too
    assert _serve(run, params, reqs_fn, kv_dtype="int8",
                  fused_sampling=True, share_prefix=True) == oracle


# ===========================================================================
# roofline: peaks are injectable, defaults preserved
# ===========================================================================
def test_roofline_peaks_injectable():
    from repro.runtime.roofline import (DEFAULT_PEAKS, HBM_BW,
                                        PEAK_FLOPS_BF16, Peaks,
                                        kernel_roofline)
    assert PEAK_FLOPS_BF16 == DEFAULT_PEAKS.flops
    assert HBM_BW == DEFAULT_PEAKS.hbm_bw
    slow = Peaks(flops=1e9, hbm_bw=1e9)
    r = kernel_roofline("k", flops=1e9, bytes_moved=1e9, wall_s=1.0,
                        peaks=slow)
    assert r["achieved_bw_frac"] == pytest.approx(1.0)
    assert r["peak_hbm_bw"] == 1e9
    d = kernel_roofline("k", flops=1e9, bytes_moved=1e9, wall_s=1.0)
    assert d["peak_hbm_bw"] == DEFAULT_PEAKS.hbm_bw
    assert d["achieved_bw_frac"] == pytest.approx(1e9 / DEFAULT_PEAKS.hbm_bw)
