"""Model substrate tests: per-arch smoke (reduced config, fwd + train step,
shape + finite checks) and the prefill/decode vs teacher-forced-forward
consistency contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, list_archs, make_run_config
from repro.models.model import build_model
from repro.train.step import init_train_state, make_train_step

ARCHS = [a for a in list_archs() if a != "svff-bench"]


def tiny_batch(run, B=2, S=16, key=0):
    cfg = run.model
    rng = jax.random.key(key)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend.kind == "vision":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.frontend.num_patches, cfg.d_model),
            jnp.float32).astype(jnp.bfloat16)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            rng, (B, max(1, S // cfg.frontend.frame_ratio), cfg.d_model),
            jnp.float32).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one train step on the reduced config; asserts output
    shapes and no NaNs (the assignment's per-arch smoke contract)."""
    run = make_run_config(arch, "train_4k", smoke=True)
    cfg = run.model
    model = build_model(run)
    batch = tiny_batch(run)
    state = init_train_state(run, jax.random.key(0))
    logits, aux, _ = jax.jit(
        lambda p, b: model.forward(p, b, "train"))(state["params"], batch)
    B, S = batch["tokens"].shape
    extra = cfg.frontend.num_patches if cfg.frontend.kind == "vision" else 0
    assert logits.shape[0] == B and logits.shape[1] == S + extra
    assert logits.shape[2] >= cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf in logits"

    step = jax.jit(make_train_step(run))
    state2, metrics = step(state, batch)
    assert int(state2["step"]) == 1
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    # params actually changed (update may be tiny under warmup)
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(state2["params"])[0]
    assert float(np.abs(np.asarray(d0, np.float32) -
                        np.asarray(d1, np.float32)).max()) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_near_uniform_at_init(arch):
    """CE at random init should be close to ln(vocab) — catches scaling
    bugs (systematically hot/cold logits)."""
    run = make_run_config(arch, "train_4k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    loss, metrics = jax.jit(model.loss)(params, tiny_batch(run, B=4, S=32))
    expect = np.log(run.model.vocab_size)
    assert abs(float(metrics["ce"]) - expect) < 0.45 * expect


def _pad_kv(cache, S):
    def one(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v"):
            return jnp.pad(x, ((0, 0), (0, 0), (0, S - x.shape[2]),
                               (0, 0), (0, 0)))
        return x
    return jax.tree_util.tree_map_with_path(one, cache)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "llama3-8b", "olmoe-1b-7b",
                                  "xlstm-350m", "jamba-1.5-large-398b",
                                  "internvl2-1b", "seamless-m4t-medium",
                                  "phi3-mini-3.8b"])
def test_prefill_decode_matches_forward(arch):
    """prefill(S0) + decode steps == teacher-forced forward logits."""
    run = make_run_config(arch, "train_4k", smoke=True)
    cfg = run.model
    model = build_model(run)
    params = model.init(jax.random.key(0))
    B, S, S0 = 2, 16, 8
    batch = tiny_batch(run, B=B, S=S, key=1)
    full, _, _ = jax.jit(
        lambda p, b: model.forward(p, b, "train"))(params, batch)
    npatch = cfg.frontend.num_patches if cfg.frontend.kind == "vision" else 0

    pre = dict(batch)
    pre.pop("labels")
    pre["tokens"] = batch["tokens"][:, :S0]
    cache, last = jax.jit(model.prefill)(params, pre)
    cache = _pad_kv(cache, S + npatch)
    errs = [float(jnp.max(jnp.abs(last - full[:, npatch + S0 - 1])))]
    dec = jax.jit(model.decode_step)
    for t in range(S0, S):
        lg, cache = dec(params, cache, batch["tokens"][:, t:t + 1],
                        jnp.int32(npatch + t))
        errs.append(float(jnp.max(jnp.abs(lg - full[:, npatch + t]))))
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert max(errs) < 0.05 * max(scale, 1.0), (arch, errs)


def test_vector_pos_decode_matches_scalar():
    """Per-slot positions (continuous batching) == scalar pos when equal."""
    run = make_run_config("qwen3-0.6b", "train_4k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    batch = tiny_batch(run, B=2, S=8)
    pre = {"tokens": batch["tokens"]}
    cache, _ = jax.jit(model.prefill)(params, pre)
    cache = _pad_kv(cache, 16)
    tok = batch["tokens"][:, :1]
    lg_s, _ = jax.jit(model.decode_step)(params, cache, tok, jnp.int32(8))
    lg_v, _ = jax.jit(model.decode_step)(params, cache, tok,
                                         jnp.full((2,), 8, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v),
                               atol=1e-5, rtol=1e-5)


def test_scan_vs_unrolled_equivalence():
    """Period-scanned stack == python-loop stack (same params)."""
    run = make_run_config("jamba-1.5-large-398b", "train_4k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    batch = tiny_batch(run, B=2, S=16)
    l1, _, _ = jax.jit(lambda p, b: model.forward(p, b, "train"))(
        params, batch)
    run2 = dataclasses.replace(
        run, sharding=dataclasses.replace(run.sharding, scan_layers=False))
    model2 = build_model(run2)
    l2, _, _ = jax.jit(lambda p, b: model2.forward(p, b, "train"))(
        params, batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=2e-2,
                               rtol=2e-2)


def test_labels_masking():
    run = make_run_config("qwen3-0.6b", "train_4k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    batch = tiny_batch(run, B=2, S=16)
    batch["labels"] = batch["labels"].at[:, 8:].set(-1)
    loss, m = jax.jit(model.loss)(params, batch)
    assert int(m["ntok"]) == 2 * 8
    assert np.isfinite(float(loss))
