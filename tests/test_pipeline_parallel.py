"""Pipeline-parallel wrapper: GPipe schedule == sequential composition."""
import json
import os
import subprocess
import sys

import pytest

from repro.runtime.pipeline import bubble_fraction

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_bubble_fraction():
    assert bubble_fraction(1, 4) == pytest.approx(3 / 4)
    assert bubble_fraction(16, 4) == pytest.approx(3 / 19)
    assert bubble_fraction(8, 1) == 0.0


@pytest.mark.slow
def test_pipeline_matches_sequential():
    prog = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.runtime.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("pipe",))
S, M, mb, D = 4, 8, 2, 16
k = jax.random.key(0)
W = jax.random.normal(k, (S, D, D)) * 0.3
b = jax.random.normal(jax.random.key(1), (S, D)) * 0.1
x = jax.random.normal(jax.random.key(2), (M, mb, D))

def stage(params, h):
    w, bb = params
    return jnp.tanh(h @ w + bb)

want = x
for s in range(S):
    want = stage((W[s], b[s]), want.reshape(M * mb, D)).reshape(M, mb, D)

got = jax.jit(lambda p, xx: pipeline_apply(stage, p, xx, mesh))((W, b), x)
err = float(jnp.max(jnp.abs(got - want)))
print(json.dumps({"err": err}))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5, res
