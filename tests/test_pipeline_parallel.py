"""Pipeline-parallel wrapper: GPipe schedule == sequential composition —
plus the elastic pipeline-serving gang: stage templates, the K-VF
PipelineServeEngine vs the single-stage oracle, live reshape / VF-loss
fallback bit-identity (I10+I14), atomic gang admission, and the
gang-aware scale-out budget."""
import dataclasses
import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro.runtime.pipeline import (bubble_fraction, schedule_stats,
                                    serve_schedule)
from repro.serve.stages import (build_templates, check_partition,
                                pipeline_supported)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_bubble_fraction():
    assert bubble_fraction(1, 4) == pytest.approx(3 / 4)
    assert bubble_fraction(16, 4) == pytest.approx(3 / 19)
    assert bubble_fraction(8, 1) == 0.0


@pytest.mark.slow
def test_pipeline_matches_sequential():
    prog = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.runtime.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("pipe",))
S, M, mb, D = 4, 8, 2, 16
k = jax.random.key(0)
W = jax.random.normal(k, (S, D, D)) * 0.3
b = jax.random.normal(jax.random.key(1), (S, D)) * 0.1
x = jax.random.normal(jax.random.key(2), (M, mb, D))

def stage(params, h):
    w, bb = params
    return jnp.tanh(h @ w + bb)

want = x
for s in range(S):
    want = stage((W[s], b[s]), want.reshape(M * mb, D)).reshape(M, mb, D)

got = jax.jit(lambda p, xx: pipeline_apply(stage, p, xx, mesh))((W, b), x)
err = float(jnp.max(jnp.abs(got - want)))
print(json.dumps({"err": err}))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5, res


# ===========================================================================
# stage templates (I14's vocabulary)
# ===========================================================================
def test_build_templates_every_width_partitions():
    tpls = build_templates(12, 4)
    assert sorted(tpls) == [1, 2, 3, 4]
    for k, t in tpls.items():
        check_partition(t.bounds, 12)          # raises on a bad partition
        widths = [hi - lo for lo, hi in zip(t.bounds, t.bounds[1:])]
        assert len(widths) == k and sum(widths) == 12
        assert max(widths) - min(widths) <= 1   # balanced
    # width is capped at the period count — never an empty stage
    assert sorted(build_templates(2, 5)) == [1, 2]


def test_check_partition_rejects_bad_bounds():
    with pytest.raises(ValueError):
        check_partition((0, 2, 2, 4), 4)        # empty stage
    with pytest.raises(ValueError):
        check_partition((1, 4), 4)              # does not start at 0
    with pytest.raises(ValueError):
        check_partition((0, 3), 4)              # does not cover the stack


def test_serve_schedule_order_and_stats():
    items = list(serve_schedule(3, 2))
    # every (s, m) exactly once, dependencies (s-1,m) and (s,m-1) first
    assert sorted(items) == [(s, m) for s in range(2) for m in range(3)]
    seen = set()
    for s, m in items:
        assert s == 0 or (s - 1, m) in seen
        assert m == 0 or (s, m - 1) in seen
        seen.add((s, m))
    # uniform walls reduce to the analytic bubble fraction
    st = schedule_stats([[1.0] * 4 for _ in range(2)])
    assert st.makespan == pytest.approx(5.0)
    assert st.bubble == pytest.approx(bubble_fraction(4, 2))
    assert st.stage_busy == (4.0, 4.0)


# ===========================================================================
# the K-VF engine vs the single-stage oracle (bit-identity, I10)
# ===========================================================================
@pytest.fixture(scope="module")
def dsetup():
    """A deepseek-67b-class config (untied embeddings, all-attn pattern)
    shrunk to smoke size but DEEPENED to 4 layers so K=4 templates exist.
    scan_layers=False matches what the pipeline engine forces, so oracle
    and gang run the byte-identical unrolled XLA program."""
    import jax
    from repro.configs import make_run_config
    from repro.models.model import build_model
    run = make_run_config("deepseek-67b", "decode_32k", smoke=True)
    run = dataclasses.replace(
        run,
        model=dataclasses.replace(run.model, num_layers=4),
        sharding=dataclasses.replace(run.sharding, scan_layers=False))
    ok, why = pipeline_supported(run.model)
    assert ok, why
    model = build_model(run)
    params = model.init(jax.random.key(0))
    return run, params


def _drive(eng, reqs, hook=None):
    for r in reqs:
        eng.submit(r)
    steps = 0
    while (eng.step() or eng.queue) and steps < 200:
        steps += 1
        if hook is not None:
            hook(steps)
    assert all(r.done for r in reqs), [r.rid for r in reqs if not r.done]
    return [list(r.out) for r in reqs]


def _mkreqs(n=3, max_new=6):
    from repro.serve.engine import Request
    prompts = [np.arange(4) % 97, (np.arange(7) * 3) % 97,
               (np.arange(5) * 5 + 2) % 97, (np.arange(6) * 7 + 1) % 97]
    return [Request(rid=i, prompt=np.asarray(prompts[i % 4], np.int32),
                    max_new_tokens=max_new) for i in range(n)]


@pytest.mark.slow
def test_pipeline_k4_serves_deepseek_class_bit_identical(dsetup):
    from repro.serve.engine import ServeEngine
    from repro.serve.pipeline_engine import PipelineServeEngine
    run, params = dsetup
    oracle = ServeEngine(run, params, slots=3, max_len=64, paged=True)
    want = _drive(oracle, _mkreqs())
    gang = PipelineServeEngine(run, params, stages=4, microbatches=2,
                               slots=3, max_len=64)
    assert gang.stage_width == 4 and gang.max_stage_width == 4
    got = _drive(gang, _mkreqs())
    assert got == want
    # measured telemetry accumulated over the decode schedule
    loads = gang.stage_loads()
    assert len(loads) == 4 and all(0.0 <= x <= 1.0 for x in loads)
    assert 0.0 <= gang.measured_bubble < 1.0
    assert gang.sched_ticks > 0


@pytest.mark.slow
def test_live_reshape_k4_to_k3_bit_identical(dsetup):
    """A K=4 -> K=3 width change mid-decode leaves every token stream
    exactly equal to the single-stage oracle's (the acceptance bar for
    the reshape path: pure re-layout, no state rebuild)."""
    from repro.serve.engine import ServeEngine
    from repro.serve.pipeline_engine import PipelineServeEngine
    run, params = dsetup
    oracle = ServeEngine(run, params, slots=3, max_len=64, paged=True)
    want = _drive(oracle, _mkreqs(max_new=8))
    gang = PipelineServeEngine(run, params, stages=4, microbatches=2,
                               slots=3, max_len=64)

    def shrink_mid_flight(step):
        if step == 3:
            gang.apply_reshape(3)
        elif step == 6:
            gang.apply_reshape(2)
    got = _drive(gang, _mkreqs(max_new=8), hook=shrink_mid_flight)
    assert got == want
    assert gang.stage_width == 2 and gang.reshape_count == 2
    assert gang.stage_bounds() == gang.templates[2].bounds


# ===========================================================================
# gang management: atomic admission, crash windows, fleet fallback
# ===========================================================================
def test_gang_admission_error_is_atomic(tmp_path):
    """A gang that cannot be placed whole is refused TYPED and
    side-effect-free: no member attached, no VF claimed, no pending
    journal entry — then the same gang attaches fine once room exists."""
    from repro.core import GangPlacementError, SVFFManager
    from repro.core.pool import DevicePool
    from repro.core.staging import StagingEngine
    from repro.sim.invariants import check_invariants
    from repro.sim.tenant import SimPipelineTenant, SimTenant

    pool = DevicePool(devices=tuple(f"d{i}" for i in range(4)), max_vfs=2)
    mgr = SVFFManager(pool, workdir=str(tmp_path),
                      staging=StagingEngine(num_queues=2),
                      scheduler="first_fit")
    vm0 = SimTenant("vm0", seed=1)
    mgr.init(2, [vm0])                    # 1 free VF, gang needs 2
    lead = SimPipelineTenant("pg0", seed=2, width=2, max_width=2)
    with pytest.raises(GangPlacementError):
        mgr.attach_group(lead)
    assert lead.status == "created"
    assert all(sh.status == "created" for sh in lead.gang_shells)
    assert all(vf.owner in (None, "vm0") for vf in pool.vfs.values())
    assert not [e for e in mgr.journal.entries()
                if e["status"] == "pending"]
    check_invariants(mgr)
    mgr.detach(vm0)                       # room appears: attach succeeds
    mgr.attach_group(lead)
    assert lead.status == "running"
    assert sum(1 for sh in lead.gang_shells
               if sh.status == "running") == 1
    check_invariants(mgr)


@pytest.mark.chaos
def test_gang_crash_windows_recover():
    """The PR's crash windows: mid-gang-attach rolls the whole gang back
    (I8/I9-clean), before-commit rolls it forward; reshape crashes land
    on exactly the old or the new width, never between (I14)."""
    from repro.sim.chaos import run_crash_case
    for point in ("gang_mid_member", "gang_before_commit",
                  "reshape_mid_members", "reshape_before_commit"):
        for seed in (0, 1):
            assert run_crash_case(point, seed)["ok"]


@pytest.fixture(scope="module")
def qsetup():
    """The fleet-level gang config: qwen3-0.6b smoke (2 layers -> K up
    to 2), scan_layers=False to match the pipeline engine's program."""
    import jax
    from repro.configs import make_run_config
    from repro.models.model import build_model
    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    run = dataclasses.replace(
        run, sharding=dataclasses.replace(run.sharding,
                                          scan_layers=False))
    model = build_model(run)
    params = model.init(jax.random.key(0))
    return run, params


@pytest.mark.slow
def test_fleet_vf_loss_fallback_and_stage_telemetry(qsetup):
    """A shell VF dies mid-serving: the fleet sheds exactly that stage
    (journaled reshape K=2 -> K=1) and every request still matches the
    single-stage oracle token-for-token. Per-stage telemetry surfaces
    through EngineStats and the MetricsBus on the way."""
    from repro.serve.engine import ServeEngine
    from repro.serve.fleet import ServeFleet
    run, params = qsetup
    oracle = ServeEngine(run, params, slots=2, max_len=48, paged=True)
    want = _drive(oracle, _mkreqs(n=3))
    with tempfile.TemporaryDirectory() as wd:
        fleet = ServeFleet(run, params, num_engines=1, num_devices=4,
                           stages=2, slots=2, max_len=48, workdir=wd)
        tn = fleet.tenants["serve0"]
        assert tn.stage_width == 2
        reqs = _mkreqs(n=3)
        for r in reqs:
            fleet.submit(r)
        for _ in range(3):
            fleet.step()
        snap = fleet.telemetry_snapshot()
        e = next(s for s in snap.engines if s.tid == "serve0")
        assert e.stage_width == 2 and e.stage_width_max == 2
        assert len(e.stage_loads) == 2
        assert 0.0 <= e.bubble_frac <= 1.0
        desc = fleet.telemetry.describe()["serve0"]
        assert len(desc["stage_loads"]) == 2
        # the fallback: shed the dead shell's stage, keep serving at K=1
        shell = tn.gang_shells[0]
        assert shell.status == "running"
        info = fleet.handle_vf_loss("serve0", shell.vf_id)
        assert info["k_new"] == 1 and info["dropped"] == [shell.tid]
        assert tn.stage_width == 1 and shell.status == "detached"
        assert fleet.drain().drained
        assert [list(r.out) for r in reqs] == want
        assert not [ent for ent in fleet.mgr.journal.entries()
                    if ent["status"] == "pending"]


@pytest.mark.slow
def test_fleet_scale_out_gang_budget(qsetup):
    """Satellite bugfix: scale_out's VF-cap math counts the K VFs a
    whole gang needs. 3 devices with one K=2 gang live -> a second gang
    (4 VFs) is refused typed, nothing half-carved; with 4 devices the
    same scale-out reconfs to 4 VFs and gang-attaches whole."""
    from repro.core import ManagerError
    from repro.serve.fleet import ServeFleet
    run, params = qsetup
    with tempfile.TemporaryDirectory() as wd:
        fleet = ServeFleet(run, params, num_engines=1, num_devices=3,
                           stages=2, slots=2, max_len=48, workdir=wd)
        with pytest.raises(ManagerError, match="device budget"):
            fleet.scale_out()
        assert len(fleet.pool.vfs) == 2         # partition untouched
        assert sorted(fleet.tenants) == ["serve0"]   # no leaked tenant
    with tempfile.TemporaryDirectory() as wd:
        fleet = ServeFleet(run, params, num_engines=1, num_devices=4,
                           stages=2, slots=2, max_len=48, workdir=wd)
        tid = fleet.scale_out()
        tn = fleet.tenants[tid]
        assert tn.status == "running" and tn.stage_width == 2
        assert sum(1 for s in tn.gang_shells
                   if s.status == "running") == 1
