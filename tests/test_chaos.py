"""Crash-consistency suite: the write-ahead OpJournal, the crash-point
catalogue (inject -> recover -> invariants I1-I9), crash ops inside
randomized scenarios, checker sensitivity for I8, RecordStore crash
windows (property-style), and the deterministic fault plane (injected
clock for HeartbeatMonitor/Supervisor)."""
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (DevicePool, HeartbeatMonitor, InjectedCrash,
                        OpJournal, RecordStore, SVFFManager, StagingEngine,
                        Supervisor, UnknownTenantError, crash_plane)
from repro.core.journal import JournalError
from repro.sim import (CRASH_POINTS, InvariantViolation, ScenarioConfig,
                       ScenarioRunner, SimTenant, VirtualClock,
                       check_invariants, crash_matrix, recover_manager,
                       run_crash_case, state_fingerprint)

POLICIES = ("first_fit", "best_fit", "fair_share")
HSET = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# OpJournal: WAL discipline
# ---------------------------------------------------------------------------
def test_journal_begin_commit_abort(tmp_path):
    j = OpJournal(str(tmp_path / "j"))
    a = j.begin("attach", "vm0", vf_id="vf.1")
    b = j.begin("pause", "vm1", vf_id="vf.2")
    assert [e["seq"] for e in j.pending()] == [a, b]
    j.commit(a)
    j.abort(b, reason="rolled back")
    assert j.pending() == []
    assert j.read(a)["status"] == "committed"
    assert j.read(b)["status"] == "aborted"
    assert j.read(b)["details"]["reason"] == "rolled back"
    with pytest.raises(JournalError):          # double resolution refused
        j.commit(a)
    with pytest.raises(JournalError):
        j.begin("frobnicate", "vm0")           # unknown op never journaled


def test_journal_survives_reopen_and_sweeps_parts(tmp_path):
    d = str(tmp_path / "j")
    j = OpJournal(d)
    a = j.begin("detach", "vm0", vf_id="vf.1", step=3)
    # torn write debris + a fresh journal over the same dir
    open(os.path.join(d, f"op_{99:08d}.json.part"), "w").write("{torn")
    j2 = OpJournal(d)
    assert [e["seq"] for e in j2.pending()] == [a]
    assert j2.sweep_parts() == 1
    # seq numbering continues past the crash (no reuse)
    assert j2.begin("attach", "vm1") > a
    j2.commit(a)
    assert j2.compact() == 1                   # resolved entries dropped
    assert len(j2.pending()) == 1              # pending never compacted


# ---------------------------------------------------------------------------
# the crash matrix: every point x a few seeds (fast subset, always on);
# the full 20-seed x 3-policy matrix runs under the chaos marker / CI job
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("point", sorted(CRASH_POINTS))
def test_crash_point_recovers(point):
    for seed in range(3):
        res = run_crash_case(point, seed)
        assert res["ok"], res


@pytest.mark.chaos
def test_crash_matrix_fast():
    """PR-gate subset of the matrix: every point, 5 seeds, one policy."""
    out = crash_matrix(seeds=range(5), policies=("first_fit",))
    assert out["summary"]["num_failures"] == 0


@pytest.mark.chaos
@pytest.mark.slow
@pytest.mark.skipif(os.environ.get("SVFF_CHAOS_FULL") != "1",
                    reason="full crash matrix runs on main (CI chaos job "
                           "sets SVFF_CHAOS_FULL=1)")
def test_crash_matrix_full():
    """Acceptance matrix: every point x >= 20 seeds x all policies."""
    out = crash_matrix(seeds=range(20), policies=POLICIES)
    assert out["summary"]["num_failures"] == 0
    assert out["summary"]["num_cases"] == len(CRASH_POINTS) * 20 * 3


# ---------------------------------------------------------------------------
# crash ops inside randomized scenarios (the tentpole property)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_crash_scenarios_hold_invariants(policy):
    """Randomized histories with crash injection at every opportunity:
    the harness kills the manager mid-op, recovers, and asserts I1-I9
    after every op. The generator models the cataloged recovery outcome,
    so even post-crash, every non-chaos op must still succeed."""
    crashed = 0
    for seed in range(8):
        res = ScenarioRunner(ScenarioConfig(seed=seed, policy=policy,
                                            crash_rate=0.25)).run()
        for r in res.ops:
            if r.status == "rejected":
                assert r.op.chaos, (
                    f"seed={seed} policy={policy}: valid op rejected "
                    f"after a crash: {r.op} -> {r.error}")
            if r.op.kind == "crash":
                crashed += 1
    assert crashed > 10           # the histories actually exercised crashes


def test_crash_scenarios_replay_deterministically():
    for seed in (1, 4, 9):
        cfg = ScenarioConfig(seed=seed, crash_rate=0.3)
        a = ScenarioRunner(cfg).run()
        b = ScenarioRunner(cfg).run()
        assert a.fingerprint() == b.fingerprint()


def test_crash_rate_zero_leaves_scenarios_unchanged():
    """crash_rate=0 must not consume generator randomness: pre-chaos
    seeds keep their exact op sequences (regression gate for replays)."""
    from repro.sim import generate_scenario
    for seed in range(6):
        base = generate_scenario(ScenarioConfig(seed=seed))
        zero = generate_scenario(ScenarioConfig(seed=seed, crash_rate=0.0))
        assert base == zero
        assert all(o.kind != "crash" for o in base)


# ---------------------------------------------------------------------------
# recovery semantics, directly
# ---------------------------------------------------------------------------
def _system(tmp_path, policy="first_fit"):
    pool = DevicePool(devices=tuple(f"d{i}" for i in range(8)))
    mgr = SVFFManager(pool, workdir=str(tmp_path),
                      staging=StagingEngine(num_queues=1),
                      scheduler=policy)
    tn = SimTenant("vm0", seed=0)
    mgr.init(num_vfs=2, tenants=[tn], devices_per_vf=2)
    return pool, mgr, tn


def _crash(mgr, point, fn):
    crash_plane.arm(point)
    try:
        with pytest.raises(InjectedCrash):
            fn()
    finally:
        crash_plane.disarm()


def test_pause_crash_rolls_forward_from_registered_snapshot(tmp_path):
    _, mgr, tn = _system(tmp_path)
    tn.run_steps(3)
    _crash(mgr, "after_suspend", lambda: mgr.pause(tn))
    # suspended mid-pause: the guest's only state copy is the snapshot
    assert tn.status == "paused" and tn.export_state() is None
    mgr2 = recover_manager(mgr, {"vm0": tn})
    check_invariants(mgr2)
    assert tn.status == "paused"
    mgr2.unpause(tn)                       # and it restores bit-identically
    check_invariants(mgr2)
    assert tn.steps_done == 3


def test_detach_crash_rollback_removes_orphan_snapshot(tmp_path):
    _, mgr, tn = _system(tmp_path)
    _crash(mgr, "after_detach_snapshot", lambda: mgr.detach(tn))
    assert tn.status == "running"          # guest never lost its device
    mgr2 = recover_manager(mgr, {"vm0": tn})
    check_invariants(mgr2)
    assert mgr2._detached_steps() == {}    # orphan disk snapshot swept
    mgr2.detach(tn)                        # the op still works end-to-end
    check_invariants(mgr2)


def test_staging_crash_leaves_memo_unpublished(tmp_path):
    """Transactional snapshot publication: a save that dies mid-pipeline
    must leave the incremental memo exactly as before, so the next save
    re-transfers everything it should."""
    eng = StagingEngine(num_queues=2, incremental=True, dirty="digest")
    tree = {"a": np.arange(8, dtype=np.float32),
            "b": np.ones(4, dtype=np.float32)}
    crash_plane.arm("mid_pipeline_chunk")
    try:
        with pytest.raises(InjectedCrash):
            eng.save(tree, tenant="t0")
    finally:
        crash_plane.disarm()
    assert eng.memo_size("t0") == 0        # nothing published
    out = eng.save(tree, tenant="t0")      # clean retry is complete
    assert eng.last_stats.skipped_bytes == 0
    np.testing.assert_array_equal(out["a"], tree["a"])


def test_clean_bind_failure_resolves_wal_entry(tmp_path):
    """A non-crash failure after begin() (e.g. a compile error in bind)
    must abort the WAL entry, not leave a pending intent that fails I8
    forever, and the op must stay retryable."""
    _, mgr, tn = _system(tmp_path)
    mgr.detach(tn)

    def bad_bind(*a, **k):
        raise RuntimeError("compile failed")
    orig_bind, tn.bind = tn.bind, bad_bind
    with pytest.raises(RuntimeError, match="compile failed"):
        mgr.attach(tn)
    assert mgr.journal.pending() == []     # intent resolved, not pending
    check_invariants(mgr)
    tn.bind = orig_bind
    mgr.attach(tn)                         # retry succeeds
    check_invariants(mgr)


def test_clean_pause_failure_self_heals_wal(tmp_path):
    """A non-crash staging failure mid-pause on a LIVE manager must
    self-heal its WAL entry inline (no pending intent, guest untouched,
    op retryable) — no manager restart required."""
    _, mgr, tn = _system(tmp_path)
    orig = mgr.staging.save

    def boom(*a, **k):
        raise RuntimeError("device error")
    mgr.staging.save = boom
    with pytest.raises(RuntimeError, match="device error"):
        mgr.pause(tn)
    mgr.staging.save = orig
    assert mgr.journal.pending() == []
    assert tn.status == "running"
    check_invariants(mgr)
    mgr.pause(tn)                          # retry succeeds
    mgr.unpause(tn)
    check_invariants(mgr)


def test_unpause_of_never_paused_raises_typed_error(tmp_path):
    _, mgr, tn = _system(tmp_path)
    with pytest.raises(UnknownTenantError):
        mgr.unpause(tn)
    check_invariants(mgr)                  # typed rejection stays atomic


@pytest.mark.chaos
def test_mid_cow_crash_window(tmp_path):
    """Chaos fast-subset: crash a live pause whose pre-copy rounds step
    the engine THROUGH a copy-on-write page split (a CoW resolves within
    the step that makes it necessary, so the window is the step itself).
    Recovery must complete the pause with refcount accounting intact
    (I12), and the drained outputs must stay oracle-identical (I10)."""
    from repro.sim.tenant import SimServeTenant
    pool = DevicePool(devices=tuple(f"d{i}" for i in range(4)))
    mgr = SVFFManager(pool, workdir=str(tmp_path),
                      staging=StagingEngine(num_queues=1),
                      scheduler="first_fit")
    tn = SimServeTenant("sv0", seed=2)
    mgr.init(num_vfs=2, tenants=[tn], devices_per_vf=2)
    # deterministic schedule (seed 2, burst 8): the first CoW split fires
    # during step 4, so stepping 3 times parks the engine one step short
    # and the pause's 2 pre-copy rounds (steps 4-5) run straight through it
    tn.submit_burst(8)
    tn.run_steps(3)
    assert tn.cow_splits == 0

    _crash(mgr, "after_suspend",
           lambda: mgr.pause_live(tn, rounds=2,
                                  step_fn=lambda: tn.run_steps(1)))
    assert tn.cow_splits >= 1, \
        "seed 2 no longer CoWs inside the pre-copy window"
    mgr2 = recover_manager(mgr, {"sv0": tn})
    check_invariants(mgr2)                 # I12: refcounts survived
    assert tn.status == "paused"
    mgr2.unpause(tn)
    check_invariants(mgr2)
    for _ in range(200):                   # drain: every request completes
        tn.run_steps(1)
        if not tn.queue and all(r is None for r in tn.active):
            break
    check_invariants(mgr2)                 # I10 over the finished outputs
    assert all(r.done for r in tn.requests)


# ---------------------------------------------------------------------------
# checker sensitivity: I8 must actually bite
# ---------------------------------------------------------------------------
def test_checker_detects_pending_intent(tmp_path):
    _, mgr, tn = _system(tmp_path)
    check_invariants(mgr)
    mgr.journal.begin("pause", "vm0", vf_id=tn.vf_id)
    with pytest.raises(InvariantViolation, match="I8"):
        check_invariants(mgr)


def test_checker_detects_record_part_debris(tmp_path):
    _, mgr, tn = _system(tmp_path)
    open(os.path.join(mgr.records.dir, "vm9.json.part"), "w").write("{")
    with pytest.raises(InvariantViolation, match="I8"):
        check_invariants(mgr)


def test_checker_detects_history_state_contradiction(tmp_path):
    _, mgr, tn = _system(tmp_path)
    seq = mgr.journal.begin("pause", "vm0", vf_id=tn.vf_id)
    mgr.journal.commit(seq)                # journal says paused; it runs
    with pytest.raises(InvariantViolation, match="I8"):
        check_invariants(mgr)


def test_recovery_idempotence_detects_divergence(tmp_path):
    """state_fingerprint must be sensitive to everything recovery
    rebuilds (a vacuous I9 would pass any recover())."""
    _, mgr, tn = _system(tmp_path)
    fp = state_fingerprint(mgr)
    tn.run_steps(1)
    assert state_fingerprint(mgr) != fp


# ---------------------------------------------------------------------------
# RecordStore crash windows (property-style, via hypothesis/minihypothesis)
# ---------------------------------------------------------------------------
@given(n_parts=st.integers(0, 3), n_recs=st.integers(0, 3),
       double_remove=st.booleans())
@HSET
def test_record_store_part_files_invisible_and_swept(n_parts, n_recs,
                                                     double_remove):
    import tempfile
    import shutil
    d = tempfile.mkdtemp(prefix="svff_rec_")
    try:
        rs = RecordStore(d)
        for i in range(n_recs):
            rs.write(f"vm{i}", {"vf_id": "0000:03:00.1",
                                "mesh_shape": [1, 1]}, "run")
        for i in range(n_parts):
            open(os.path.join(d, f"vm{90 + i}.json.part"), "w").write("{")
        # crash debris is invisible to reads...
        assert rs.list() == sorted(f"vm{i}" for i in range(n_recs))
        assert len(rs.part_files()) == n_parts
        # ...swept exactly once by recovery...
        assert rs.sweep_parts() == n_parts
        assert rs.part_files() == []
        # ...and remove() is idempotent, including for missing records
        rs.remove("vm0")
        if double_remove:
            rs.remove("vm0")
        rs.remove("vm-never-existed")
        want = sorted(f"vm{i}" for i in range(1, n_recs))
        assert rs.list() == want
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_record_write_crash_window_leaves_part_only(tmp_path):
    rs = RecordStore(str(tmp_path / "r"))
    crash_plane.arm("mid_record_write")
    try:
        with pytest.raises(InjectedCrash):
            rs.write("vm0", {"vf_id": "0000:03:00.1",
                             "mesh_shape": [1, 1]}, "run")
    finally:
        crash_plane.disarm()
    assert rs.list() == []                 # record not visible
    assert len(rs.part_files()) == 1       # debris awaiting sweep
    rs.sweep_parts()
    rs.write("vm0", {"vf_id": "0000:03:00.1", "mesh_shape": [1, 1]}, "run")
    assert rs.list() == ["vm0"]


# ---------------------------------------------------------------------------
# deterministic fault plane: injected clock for HeartbeatMonitor/Supervisor
# ---------------------------------------------------------------------------
def test_heartbeat_dead_threshold_under_virtual_clock():
    clock = VirtualClock()
    mon = HeartbeatMonitor(dead_after_s=30.0, clock=clock.now)
    mon.record("vm0", 0.1)
    mon.record("vm1", 0.1)
    clock.advance(10.0)
    mon.record("vm1", 0.1)                 # vm1 keeps beating
    assert mon.dead() == []
    clock.advance(25.0)                    # vm0 last beat 35s ago
    assert mon.dead() == ["vm0"]
    clock.advance(31.0)
    assert sorted(mon.dead()) == ["vm0", "vm1"]


def test_straggler_threshold_and_supervisor_migration(tmp_path):
    clock = VirtualClock()
    pool = DevicePool(devices=tuple(f"d{i}" for i in range(8)))
    mgr = SVFFManager(pool, workdir=str(tmp_path),
                      staging=StagingEngine(num_queues=1))
    tns = [SimTenant(f"vm{i}", seed=i, clock=clock) for i in range(3)]
    mgr.init(num_vfs=3, tenants=tns, devices_per_vf=2)
    mon = HeartbeatMonitor(straggler_factor=3.0, clock=clock.now)
    sup = Supervisor(mgr, monitor=mon, clock=clock.now)
    sup.run_round(1)
    assert mon.stragglers() == []
    # vm2 turns 10x slower than the median -> flagged and migrated within
    # the same supervision round
    tns[2].STEP_COST = 0.010
    old_devices = set(pool.find(tns[2].vf_id).devices)
    sup.run_round(1)
    kinds = [e["kind"] for e in sup.events]
    assert "straggler" in kinds and "migrated" in kinds
    assert tns[2].status == "running"
    assert set(pool.find(tns[2].vf_id).devices) != old_devices
    # event timestamps come from the injected clock (deterministic)
    assert all(e["t"] <= clock.now() for e in sup.events if "t" in e)
    check_invariants(mgr)
