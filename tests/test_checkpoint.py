"""Checkpoint store: crash consistency, fingerprints, resume determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore, tree_fingerprint
from repro.configs import OptimizerConfig, make_run_config
from repro.data.pipeline import SyntheticSource
from repro.train.step import init_train_state, make_train_step


def tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = tree()
    store.save(5, t, metadata={"note": "x"})
    assert store.steps() == [5]
    out = store.restore(5, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert store.metadata(5) == {"note": "x"}


def test_crash_consistency_ignores_partial(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, tree())
    # simulate a crash mid-write: directory without manifest
    os.makedirs(tmp_path / "step_2")
    (tmp_path / "step_2" / "leaf_00000.npy").write_bytes(b"garbage")
    assert store.steps() == [1]
    assert store.latest() == 1


def test_corruption_detected(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, tree())
    # flip bytes in a leaf
    leaf = tmp_path / "step_1" / "leaf_00000.npy"
    data = bytearray(leaf.read_bytes())
    data[-1] ^= 0xFF
    leaf.write_bytes(bytes(data))
    with pytest.raises(IOError):
        store.restore(1, tree())


def test_gc_keeps_last_k(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, tree())
    assert store.steps() == [3, 4]


def test_fingerprint_detects_structure_change(tmp_path):
    t = tree()
    f1 = tree_fingerprint(t)
    t2 = dict(t, extra=jnp.zeros((1,)))
    assert tree_fingerprint(t2) != f1


def test_train_resume_bit_identical(tmp_path):
    """Crash/restart determinism: save at step 3, keep training to 6;
    restore at 3 and retrain 3 steps -> identical params."""
    run = make_run_config("qwen3-0.6b", "train_4k", smoke=True,
                          optimizer=OptimizerConfig(lr=1e-2, warmup=2))
    src = SyntheticSource(run, batch_override=2, seq_override=16)
    step = jax.jit(make_train_step(run))
    store = CheckpointStore(str(tmp_path))

    state = init_train_state(run, jax.random.key(0))
    for i in range(3):
        state, _ = step(state, {k: jnp.asarray(v)
                                for k, v in src.batch_at(i).items()})
    store.save(3, state)
    stateA = state
    for i in range(3, 6):
        stateA, _ = step(stateA, {k: jnp.asarray(v)
                                  for k, v in src.batch_at(i).items()})

    stateB = store.restore(3, init_train_state(run, jax.random.key(1)))
    stateB = jax.tree.map(jnp.asarray, stateB)
    for i in range(3, 6):
        stateB, _ = step(stateB, {k: jnp.asarray(v)
                                  for k, v in src.batch_at(i).items()})
    for a, b in zip(jax.tree.leaves(stateA["params"]),
                    jax.tree.leaves(stateB["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    store = CheckpointStore(str(tmp_path))
    t = tree()
    th = store.save_async(9, t)
    store.wait()
    assert store.steps() == [9]
    out = store.restore(9, t)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))
