"""Data pipeline: determinism, host sharding, prefetch."""
import numpy as np

from repro.configs import make_run_config
from repro.data.pipeline import HostShard, Prefetcher, SyntheticSource


def test_determinism_across_instances():
    run = make_run_config("qwen3-0.6b", "train_4k", smoke=True)
    a = SyntheticSource(run, batch_override=4, seq_override=32)
    b = SyntheticSource(run, batch_override=4, seq_override=32)
    for s in (0, 7, 123):
        ba, bb = a.batch_at(s), b.batch_at(s)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
        np.testing.assert_array_equal(ba["labels"], bb["labels"])


def test_labels_are_shifted_tokens():
    run = make_run_config("qwen3-0.6b", "train_4k", smoke=True)
    src = SyntheticSource(run, batch_override=2, seq_override=16)
    b = src.batch_at(0)
    # label[t] is the next token: generated jointly from a (S+1) stream
    assert b["tokens"].shape == b["labels"].shape
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_shards_disjoint():
    run = make_run_config("qwen3-0.6b", "train_4k", smoke=True)
    s0 = SyntheticSource(run, HostShard(0, 2), batch_override=8,
                         seq_override=16)
    s1 = SyntheticSource(run, HostShard(1, 2), batch_override=8,
                         seq_override=16)
    assert s0.local_batch == s1.local_batch == 4
    b0, b1 = s0.batch_at(3), s1.batch_at(3)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_vocab_range():
    run = make_run_config("olmoe-1b-7b", "train_4k", smoke=True)
    src = SyntheticSource(run, batch_override=2, seq_override=64)
    b = src.batch_at(5)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < run.model.vocab_size


def test_prefetcher_orders_steps():
    run = make_run_config("qwen3-0.6b", "train_4k", smoke=True)
    src = SyntheticSource(run, batch_override=2, seq_override=16)
    pf = Prefetcher(src, depth=2)
    try:
        steps = [pf.next()[0] for _ in range(5)]
        assert steps == [0, 1, 2, 3, 4]
        want = src.batch_at(2)
        pf2 = Prefetcher(src, depth=2, start_step=2)
        got_step, got = pf2.next()
        assert got_step == 2
        np.testing.assert_array_equal(got["tokens"], want["tokens"])
        pf2.stop()
    finally:
        pf.stop()


def test_frontend_inputs_present():
    for arch, key in (("internvl2-1b", "patches"),
                      ("seamless-m4t-medium", "frames")):
        run = make_run_config(arch, "train_4k", smoke=True)
        src = SyntheticSource(run, batch_override=2, seq_override=16)
        assert key in src.batch_at(0)
