"""Refcounted copy-on-write prefix sharing: allocator trie/refcount
semantics, engine-level CoW + lazy growth + preemption, the I12 refcount
invariant, and the allocator-hardening bugfixes (typed double-free,
defragment-before-backoff, dead `extend` wired as lazy decode growth)."""
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import make_run_config
from repro.core import DevicePool, SVFFManager, StagingEngine
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.paged import (BlockAllocator, CacheExhausted,
                               DoubleFreeError, RequestRejected,
                               UnknownRequestError)
from repro.sim.invariants import InvariantViolation, check_invariants


@pytest.fixture(scope="module")
def setup():
    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    return run, model, params


def naive_generate(model, params, prompt, n, max_len=48):
    import jax.numpy as jnp
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    cache, last = jax.jit(model.prefill)(params, batch)

    def pad(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v"):
            return jnp.pad(x, ((0, 0), (0, 0), (0, max_len - x.shape[2]),
                               (0, 0), (0, 0)))
        return x
    cache = jax.tree_util.tree_map_with_path(pad, cache)
    toks = [int(jnp.argmax(last[0]))]
    pos = len(prompt) - 1
    dec = jax.jit(model.decode_step)
    for _ in range(n - 1):
        pos += 1
        lg, cache = dec(params, cache,
                        jnp.asarray([[toks[-1]]], jnp.int32), jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0])))
    return toks


# ===========================================================================
# allocator: trie sharing + refcounts
# ===========================================================================
def _alloc_with_prompt(alloc, rid, tokens, extra=0):
    """Allocate rid's prompt pages (+extra) and register them for sharing,
    mirroring the engine's allocate-at-admit / register-at-place split."""
    n = alloc.pages_needed(len(tokens)) + extra
    pages = alloc.allocate(rid, n, tokens=tokens)
    alloc.register_prefix(rid)
    return pages


def test_full_page_prefix_shares_physical_pages():
    alloc = BlockAllocator(16, 4)
    sys_prompt = tuple(range(8))                      # two full pages
    p0 = _alloc_with_prompt(alloc, 0, sys_prompt)
    p1 = alloc.allocate(1, 2, tokens=sys_prompt)
    assert p1 == p0                                   # same physical pages
    assert alloc.shared_count(1) == 2
    assert alloc.refcount(p0[0]) == alloc.refcount(p0[1]) == 2
    assert alloc.pages_in_use == 2                    # counted once
    # divergent second page -> only the first page shares
    p2 = alloc.allocate(2, 2, tokens=sys_prompt[:4] + (90, 91, 92, 93))
    assert p2[0] == p0[0] and p2[1] not in p0
    assert alloc.shared_count(2) == 1
    alloc.check_invariants()


def test_partial_page_shares_only_on_exact_prefix_rest():
    alloc = BlockAllocator(16, 4)
    reg = tuple(range(6))                  # 1 full page + rest (4, 5)
    p0 = _alloc_with_prompt(alloc, 0, reg)
    # sharer's leftover (4,) is a PREFIX of the registered (4, 5): both
    # pages shared — the longer registered tail sits past the sharer's
    # position and is masked by the decode kernel
    p1 = alloc.allocate(1, 2, tokens=tuple(range(5)))
    assert p1 == p0 and alloc.shared_count(1) == 2
    # leftover (4, 7) is NOT a prefix: only the full page shares
    p2 = alloc.allocate(2, 2, tokens=(0, 1, 2, 3, 4, 7))
    assert p2[0] == p0[0] and p2[1] != p0[1]
    # leftover longer than the registered rest: the registered page does
    # not hold the sharer's extra row, so it must not share either
    p3 = alloc.allocate(3, 2, tokens=tuple(range(7)))
    assert p3[0] == p0[0] and p3[1] != p0[1]
    alloc.check_invariants()


def test_free_keeps_shared_pages_live_for_siblings():
    alloc = BlockAllocator(16, 4)
    prompt = tuple(range(8))
    p0 = _alloc_with_prompt(alloc, 0, prompt)
    alloc.allocate(1, 2, tokens=prompt)
    alloc.free(0)                          # registrant finishes first
    assert alloc.refcount(p0[0]) == 1      # sibling keeps the pages live
    assert alloc.pages_in_use == 2
    # the trie entry survives with the page: a third request still hits
    p2 = alloc.allocate(2, 2, tokens=prompt)
    assert p2 == p0 and alloc.shared_count(2) == 2
    alloc.free(1)
    alloc.free(2)
    assert alloc.pages_in_use == 0         # last owner returned them
    # and the trie let go: a fresh request gets fresh pages, no stale hit
    assert alloc.allocate(3, 2, tokens=prompt) and alloc.shared_count(3) == 0
    alloc.check_invariants()


def test_double_free_raises_typed_error():
    alloc = BlockAllocator(8, 4)
    with pytest.raises(DoubleFreeError):
        alloc.free(7)                      # never allocated
    alloc.allocate(0, 2)
    alloc.free(0)
    with pytest.raises(DoubleFreeError):
        alloc.free(0)                      # double free
    assert issubclass(DoubleFreeError, RuntimeError)
    alloc.check_invariants()


def test_cow_splits_one_page_and_respects_guards():
    alloc = BlockAllocator(16, 4)
    prompt = tuple(range(8))
    p0 = _alloc_with_prompt(alloc, 0, prompt)
    alloc.allocate(1, 2, tokens=prompt)
    old, new = alloc.cow(1, 1)             # rid 1 writes into page idx 1
    assert old == p0[1] and new not in p0
    assert alloc.pages_of(1) == [p0[0], new]
    assert alloc.pages_of(0) == p0         # sharer's chain untouched
    assert alloc.refcount(old) == 1 and alloc.refcount(new) == 1
    with pytest.raises(ValueError):
        alloc.cow(1, 1)                    # already private
    alloc.check_invariants()


def test_cow_exhaustion_is_typed_and_side_effect_free():
    alloc = BlockAllocator(4, 4)           # capacity 3
    prompt = tuple(range(8))
    _alloc_with_prompt(alloc, 0, prompt)
    alloc.allocate(1, 2, tokens=prompt)
    alloc.allocate(2, 1)                   # last free page gone
    before = alloc.pages_of(1)
    with pytest.raises(CacheExhausted):
        alloc.cow(1, 0)
    assert alloc.pages_of(1) == before     # refcounts untouched
    alloc.check_invariants()


def test_extend_grows_chain_with_private_pages():
    alloc = BlockAllocator(8, 4)
    prompt = tuple(range(4))
    _alloc_with_prompt(alloc, 0, prompt)
    chain0 = alloc.pages_of(0)
    (new,) = alloc.extend(0, 1)
    assert alloc.pages_of(0) == chain0 + [new]
    assert alloc.refcount(new) == 1
    # decode-grown pages are never offered for sharing
    p1 = alloc.allocate(1, 2, tokens=prompt + (9, 9, 9, 9))
    assert new not in p1
    with pytest.raises(UnknownRequestError):
        alloc.extend(42, 1)                # unknown rid
    with pytest.raises(CacheExhausted):
        alloc.extend(0, 99)
    alloc.check_invariants()


def test_defragment_moves_shared_pages_once_and_remaps_trie():
    alloc = BlockAllocator(32, 4)
    prompt = tuple(range(8))
    alloc.allocate(0, 3)                   # filler to push pages up
    p1 = _alloc_with_prompt(alloc, 1, prompt, extra=1)
    alloc.allocate(2, 2, tokens=prompt)
    alloc.free(0)                          # hole below the shared pages
    moves = alloc.defragment()             # runs check_invariants itself
    assert moves
    c1, c2 = alloc.pages_of(1), alloc.pages_of(2)
    assert c1[:2] == c2[:2]                # sharing survives compaction
    assert c1[:2] != p1[:2]                # and the pages really moved
    assert alloc.refcount(c1[0]) == 2
    # the trie remapped with the pages: a post-defrag admit still hits
    p3 = alloc.allocate(3, 2, tokens=prompt)
    assert p3 == c2 and alloc.shared_count(3) == 2


def test_allocator_self_check_catches_seeded_over_decref():
    alloc = BlockAllocator(16, 4)
    prompt = tuple(range(8))
    pages = _alloc_with_prompt(alloc, 0, prompt)
    alloc.allocate(1, 2, tokens=prompt)
    alloc.check_invariants()               # sane baseline
    alloc._decref(pages[0])                # seeded bug: one decref too many
    with pytest.raises(AssertionError, match="refcount drift"):
        alloc.check_invariants()


# ===========================================================================
# engine: bit-identical outputs, CoW splits, lazy growth, preemption
# ===========================================================================
def _drain(eng, limit=300):
    steps = 0
    while (eng.step() or eng.queue or eng._jobs) and steps < limit:
        steps += 1
    return steps


def test_share_prefix_outputs_bit_identical_and_fewer_pages(setup):
    """Four residents on one prompt: sharing must not change a single
    token (I10 vs both the naive oracle and a no-sharing engine) while
    holding strictly fewer unique pages at equal residency."""
    run, model, params = setup
    prompt = np.arange(32) % 100
    want = naive_generate(model, params, prompt, 4)
    peaks = {}
    outs = {}
    for share in (False, True):
        eng = ServeEngine(run, params, slots=4, max_len=48, paged=True,
                          page_size=16, share_prefix=share)
        reqs = [Request(rid=i, prompt=prompt, max_new_tokens=4)
                for i in range(4)]
        for r in reqs:
            eng.submit(r)
        peak = 0
        steps = 0
        while (eng.step() or eng.queue) and steps < 100:
            peak = max(peak, eng.alloc.pages_in_use)
            steps += 1
        peaks[share] = peak
        outs[share] = [r.out for r in reqs]
        assert all(r.done for r in reqs)
        assert eng.alloc.pages_in_use == 0          # everything returned
        eng.alloc.check_invariants()
    assert outs[True] == outs[False] == [want] * 4
    assert peaks[True] < peaks[False]
    # 2 shared prompt pages x 3 sharing residents
    assert peaks[False] - peaks[True] >= 4


def test_cow_splits_exactly_one_page_on_mid_page_divergence(setup):
    """Two requests share a 12-token prompt (page_size 8: one full page +
    a partial). The first decode write lands mid-page in the shared
    partial page -> exactly ONE CoW split (the writer goes private; the
    remaining owner writes in place at refcount 1)."""
    run, model, params = setup
    prompt = (np.arange(12) * 3) % 100
    want = naive_generate(model, params, prompt, 4)
    eng = ServeEngine(run, params, slots=2, max_len=48, paged=True,
                      page_size=8, share_prefix=True)
    reqs = [Request(rid=i, prompt=prompt, max_new_tokens=4)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    _drain(eng)
    assert [r.out for r in reqs] == [want, want]
    assert eng.stats["shared_page_hits"] == 2       # full + partial hit
    assert eng.stats["cow_splits"] == 1
    eng.alloc.check_invariants()


def test_sibling_finish_keeps_shared_pages_live(setup):
    """A short request finishing must not free the shared prompt pages
    its long-running sibling still reads through."""
    run, model, params = setup
    prompt = np.arange(16) % 100
    want = naive_generate(model, params, prompt, 8)
    eng = ServeEngine(run, params, slots=2, max_len=48, paged=True,
                      page_size=16, share_prefix=True)
    long_r = Request(rid=0, prompt=prompt, max_new_tokens=8)
    short_r = Request(rid=1, prompt=prompt, max_new_tokens=2)
    eng.submit(long_r)
    eng.submit(short_r)
    while not short_r.done:
        eng.step()
    # sibling gone; the long request still owns the shared prompt page
    assert eng.alloc.refcount(eng.alloc.pages_of(0)[0]) == 1
    eng.alloc.check_invariants()
    _drain(eng)
    assert long_r.out == want and short_r.out == want[:2]


def test_defragment_with_refcounted_pages_mid_decode(setup):
    """Production defragment (the _admit retry path calls this) while
    shared refcount>1 pages are live mid-decode: chains, tables, and the
    trie all follow the moved pages; outputs stay bit-identical."""
    run, model, params = setup
    prompt = np.arange(32) % 100
    want = naive_generate(model, params, prompt, 6)
    eng = ServeEngine(run, params, slots=3, max_len=48, paged=True,
                      page_size=16, share_prefix=True)
    filler = Request(rid=9, prompt=(np.arange(8) * 7) % 100,
                     max_new_tokens=1)     # finishes at prefill -> a hole
    reqs = [Request(rid=i, prompt=prompt, max_new_tokens=6)
            for i in range(2)]
    eng.submit(filler)
    for r in reqs:
        eng.submit(r)
    eng.step()                             # all admitted, filler done
    assert filler.done and not reqs[0].done
    moves = eng.defragment()
    chain = eng.alloc.pages_of(0)
    assert eng.alloc.refcount(chain[0]) == 2       # sharing survived
    assert list(eng.tables[0][:len(chain)]) == chain
    eng.alloc.check_invariants()
    _drain(eng)
    assert [r.out for r in reqs] == [want, want]
    assert moves is not None               # the path ran (may be {})


def test_lazy_extend_grows_pages_on_demand(setup):
    """Satellite: admission reserves only PROMPT pages; decode grows the
    chain one page at a time toward max_new_tokens."""
    run, model, params = setup
    prompt = np.arange(16) % 100
    want = naive_generate(model, params, prompt, 20)
    eng = ServeEngine(run, params, slots=1, max_len=48, paged=True,
                      page_size=16)
    req = Request(rid=0, prompt=prompt, max_new_tokens=20)
    eng.submit(req)
    seen_pages = []
    while not req.done:
        eng.step()
        seen_pages.append(eng.alloc.pages_in_use)
    assert req.out == want
    # grew 1 -> 2 -> 3 pages on demand instead of reserving 3 up front
    assert seen_pages[0] == 2 and max(seen_pages) == 3
    assert eng.stats["lazy_extends"] == 2
    assert eng.alloc.pages_in_use == 0


def test_impossible_request_rejected_despite_lazy_growth(setup):
    """The full-need capacity check stays at admission: a request whose
    TOTAL footprint exceeds the pool must reject typed up front, not
    live-lock in an endless extend/preempt cycle mid-decode."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=1, max_len=48, paged=True,
                      page_size=8, num_pages=3)     # capacity 2
    bad = Request(rid=0, prompt=np.arange(8) % 100, max_new_tokens=16)
    eng.submit(bad)
    eng.step()
    assert bad.done and bad.error and "capacity" in bad.error
    assert eng.alloc.pages_in_use == 0


def test_preemption_replay_is_token_identical(setup):
    """CoW/extend exhaustion preempts a slot (free pages + requeue); the
    replay from scratch must emit exactly the same tokens (I10)."""
    run, model, params = setup
    prompt = np.arange(8) % 100
    want = naive_generate(model, params, prompt, 10)
    eng = ServeEngine(run, params, slots=2, max_len=48, paged=True,
                      page_size=8, num_pages=4)     # capacity 3
    reqs = [Request(rid=i, prompt=prompt, max_new_tokens=10)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    _drain(eng)
    # both fit at admission (1 prompt page each) but the pool cannot hold
    # both requests' full 3-page footprints -> one slot preempted
    assert eng.stats["preemptions"] >= 1
    assert [r.out for r in reqs] == [want, want]
    assert eng.alloc.pages_in_use == 0
    eng.alloc.check_invariants()


def test_exhaustion_defragments_once_and_counts_pressure(setup):
    """Satellite: CacheExhausted at admission triggers one production
    defragment() pass and both events land in engine stats (the fleet
    pumps them into MetricsBus for the autoscaler)."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=2, max_len=48, paged=True,
                      page_size=8, num_pages=4)     # capacity 3
    first = Request(rid=0, prompt=np.arange(8) % 100, max_new_tokens=10)
    second = Request(rid=1, prompt=np.arange(16) % 100, max_new_tokens=4)
    eng.submit(first)
    eng.step()                             # rid 0 resident, 1 page
    eng.submit(second)                     # needs 2 prompt pages; only
    _drain(eng)                            # fits once rid 0 progresses
    assert first.done and second.done
    assert eng.stats["cache_exhausted"] >= 1
    assert eng.stats["defrag_events"] >= 1
    assert eng.stats["cache_exhausted"] >= eng.stats["defrag_events"]


def test_fleet_exposes_cache_pressure_to_autoscaler(setup):
    """The telemetry path end-to-end: engine stats -> MetricsBus ->
    EngineStats fields the autoscaler policy reads."""
    from repro.serve.fleet import ServeFleet
    run, _, params = setup
    fleet = ServeFleet(run, params, num_engines=1, num_devices=2,
                       slots=2, max_len=48, paged=True, page_size=16,
                       share_prefix=True,
                       workdir=tempfile.mkdtemp())
    prompt = np.arange(16) % 100
    for i in range(2):
        fleet.submit(Request(rid=i, prompt=prompt, max_new_tokens=3))
    fleet.drain()
    snap = fleet.telemetry_snapshot()
    st = snap.engines[0]
    assert st.pages_free > 0 and st.pages_in_use == 0
    assert st.cache_exhausted == 0 and st.defrag_events == 0
    eng = fleet.tenants["serve0"].engine
    assert eng.stats["shared_page_hits"] >= 1
    assert "cache_exhausted" in fleet.telemetry.describe().get(
        "serve0", {"cache_exhausted": 0})


# ===========================================================================
# I12: refcount accounting == live block-table references
# ===========================================================================
class _VF:
    mesh_shape = (1, 1)
    mesh_axes = ("data", "model")
    devices = ("d0",)
    vf_id = "vf1"
    emulated: dict = {}


def _serve_system(tmp_path):
    from repro.sim.tenant import SimServeTenant
    pool = DevicePool(devices=tuple(f"d{i}" for i in range(4)))
    mgr = SVFFManager(pool, workdir=str(tmp_path),
                      staging=StagingEngine(num_queues=1),
                      scheduler="first_fit")
    tn = SimServeTenant("sv0", seed=2)
    mgr.init(num_vfs=2, tenants=[tn], devices_per_vf=2)
    tn.submit_burst(6)
    tn.run_steps(2)                        # pages held, sharing live
    assert tn.alloc.pages_in_use > 0
    return mgr, tn


def test_i12_catches_seeded_over_decref(tmp_path):
    """The acceptance bug: one decref too many on a shared page frees a
    page a sibling still reads through. I12 must catch it."""
    mgr, tn = _serve_system(tmp_path)
    check_invariants(mgr)                  # sane baseline
    page = tn.alloc.pages_of(
        next(r for r in tn.active if r is not None).rid)[0]
    tn.alloc._decref(page)                 # seeded over-decref
    with pytest.raises(InvariantViolation, match="I12"):
        check_invariants(mgr)


def test_i12_catches_table_chain_divergence(tmp_path):
    """A CoW that repoints the allocator chain but not the block-table
    row (or vice versa) must fail I12's table==chain cross-check."""
    mgr, tn = _serve_system(tmp_path)
    check_invariants(mgr)
    slot = next(s for s, r in enumerate(tn.active) if r is not None)
    tn.tables[slot, 0] = (tn.tables[slot, 0] % (tn.num_pages - 1)) + 1
    with pytest.raises(InvariantViolation, match="I12"):
        check_invariants(mgr)


def test_sim_i10_regression_seed_with_sharing():
    """Checked-in regression seed: serve traffic with prefix sharing ON
    (the sim tenant always shares) stays token-deterministic and replay-
    stable, and the run actually exercised sharing."""
    from repro.sim import ScenarioConfig, ScenarioRunner
    for policy in ("first_fit", "best_fit"):
        cfg = ScenarioConfig(seed=3, policy=policy, serve_rate=0.35,
                             num_ops=30)
        r1, r2 = ScenarioRunner(cfg), ScenarioRunner(cfg)
        assert r1.run().fingerprint() == r2.run().fingerprint()
        shared = sum(getattr(tn, "shared_hits", 0)
                     for tn in r1.tenants.values())
        assert shared > 0, "scenario never hit the prefix trie"
