"""Per-kernel sweeps: shapes x dtypes, assert_allclose vs the ref.py
oracles (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.paged_decode import paged_decode
from repro.kernels.qdma_pack import qdma_pack, qdma_unpack
from repro.kernels.ssm_scan import ssm_scan


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,K,hd,causal", [
    (1, 128, 4, 4, 64, True),        # MHA causal
    (2, 256, 4, 2, 64, True),        # GQA
    (1, 256, 8, 1, 128, True),       # MQA, wide head
    (2, 128, 2, 2, 64, False),       # bidirectional (encoder)
    (1, 384, 6, 3, 64, True),        # non-pow2 grid
])
def test_flash_attention_sweep(B, S, H, K, hd, causal, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = rand(ks[0], (B, S, H, hd), dtype)
    k = rand(ks[1], (B, S, K, hd), dtype)
    v = rand(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("blocks", [(64, 64), (128, 32)])
def test_flash_attention_block_shapes(blocks):
    bq, bk = blocks
    ks = jax.random.split(jax.random.key(1), 3)
    q = rand(ks[0], (1, 256, 2, 64), jnp.float32)
    k = rand(ks[1], (1, 256, 2, 64), jnp.float32)
    v = rand(ks[2], (1, 256, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,K,hd,pos", [
    (1, 256, 4, 4, 64, 255),
    (2, 512, 4, 2, 64, 17),          # pos inside first block
    (1, 1024, 8, 2, 128, 700),
    (2, 256, 2, 1, 64, 0),           # single valid position
])
def test_flash_decode_sweep(B, T, H, K, hd, pos, dtype):
    ks = jax.random.split(jax.random.key(2), 3)
    q = rand(ks[0], (B, 1, H, hd), dtype)
    k = rand(ks[1], (B, T, K, hd), dtype)
    v = rand(ks[2], (B, T, K, hd), dtype)
    out = flash_decode(q, k, v, pos, interpret=True)
    want = ref.flash_decode_ref(q, k, v, pos)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_decode_matches_flash_attention_last_row():
    """decode(pos=S-1) == last row of causal flash_attention."""
    ks = jax.random.split(jax.random.key(3), 3)
    S = 256
    q = rand(ks[0], (1, S, 4, 64), jnp.float32)
    k = rand(ks[1], (1, S, 2, 64), jnp.float32)
    v = rand(ks[2], (1, S, 2, 64), jnp.float32)
    full = flash_attention(q, k, v, causal=True, interpret=True)
    dec = flash_decode(q[:, -1:], k, v, S - 1, interpret=True)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,hd,page,NP,P,positions", [
    (1, 4, 4, 64, 16, 4, 8, (63,)),          # MHA, full view valid
    (3, 4, 2, 64, 8, 4, 16, (5, -1, 31)),    # GQA, one inactive slot
    (2, 8, 2, 128, 32, 2, 5, (0, 40)),       # MQA-ish wide head
    (2, 2, 1, 64, 8, 8, 17, (-1, -1)),       # all slots inactive
])
def test_paged_decode_sweep(B, H, K, hd, page, NP, P, positions, dtype):
    ks = jax.random.split(jax.random.key(4), 3)
    q = rand(ks[0], (B, 1, H, hd), dtype)
    kp = rand(ks[1], (P, page, K, hd), dtype)
    vp = rand(ks[2], (P, page, K, hd), dtype)
    rng = np.random.default_rng(0)
    # arbitrary page ids (reads may alias; page 0 stays reserved for writes)
    tables = jnp.asarray(rng.integers(1, P, (B, NP)), jnp.int32)
    pos = jnp.asarray(positions, jnp.int32)
    out = paged_decode(q, kp, vp, tables, pos, interpret=True)
    want = ref.paged_decode_ref(q, kp, vp, tables, pos)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])
    # inactive slots produce exactly-zero rows in BOTH implementations
    for b, p in enumerate(positions):
        if p < 0:
            assert np.all(np.asarray(out[b]) == 0)
            assert np.all(np.asarray(want[b]) == 0)


def test_paged_decode_matches_flash_decode_contiguous_view():
    """With an identity-ordered table the paged kernel equals flash_decode
    over the gathered contiguous cache."""
    ks = jax.random.split(jax.random.key(5), 3)
    page, NP, P, K, hd = 32, 4, 9, 2, 64
    q = rand(ks[0], (1, 1, 4, hd), jnp.float32)
    kp = rand(ks[1], (P, page, K, hd), jnp.float32)
    vp = rand(ks[2], (P, page, K, hd), jnp.float32)
    tables = jnp.asarray([[3, 1, 8, 5]], jnp.int32)
    pos = 77
    out = paged_decode(q, kp, vp, tables, jnp.asarray([pos], jnp.int32),
                       interpret=True)
    k = kp[tables[0]].reshape(1, NP * page, K, hd)
    v = vp[tables[0]].reshape(1, NP * page, K, hd)
    want = flash_decode(q, k, v, pos, block_k=page, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,hd,N,chunk", [
    (1, 128, 2, 32, 16, 32),
    (2, 256, 4, 64, 64, 64),
    (1, 512, 1, 128, 16, 128),
])
def test_ssm_scan_sweep(B, S, H, hd, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(4), 4)
    xdt = rand(ks[0], (B, S, H, hd), dtype)
    Bv = rand(ks[1], (B, S, N), dtype)
    Cv = rand(ks[2], (B, S, N), dtype)
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    y, hf = ssm_scan(xdt, Bv, Cv, la, chunk=chunk, interpret=True)
    yr, hfr = ref.ssm_scan_sequential_ref(xdt, Bv, Cv, la)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr), atol=tol,
                               rtol=tol)


def test_ssm_scan_matches_chunked_ref():
    ks = jax.random.split(jax.random.key(5), 4)
    B, S, H, hd, N = 2, 256, 3, 32, 16
    xdt = rand(ks[0], (B, S, H, hd), jnp.float32)
    Bv = rand(ks[1], (B, S, N), jnp.float32)
    Cv = rand(ks[2], (B, S, N), jnp.float32)
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    y, hf = ssm_scan(xdt, Bv, Cv, la, chunk=64, interpret=True)
    yr, hfr = ref.ssm_scan_ref(xdt, Bv, Cv, la, chunk=64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4,
                               rtol=2e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,block", [
    ((4, 512), 256), ((3, 7, 512), 128), ((128, 256), 256), ((2, 1024), 512),
])
def test_qdma_pack_sweep(shape, block, dtype):
    x = rand(jax.random.key(6), shape, dtype)
    q, s = qdma_pack(x, block=block, interpret=True)
    qr, sr = ref.qdma_pack_ref(x, block=block)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    # identical up to round-to-nearest ties on values landing exactly on a
    # quantization boundary (last-ulp division-order differences)
    diff = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    assert diff.max() <= 1 and (diff > 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # round-trip error bounded by the quantization step
    xx = qdma_unpack(q, s, dtype="float32", interpret=True)
    step = np.asarray(s)[..., :, None] * np.ones((1,) * s.ndim + (block,))
    err = np.abs(np.asarray(xx) -
                 np.asarray(x, np.float32).reshape(xx.shape))
    assert (err <= 0.5 * step.reshape(err.shape) + 1e-6).all()


def test_qdma_pack_preserves_zeros_and_extremes():
    x = jnp.zeros((4, 512), jnp.float32).at[0, 0].set(1000.0)
    q, s = qdma_pack(x, block=256, interpret=True)
    xx = qdma_unpack(q, s, interpret=True)
    assert float(xx[0, 0]) == pytest.approx(1000.0, rel=1e-2)
    assert float(jnp.abs(xx[1:]).max()) == 0.0
