"""Pipelined descriptor engine: per-tenant memo lifecycle, dirty
tracking (identity + digest), live-pause stall accounting, and the
multi-device restore paths (NamedSharding + quantized leaves) that the
pause/unpause cycle exercises on a real mesh."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DevicePool, StagingEngine, SVFFManager, pause_vf,
                        pause_vf_live, unpause_vf)
from repro.core.vf import VFState, VirtualFunction
from repro.sim import (ServeSimTenant, SimTenant, check_invariants,
                       check_pause_timings)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# memo lifecycle (satellite: bound StagingEngine._memo)
# ---------------------------------------------------------------------------
def _tree(seed=0, n=2048):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.standard_normal((n,)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)}


def test_memo_scoped_per_tenant_and_cleared():
    eng = StagingEngine(num_queues=2, incremental=True)
    ta, tb = _tree(1), _tree(2)
    eng.save(ta, tenant="vmA")
    eng.save(tb, tenant="vmB")
    assert eng.memo_size("vmA") == 2 and eng.memo_size("vmB") == 2
    assert eng.memo_size() == 4
    eng.save(ta, tenant="vmA")
    assert eng.last_stats.bytes_moved == 0        # hit within scope
    eng.save(tb, tenant="vmA")                    # other tenant's tree: miss
    assert eng.last_stats.bytes_moved > 0
    eng.clear("vmA")
    assert eng.memo_size("vmA") == 0 and eng.memo_size("vmB") == 2
    eng.save(ta, tenant="vmA")
    assert eng.last_stats.bytes_moved > 0         # memo really gone
    eng.clear()
    assert eng.memo_size() == 0


def test_manager_detach_clears_tenant_memo(tmp_path):
    pool = DevicePool(devices=tuple(f"d{i}" for i in range(4)))
    staging = StagingEngine(num_queues=1, incremental=True)
    mgr = SVFFManager(pool, workdir=str(tmp_path), staging=staging)
    tn = SimTenant("vm0", seed=0)
    mgr.init(num_vfs=2, tenants=[tn], devices_per_vf=1)
    tn.run_steps(1)
    staging.save(tn.export_state(), tenant=tn.tid)
    # SimTenant state is numpy (identity mode memoizes only jax arrays),
    # so plant a sentinel to prove detach really empties the scope
    staging._memo_for(tn.tid)["sentinel"] = object()
    assert staging.memo_size(tn.tid) == 1
    mgr.detach(tn)
    assert staging.memo_size(tn.tid) == 0            # emptied on detach
    check_invariants(mgr)


def test_pause_clears_tenant_memo(tmp_path):
    pool = DevicePool(devices=tuple(f"d{i}" for i in range(4)))
    staging = StagingEngine(num_queues=1, incremental=True)
    mgr = SVFFManager(pool, workdir=str(tmp_path), staging=staging)
    tn = SimTenant("vm0", seed=0)
    mgr.init(num_vfs=2, tenants=[tn], devices_per_vf=1)
    mgr.pause(tn)
    assert staging.memo_size(tn.tid) == 0
    mgr.unpause(tn)
    check_invariants(mgr)


# ---------------------------------------------------------------------------
# dirty tracking
# ---------------------------------------------------------------------------
def test_digest_dirty_tracking_skips_equal_content():
    eng = StagingEngine(num_queues=2, incremental=True, dirty="digest")
    tree = _tree(3)
    eng.save(tree, tenant="t")
    clone = {k: v * 1.0 for k, v in tree.items()}    # new objects, = bytes
    eng.save(clone, tenant="t")
    assert eng.last_stats.bytes_moved == 0
    assert eng.last_stats.skipped_bytes > 0
    changed = dict(clone)
    changed["w"] = clone["w"] + 1.0
    eng.save(changed, tenant="t")
    assert eng.last_stats.bytes_moved == changed["w"].nbytes


def test_identity_dirty_tracking_requires_same_object():
    eng = StagingEngine(num_queues=2, incremental=True)
    tree = _tree(4)
    eng.save(tree, tenant="t")
    clone = {k: v * 1.0 for k, v in tree.items()}
    eng.save(clone, tenant="t")
    assert eng.last_stats.bytes_moved > 0            # identity can't prove


# ---------------------------------------------------------------------------
# live pause (unit level; the sim covers it op-by-op)
# ---------------------------------------------------------------------------
def _attached_vf(tid, vid="0000:0a:00.1"):
    vf = VirtualFunction(vf_id=vid)
    vf.assign_devices(jax.devices()[:1], (1, 1))
    vf.transition(VFState.ATTACHED)
    vf.owner = tid
    return vf


def _mini_tenant(tid="vm0"):
    return ServeSimTenant(jnp.arange(4096, dtype=jnp.float32),
                          jnp.zeros((8,), jnp.float32), tid=tid)


def test_pause_vf_live_precopy_accounting_and_bit_identity():
    pool = DevicePool(devices=jax.devices())
    tn = _mini_tenant()
    vf = _attached_vf(tn.tid)
    tn.vf_id = vf.vf_id
    staging = StagingEngine(num_queues=2, incremental=True)
    tn.step()
    want_params = np.asarray(tn.params).copy()
    stepped = [0]

    def live_step():
        tn.step()
        stepped[0] += 1
    snap, t = pause_vf_live(pool, vf, tn, staging, rounds=2,
                            step_fn=live_step)
    check_pause_timings(t, live=True)
    assert stepped[0] == 2                       # kept working during rounds
    assert t.background == {"precopy_0", "precopy_1"}
    assert t.stop_s < t.total
    assert snap.precopy_rounds == 2
    assert snap.steps_done == tn.steps_done == 3
    # final payload reflects post-round state; params untouched
    vf.assign_devices(jax.devices()[:1], (1, 1))
    unpause_vf(pool, vf, tn, snap, staging)
    np.testing.assert_array_equal(np.asarray(tn.params), want_params)
    np.testing.assert_array_equal(np.asarray(tn.cache),
                                  np.full((8,), 3.0, np.float32))
    # params moved in the background rounds, not in the stop-and-copy
    assert snap.stats.skipped_bytes >= want_params.nbytes


def test_pause_vf_stop_equals_total():
    pool = DevicePool(devices=jax.devices())
    tn = _mini_tenant("vm1")
    vf = _attached_vf(tn.tid, "0000:0a:00.2")
    tn.vf_id = vf.vf_id
    snap, t = pause_vf(pool, vf, tn, StagingEngine(num_queues=1))
    check_pause_timings(t, live=False)
    assert t.background == set()
    assert abs(t.stop_s - t.total) < 1e-12


# ---------------------------------------------------------------------------
# _scale_sharding + restore(shardings=...) on a 2-device mesh (subprocess:
# XLA pins the host device count at first init)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_restore_quantized_with_named_sharding_on_mesh():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=2"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import StagingEngine
        from repro.core.staging import _scale_sharding

        mesh = Mesh(np.array(jax.devices()).reshape(2, 1), ("dp", "mp"))
        sh = NamedSharding(mesh, P("dp", None))
        rep = NamedSharding(mesh, P())
        # _scale_sharding maps any NamedSharding to full replication
        ssh = _scale_sharding(sh)
        assert isinstance(ssh, NamedSharding) and ssh.spec == P(), ssh
        assert _scale_sharding(None) is None
        assert _scale_sharding(object()) is None

        rng = np.random.default_rng(0)
        tree = {
            "big": jax.device_put(jnp.asarray(
                rng.standard_normal((16, 512)), jnp.float32), sh),
            "odd": jax.device_put(jnp.asarray(
                rng.standard_normal((7, 33)), jnp.float32), rep),
            "idx": jax.device_put(jnp.asarray(
                rng.integers(0, 50, (6,)), jnp.int32), rep),
        }
        shardings = {"big": sh, "odd": rep, "idx": rep}
        results = {}
        for name, kw in (
                ("plain", {}),
                ("stream", {"transport": "stream", "chunk_bytes": 2048}),
                ("int8", {"compression": "int8", "min_quant_size": 1024}),
                ("int8_stream", {"compression": "int8",
                                 "min_quant_size": 1024,
                                 "transport": "stream",
                                 "chunk_bytes": 2048})):
            eng = StagingEngine(num_queues=2, **kw)
            staged = eng.save(tree)
            out = eng.restore(staged, shardings=shardings)
            jax.block_until_ready(out)
            # quantized restore computes through qdma_unpack, so only
            # assert target shardings on the directly-placed leaves there
            ok_shard = out["odd"].sharding.is_equivalent_to(rep, 2)
            if "int8" not in name:
                ok_shard = (ok_shard and
                            out["big"].sharding.is_equivalent_to(sh, 2))
            exact = all(
                np.array_equal(np.asarray(tree[k]), np.asarray(out[k]))
                for k in ("odd", "idx"))
            if "int8" in name:
                a = np.asarray(tree["big"]); b = np.asarray(out["big"])
                big_ok = bool(np.abs(a - b).max() <= np.abs(a).max() / 64)
            else:
                big_ok = bool(np.array_equal(np.asarray(tree["big"]),
                                             np.asarray(out["big"])))
            results[name] = bool(ok_shard and exact and big_ok)
        print(json.dumps(results))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res == {"plain": True, "stream": True, "int8": True,
                   "int8_stream": True}, res
