"""Scenario-simulation subsystem: determinism of the generator, invariant
preservation over hundreds of randomized multi-tenant histories (per
placement policy), rejection atomicity, and checker sensitivity."""
import pytest

from repro.core import DevicePool, SVFFManager, StagingEngine
from repro.sim import (InvariantViolation, ScenarioConfig, ScenarioRunner,
                       SimTenant, VirtualClock, check_invariants,
                       check_timings, generate_scenario)

POLICIES = ("first_fit", "best_fit", "fair_share")
SCENARIOS_PER_POLICY = 70        # 3 x 70 = 210 randomized scenarios


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
def test_generator_deterministic():
    for seed in range(10):
        cfg = ScenarioConfig(seed=seed)
        assert generate_scenario(cfg) == generate_scenario(cfg)
    assert (generate_scenario(ScenarioConfig(seed=1))
            != generate_scenario(ScenarioConfig(seed=2)))


def test_generator_starts_with_init_and_respects_length():
    for seed in range(10):
        ops = generate_scenario(ScenarioConfig(seed=seed, num_ops=30))
        assert ops[0].kind == "init"
        assert len(ops) == 30
        assert all(o.kind != "init" for o in ops[1:])


@pytest.mark.parametrize("policy", POLICIES)
def test_replay_determinism_gate(policy):
    """CI regression gate for accidental nondeterminism anywhere in the
    staging/scheduler/pause/journal paths: every seed replays to the same
    fingerprint (identical per-op outcomes and final tenant states) under
    every placement policy — thread-pool transfer order, dict iteration,
    or wall-clock leakage into outcomes would all show here as a flaky
    mismatch. This is also what makes any failing scenario reproducible
    from its seed alone."""
    for seed in (0, 1, 2, 3, 4, 11):
        cfg = ScenarioConfig(seed=seed, policy=policy)
        a = ScenarioRunner(cfg).run()
        b = ScenarioRunner(cfg).run()
        assert a.fingerprint() == b.fingerprint(), (
            f"seed={seed} policy={policy} replay diverged")
        assert a.virtual_seconds == b.virtual_seconds


# ---------------------------------------------------------------------------
# the main property: invariants hold across randomized histories
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_randomized_scenarios_hold_invariants(policy):
    """70 seeded scenarios per policy; ScenarioRunner asserts all
    invariants after every op and raises InvariantViolation otherwise.
    Valid ops must succeed; only deliberate chaos ops may be rejected
    (and those must be rejected ATOMICALLY — the post-op invariant check
    runs either way)."""
    total_ok = total_rejected = 0
    for seed in range(SCENARIOS_PER_POLICY):
        res = ScenarioRunner(ScenarioConfig(seed=seed,
                                            policy=policy)).run()
        for r in res.ops:
            if r.status == "rejected":
                assert r.op.chaos, (
                    f"seed={seed} policy={policy}: valid op rejected: "
                    f"{r.op} -> {r.error}")
        for t in res.reconf_timings:
            check_timings(t)
        total_ok += res.num_ok
        total_rejected += res.num_rejected
    assert total_ok > SCENARIOS_PER_POLICY * 10   # scenarios actually ran
    assert total_rejected > 0                     # chaos ops exercised


# ---------------------------------------------------------------------------
# elastic pipeline gangs in the scenario plane (reshape op, I14)
# ---------------------------------------------------------------------------
def test_generator_reshape_rate_zero_is_byte_identical():
    """reshape_rate=0 must not perturb a single rng draw: pre-gang
    sequences stay byte-identical (the knob is truthiness-gated)."""
    for seed in range(8):
        a = generate_scenario(ScenarioConfig(seed=seed, serve_rate=0.3,
                                             crash_rate=0.05))
        b = generate_scenario(ScenarioConfig(seed=seed, serve_rate=0.3,
                                             crash_rate=0.05,
                                             reshape_rate=0.0))
        assert a == b


def test_generator_emits_reshape_ops_within_budget():
    """With room for the gang (max_vfs=8) the generator attaches pg0 and
    alternates its width 2<->3; every reshape op targets the lead and
    the gang's VF take stays within the pool."""
    saw_reshape = False
    for seed in range(6):
        ops = generate_scenario(ScenarioConfig(
            seed=seed, num_ops=40, serve_rate=0.3, reshape_rate=0.3,
            max_vfs=8))
        gang = [o for o in ops if o.tenant == "pg0"]
        assert gang and gang[0].kind == "attach"
        widths = [o.num_vfs for o in ops if o.kind == "reshape"]
        assert all(o.tenant == "pg0" for o in ops if o.kind == "reshape")
        assert all(w in (2, 3) for w in widths)
        for a, b in zip([2] + widths, widths):
            assert a != b                 # always an actual width change
        saw_reshape = saw_reshape or bool(widths)
    assert saw_reshape


@pytest.mark.parametrize("policy", POLICIES)
def test_reshape_scenarios_hold_invariants(policy):
    """Gang scenarios (reshape + serve traffic + crash ops interleaved)
    hold every invariant including I14 after each op, and replay to
    identical fingerprints.  Autoscale is off so the generator's
    validity model is exact and every non-chaos op must succeed (an
    autoscaler-attached engine would consume free VFs the model cannot
    see — same caveat as the autoscale suite)."""
    for seed in range(8):
        cfg = ScenarioConfig(seed=seed, policy=policy, num_ops=45,
                             serve_rate=0.3, reshape_rate=0.25,
                             crash_rate=0.06, max_vfs=8)
        res = ScenarioRunner(cfg).run()
        for r in res.ops:
            if r.status == "rejected":
                assert r.op.chaos, (
                    f"seed={seed}: valid op rejected: {r.op} -> "
                    f"{r.error}")
        assert res.fingerprint() == ScenarioRunner(cfg).run().fingerprint()


def test_reshape_with_autoscale_interleaved():
    """Reshape interleaved with the autoscale plane: scale_out may
    legitimately consume the free VF a planned grow-reshape counted on,
    so rejections are permitted here — but each must be ATOMIC (the
    harness checks all invariants, I14 included, after every op either
    way) and the whole history must replay to the same fingerprint.
    Seeds are fixed (as in the autoscale suite) because the generator's
    validity model is only approximate once the autoscaler acts."""
    for seed in (1, 4, 5):
        cfg = ScenarioConfig(seed=seed, num_ops=45, serve_rate=0.3,
                             reshape_rate=0.25, autoscale_rate=0.1,
                             crash_rate=0.06, max_vfs=8)
        res = ScenarioRunner(cfg).run()
        assert res.fingerprint() == ScenarioRunner(cfg).run().fingerprint()


# ---------------------------------------------------------------------------
# checker sensitivity: a vacuous checker would pass everything
# ---------------------------------------------------------------------------
def _small_system(tmp_path, policy="first_fit"):
    pool = DevicePool(devices=tuple(f"d{i}" for i in range(8)))
    mgr = SVFFManager(pool, workdir=str(tmp_path),
                      staging=StagingEngine(num_queues=1),
                      scheduler=policy)
    tn = SimTenant("vm0", seed=0)
    mgr.init(num_vfs=2, tenants=[tn], devices_per_vf=2)
    return pool, mgr, tn


def test_checker_detects_ownership_corruption(tmp_path):
    pool, mgr, tn = _small_system(tmp_path)
    check_invariants(mgr)                         # sane baseline
    pool.find(tn.vf_id).owner = None
    with pytest.raises(InvariantViolation, match="I2"):
        check_invariants(mgr)


def test_checker_detects_state_corruption(tmp_path):
    _, mgr, tn = _small_system(tmp_path)
    tn.run_steps(2)
    check_invariants(mgr)
    tn._state["params"]["w0"] = tn._state["params"]["w0"] + 1.0
    with pytest.raises(InvariantViolation, match="I4"):
        check_invariants(mgr)


def test_checker_detects_gang_width_drift(tmp_path):
    """I14 sensitivity: a lead whose width disagrees with its running
    shell count (half-applied reshape) must be caught."""
    from repro.sim.tenant import SimPipelineTenant
    pool = DevicePool(devices=tuple(f"d{i}" for i in range(8)))
    mgr = SVFFManager(pool, workdir=str(tmp_path),
                      staging=StagingEngine(num_queues=1))
    lead = SimPipelineTenant("pg0", seed=0, width=2, max_width=3)
    mgr.init(num_vfs=4, tenants=[])
    mgr.attach_group(lead)
    check_invariants(mgr)                          # sane baseline
    lead._width = 3                                # width moved, no shell
    with pytest.raises(InvariantViolation, match="I14"):
        check_invariants(mgr)
    lead._width = 2
    check_invariants(mgr)
    bad = lead.stage_bounds()[:-1] + (99,)         # broken partition
    lead.stage_bounds = lambda: bad
    with pytest.raises(InvariantViolation, match="I14"):
        check_invariants(mgr)


def test_checker_detects_lost_snapshot(tmp_path):
    _, mgr, tn = _small_system(tmp_path)
    mgr.pause(tn)
    check_invariants(mgr)
    mgr.snapshots.pop(tn.tid)
    with pytest.raises(InvariantViolation, match="I3"):
        check_invariants(mgr)


def test_checker_detects_record_drift(tmp_path):
    _, mgr, tn = _small_system(tmp_path)
    check_invariants(mgr)
    mgr.records.remove(tn.tid)
    with pytest.raises(InvariantViolation, match="I5"):
        check_invariants(mgr)


def test_timing_dict_validation():
    good = {"rescan": 0.1, "remove_vf": 0.0, "change_num_vf": 0.2,
            "add_vf": 0.3, "total": 0.6}
    check_timings(good)
    with pytest.raises(InvariantViolation, match="I6"):
        check_timings({**good, "extra": 1.0})
    with pytest.raises(InvariantViolation, match="I6"):
        check_timings({**good, "rescan": -1.0, "total": -0.5})
    with pytest.raises(InvariantViolation, match="I6"):
        check_timings({**good, "total": 99.0})


# ---------------------------------------------------------------------------
# rejection atomicity (direct, not via generator)
# ---------------------------------------------------------------------------
def test_rejected_ops_leave_invariants_intact(tmp_path):
    from repro.core import AdmissionError, PoolError, PauseError
    pool, mgr, tn = _small_system(tmp_path)
    other = SimTenant("vm1", seed=1)
    mgr.attach(other)                              # pool now full
    with pytest.raises(AdmissionError):            # no free VF
        mgr.attach(SimTenant("vm2", seed=2))
    check_invariants(mgr)
    mgr.pause(tn)
    with pytest.raises(PoolError):                 # can't detach paused
        mgr.detach(tn)
    check_invariants(mgr)
    with pytest.raises(PauseError):                # double pause
        mgr.pause(tn)
    check_invariants(mgr)
    mgr.unpause(tn)
    check_invariants(mgr)


def test_failed_unpause_keeps_snapshot_retryable(tmp_path):
    """The RAM snapshot is a paused tenant's only state copy; a failed
    unpause must not consume it."""
    from repro.core import PoolError
    _, mgr, tn = _small_system(tmp_path)
    mgr.pause(tn)
    with pytest.raises(PoolError):
        mgr.unpause(tn, vf_id="0000:03:00.99")     # no such VF
    check_invariants(mgr)                          # snapshot still held
    mgr.unpause(tn)                                # retry succeeds
    check_invariants(mgr)
    assert tn.status == "running"


def test_explicit_vf_attach_goes_through_admission(tmp_path):
    """attach(vf_id=...) must not let a running tenant bind a second VF
    (which would leak its first VF permanently ATTACHED)."""
    from repro.core import AdmissionError
    pool, mgr, tn = _small_system(tmp_path)
    free_vf = next(vf.vf_id for vf in pool.vfs.values()
                   if vf.owner is None)
    with pytest.raises(AdmissionError):
        mgr.attach(tn, vf_id=free_vf)
    check_invariants(mgr)


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------
def test_virtual_clock():
    c = VirtualClock()
    assert c.now() == 0.0
    c.advance(1.5)
    c.stamp("x", tenant="vm0")
    assert c.now() == 1.5 and c.events[0]["t"] == 1.5
    with pytest.raises(ValueError):
        c.advance(-1.0)
