"""Scenario-simulation subsystem: determinism of the generator, invariant
preservation over hundreds of randomized multi-tenant histories (per
placement policy), rejection atomicity, and checker sensitivity."""
import pytest

from repro.core import DevicePool, SVFFManager, StagingEngine
from repro.sim import (InvariantViolation, ScenarioConfig, ScenarioRunner,
                       SimTenant, VirtualClock, check_invariants,
                       check_timings, generate_scenario)

POLICIES = ("first_fit", "best_fit", "fair_share")
SCENARIOS_PER_POLICY = 70        # 3 x 70 = 210 randomized scenarios


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
def test_generator_deterministic():
    for seed in range(10):
        cfg = ScenarioConfig(seed=seed)
        assert generate_scenario(cfg) == generate_scenario(cfg)
    assert (generate_scenario(ScenarioConfig(seed=1))
            != generate_scenario(ScenarioConfig(seed=2)))


def test_generator_starts_with_init_and_respects_length():
    for seed in range(10):
        ops = generate_scenario(ScenarioConfig(seed=seed, num_ops=30))
        assert ops[0].kind == "init"
        assert len(ops) == 30
        assert all(o.kind != "init" for o in ops[1:])


@pytest.mark.parametrize("policy", POLICIES)
def test_replay_determinism_gate(policy):
    """CI regression gate for accidental nondeterminism anywhere in the
    staging/scheduler/pause/journal paths: every seed replays to the same
    fingerprint (identical per-op outcomes and final tenant states) under
    every placement policy — thread-pool transfer order, dict iteration,
    or wall-clock leakage into outcomes would all show here as a flaky
    mismatch. This is also what makes any failing scenario reproducible
    from its seed alone."""
    for seed in (0, 1, 2, 3, 4, 11):
        cfg = ScenarioConfig(seed=seed, policy=policy)
        a = ScenarioRunner(cfg).run()
        b = ScenarioRunner(cfg).run()
        assert a.fingerprint() == b.fingerprint(), (
            f"seed={seed} policy={policy} replay diverged")
        assert a.virtual_seconds == b.virtual_seconds


# ---------------------------------------------------------------------------
# the main property: invariants hold across randomized histories
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_randomized_scenarios_hold_invariants(policy):
    """70 seeded scenarios per policy; ScenarioRunner asserts all
    invariants after every op and raises InvariantViolation otherwise.
    Valid ops must succeed; only deliberate chaos ops may be rejected
    (and those must be rejected ATOMICALLY — the post-op invariant check
    runs either way)."""
    total_ok = total_rejected = 0
    for seed in range(SCENARIOS_PER_POLICY):
        res = ScenarioRunner(ScenarioConfig(seed=seed,
                                            policy=policy)).run()
        for r in res.ops:
            if r.status == "rejected":
                assert r.op.chaos, (
                    f"seed={seed} policy={policy}: valid op rejected: "
                    f"{r.op} -> {r.error}")
        for t in res.reconf_timings:
            check_timings(t)
        total_ok += res.num_ok
        total_rejected += res.num_rejected
    assert total_ok > SCENARIOS_PER_POLICY * 10   # scenarios actually ran
    assert total_rejected > 0                     # chaos ops exercised


# ---------------------------------------------------------------------------
# checker sensitivity: a vacuous checker would pass everything
# ---------------------------------------------------------------------------
def _small_system(tmp_path, policy="first_fit"):
    pool = DevicePool(devices=tuple(f"d{i}" for i in range(8)))
    mgr = SVFFManager(pool, workdir=str(tmp_path),
                      staging=StagingEngine(num_queues=1),
                      scheduler=policy)
    tn = SimTenant("vm0", seed=0)
    mgr.init(num_vfs=2, tenants=[tn], devices_per_vf=2)
    return pool, mgr, tn


def test_checker_detects_ownership_corruption(tmp_path):
    pool, mgr, tn = _small_system(tmp_path)
    check_invariants(mgr)                         # sane baseline
    pool.find(tn.vf_id).owner = None
    with pytest.raises(InvariantViolation, match="I2"):
        check_invariants(mgr)


def test_checker_detects_state_corruption(tmp_path):
    _, mgr, tn = _small_system(tmp_path)
    tn.run_steps(2)
    check_invariants(mgr)
    tn._state["params"]["w0"] = tn._state["params"]["w0"] + 1.0
    with pytest.raises(InvariantViolation, match="I4"):
        check_invariants(mgr)


def test_checker_detects_lost_snapshot(tmp_path):
    _, mgr, tn = _small_system(tmp_path)
    mgr.pause(tn)
    check_invariants(mgr)
    mgr.snapshots.pop(tn.tid)
    with pytest.raises(InvariantViolation, match="I3"):
        check_invariants(mgr)


def test_checker_detects_record_drift(tmp_path):
    _, mgr, tn = _small_system(tmp_path)
    check_invariants(mgr)
    mgr.records.remove(tn.tid)
    with pytest.raises(InvariantViolation, match="I5"):
        check_invariants(mgr)


def test_timing_dict_validation():
    good = {"rescan": 0.1, "remove_vf": 0.0, "change_num_vf": 0.2,
            "add_vf": 0.3, "total": 0.6}
    check_timings(good)
    with pytest.raises(InvariantViolation, match="I6"):
        check_timings({**good, "extra": 1.0})
    with pytest.raises(InvariantViolation, match="I6"):
        check_timings({**good, "rescan": -1.0, "total": -0.5})
    with pytest.raises(InvariantViolation, match="I6"):
        check_timings({**good, "total": 99.0})


# ---------------------------------------------------------------------------
# rejection atomicity (direct, not via generator)
# ---------------------------------------------------------------------------
def test_rejected_ops_leave_invariants_intact(tmp_path):
    from repro.core import AdmissionError, PoolError, PauseError
    pool, mgr, tn = _small_system(tmp_path)
    other = SimTenant("vm1", seed=1)
    mgr.attach(other)                              # pool now full
    with pytest.raises(AdmissionError):            # no free VF
        mgr.attach(SimTenant("vm2", seed=2))
    check_invariants(mgr)
    mgr.pause(tn)
    with pytest.raises(PoolError):                 # can't detach paused
        mgr.detach(tn)
    check_invariants(mgr)
    with pytest.raises(PauseError):                # double pause
        mgr.pause(tn)
    check_invariants(mgr)
    mgr.unpause(tn)
    check_invariants(mgr)


def test_failed_unpause_keeps_snapshot_retryable(tmp_path):
    """The RAM snapshot is a paused tenant's only state copy; a failed
    unpause must not consume it."""
    from repro.core import PoolError
    _, mgr, tn = _small_system(tmp_path)
    mgr.pause(tn)
    with pytest.raises(PoolError):
        mgr.unpause(tn, vf_id="0000:03:00.99")     # no such VF
    check_invariants(mgr)                          # snapshot still held
    mgr.unpause(tn)                                # retry succeeds
    check_invariants(mgr)
    assert tn.status == "running"


def test_explicit_vf_attach_goes_through_admission(tmp_path):
    """attach(vf_id=...) must not let a running tenant bind a second VF
    (which would leak its first VF permanently ATTACHED)."""
    from repro.core import AdmissionError
    pool, mgr, tn = _small_system(tmp_path)
    free_vf = next(vf.vf_id for vf in pool.vfs.values()
                   if vf.owner is None)
    with pytest.raises(AdmissionError):
        mgr.attach(tn, vf_id=free_vf)
    check_invariants(mgr)


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------
def test_virtual_clock():
    c = VirtualClock()
    assert c.now() == 0.0
    c.advance(1.5)
    c.stamp("x", tenant="vm0")
    assert c.now() == 1.5 and c.events[0]["t"] == 1.5
    with pytest.raises(ValueError):
        c.advance(-1.0)
