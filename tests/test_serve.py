"""Serve engine: continuous batching correctness + pause semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import make_run_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    run = make_run_config("qwen3-0.6b", "decode_32k", smoke=True)
    model = build_model(run)
    params = model.init(jax.random.key(0))
    return run, model, params


def naive_generate(model, params, prompt, n, max_len=48):
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    cache, last = jax.jit(model.prefill)(params, batch)

    def pad(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v"):
            return jnp.pad(x, ((0, 0), (0, 0), (0, max_len - x.shape[2]),
                               (0, 0), (0, 0)))
        return x
    cache = jax.tree_util.tree_map_with_path(pad, cache)
    toks = [int(jnp.argmax(last[0]))]
    pos = len(prompt) - 1
    dec = jax.jit(model.decode_step)
    for _ in range(n - 1):
        pos += 1
        lg, cache = dec(params, cache,
                        jnp.asarray([[toks[-1]]], jnp.int32), jnp.int32(pos))
        toks.append(int(jnp.argmax(lg[0])))
    return toks


def test_engine_matches_naive_with_slot_recycling(setup):
    run, model, params = setup
    prompts = [np.arange(4) % 100, (np.arange(7) * 3) % 100,
               (np.arange(5) * 5 + 2) % 100]
    want = [naive_generate(model, params, p, 6) for p in prompts]
    eng = ServeEngine(run, params, slots=2, max_len=48)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    steps = 0
    while (eng.step() or eng.queue) and steps < 100:
        steps += 1
    for r, w in zip(reqs, want):
        assert r.out == w, (r.rid, r.out, w)
        assert r.done


def test_engine_pause_queues_requests(setup):
    run, model, params = setup
    eng = ServeEngine(run, params, slots=2, max_len=48)
    eng.pause()
    eng.submit(Request(rid=0, prompt=np.arange(4) % 50, max_new_tokens=3))
    assert eng.step() == 0 and len(eng.queue) == 1   # held while paused
    eng.unpause()
    steps = 0
    while (eng.step() or eng.queue) and steps < 50:
        steps += 1
    assert len(eng.queue) == 0


def test_run_until_idle_returns_finished_requests(setup):
    """Regression: run_until_idle used to always return [] — finished
    requests (decode-finished AND prefill-finished) must be collected."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=2, max_len=48)
    reqs = [Request(rid=0, prompt=np.arange(4) % 100, max_new_tokens=4),
            Request(rid=1, prompt=(np.arange(6) * 3) % 100,
                    max_new_tokens=1),       # finishes at prefill
            Request(rid=2, prompt=(np.arange(5) * 5 + 2) % 100,
                    max_new_tokens=3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_idle()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(r.done for r in done)
    assert len(done[0].out) >= 1
    # a second call returns only newly-finished work, not stale requests
    eng.submit(Request(rid=3, prompt=np.arange(4) % 100, max_new_tokens=2))
    done2 = eng.run_until_idle()
    assert [r.rid for r in done2] == [3]


def test_engine_dirty_set_tracks_per_step_mutations(setup):
    """Serving tenants pre-copy params-free: params are clean after the
    first export; decode steps dirty only the cache/positions."""
    run, model, params = setup
    eng = ServeEngine(run, params, slots=1, max_len=48)
    assert "params" in eng.dirty_keys()          # never exported yet
    st = eng.export_state()
    assert set(st) == {"params", "cache", "pos", "last_token"}
    assert st["params"] is params
    assert eng.dirty_keys() == set()
    eng.submit(Request(rid=0, prompt=np.arange(4) % 50, max_new_tokens=2))
    eng.run_until_idle()
    assert eng.dirty_keys() == {"cache", "pos", "last_token"}
    st2 = eng.export_state()
    assert st2["params"] is params               # identity-clean for memo


def test_engine_eos_stops_early(setup):
    run, model, params = setup
    # discover the first greedy token, then use it as the EOS id
    probe = Request(rid=0, prompt=np.arange(4) % 50, max_new_tokens=2)
    eng = ServeEngine(run, params, slots=1, max_len=48)
    eng.submit(probe)
    while eng.step() or eng.queue:
        pass
    eos = probe.out[0]
    req = Request(rid=1, prompt=np.arange(4) % 50, max_new_tokens=10,
                  eos_id=eos)
    eng2 = ServeEngine(run, params, slots=1, max_len=48)
    eng2.submit(req)
    while eng2.step() or eng2.queue:
        pass
    assert req.done and len(req.out) == 1 and req.out[0] == eos
